//! Client availability: per-round dropout schedules and straggler delay
//! traces — the scenario engine's generalization of the seed's single
//! [`FaultSpec`](crate::coordinator::server::FaultSpec) dropout knob.
//!
//! An [`AvailabilityModel`] answers two questions per round: with what
//! probability does a selected client drop out of *this* round, and which
//! survivors reply late (and by how much)? Dropout can change over the
//! experiment through [`Phase`]s (e.g. a fleet that degrades after round
//! 20, mirroring diurnal client churn); stragglers inject a fixed reply
//! delay with some probability, so wall-clock metrics show the tail a
//! real federation would see.
//!
//! All probabilities are validated at construction — outside `[0, 1]` or
//! NaN is a typed [`AvailabilityError`], never silent nonsense — which is
//! also where the historically unvalidated `FaultSpec::client_dropout`
//! gets checked (`TryFrom<FaultSpec>`).
//!
//! The default model ([`AvailabilityModel::always_on`]) draws no random
//! numbers and injects no delays, so default runs stay bit-identical to
//! the pre-scenario-engine orchestrator.

use std::fmt;

use crate::coordinator::server::FaultSpec;

/// Longest allowed straggler delay: guards against a manifest typo (ms vs
/// s) freezing a round for hours.
pub const MAX_STRAGGLER_DELAY_MS: u64 = 60_000;

/// Longest *wall-clock* sleep a straggler may inject on a real transport
/// (loopback / TCP). Straggler delays are a modeling knob, not a load
/// test: the full configured delay is always *accounted* (per round in
/// `RoundRecord::straggler_delay_ms`, and in full as virtual time under
/// the `sim` transport), but the thread actually sleeping is capped here
/// so availability grids and tests run at CPU speed. Historically the
/// round driver slept the whole delay for real, which made straggler
/// grid cells wall-clock-bound.
pub const REAL_STRAGGLE_CAP_MS: u64 = 25;

/// Typed validation error for availability parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum AvailabilityError {
    /// A probability was NaN or outside [0, 1].
    BadProbability { what: &'static str, value: f64 },
    /// Phase `from_round`s must be ≥ 1 and strictly increasing.
    BadPhaseRound { round: usize },
    /// Straggler delay exceeds [`MAX_STRAGGLER_DELAY_MS`].
    BadDelay { delay_ms: u64 },
}

impl fmt::Display for AvailabilityError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AvailabilityError::BadProbability { what, value } => {
                write!(f, "{what} must be in [0, 1], got {value}")
            }
            AvailabilityError::BadPhaseRound { round } => {
                write!(
                    f,
                    "phase rounds must be >= 1 and strictly increasing (offending round {round})"
                )
            }
            AvailabilityError::BadDelay { delay_ms } => {
                write!(
                    f,
                    "straggler delay {delay_ms} ms exceeds the {MAX_STRAGGLER_DELAY_MS} ms cap"
                )
            }
        }
    }
}

impl std::error::Error for AvailabilityError {}

/// One dropout-schedule step: from round `from_round` (1-based, inclusive)
/// onward, selected clients drop with probability `dropout` — until a
/// later phase takes over.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Phase {
    pub from_round: usize,
    pub dropout: f64,
}

/// Validated per-round availability: phased dropout plus straggler delays.
#[derive(Clone, Debug, PartialEq)]
pub struct AvailabilityModel {
    base_dropout: f64,
    phases: Vec<Phase>,
    straggler_prob: f64,
    straggler_delay_ms: u64,
}

impl Default for AvailabilityModel {
    fn default() -> Self {
        Self::always_on()
    }
}

impl AvailabilityModel {
    /// Every client always participates and replies promptly — the
    /// zero-randomness default; runs under it are bit-identical to the
    /// pre-availability orchestrator.
    pub fn always_on() -> Self {
        AvailabilityModel {
            base_dropout: 0.0,
            phases: Vec::new(),
            straggler_prob: 0.0,
            straggler_delay_ms: 0,
        }
    }

    /// Uniform dropout, no phases, no stragglers (the seed `FaultSpec`
    /// behavior — but validated).
    pub fn uniform(dropout: f64) -> Result<Self, AvailabilityError> {
        Self::new(dropout, Vec::new(), 0.0, 0)
    }

    /// Full model. Rejects NaN / out-of-range probabilities, unsorted
    /// phase rounds, and absurd delays with a typed error.
    pub fn new(
        base_dropout: f64,
        phases: Vec<Phase>,
        straggler_prob: f64,
        straggler_delay_ms: u64,
    ) -> Result<Self, AvailabilityError> {
        check_prob("client dropout probability", base_dropout)?;
        check_prob("straggler probability", straggler_prob)?;
        if straggler_delay_ms > MAX_STRAGGLER_DELAY_MS {
            return Err(AvailabilityError::BadDelay { delay_ms: straggler_delay_ms });
        }
        let mut last = 0usize;
        for p in &phases {
            check_prob("phase dropout probability", p.dropout)?;
            if p.from_round == 0 || p.from_round <= last {
                return Err(AvailabilityError::BadPhaseRound { round: p.from_round });
            }
            last = p.from_round;
        }
        Ok(AvailabilityModel { base_dropout, phases, straggler_prob, straggler_delay_ms })
    }

    /// Dropout probability in effect for `round` (1-based): the latest
    /// phase whose `from_round` has been reached, else the base rate.
    pub fn dropout_for_round(&self, round: usize) -> f64 {
        let mut p = self.base_dropout;
        for phase in &self.phases {
            if phase.from_round <= round {
                p = phase.dropout;
            } else {
                break;
            }
        }
        p
    }

    /// Probability that a surviving client replies `straggler_delay_ms`
    /// late.
    pub fn straggler_prob(&self) -> f64 {
        self.straggler_prob
    }

    /// Reply delay injected for stragglers, in milliseconds.
    pub fn straggler_delay_ms(&self) -> u64 {
        self.straggler_delay_ms
    }

    /// True when stragglers are enabled (the round driver skips the
    /// per-client RNG draws entirely otherwise, preserving the default
    /// path's bit-exact RNG stream).
    pub fn has_stragglers(&self) -> bool {
        self.straggler_prob > 0.0 && self.straggler_delay_ms > 0
    }
}

/// Ledger of *observed* (as opposed to scheduled) unavailability. The
/// [`AvailabilityModel`] predicts dropout; this ledger records what each
/// round actually saw: selected clients that contributed nothing —
/// whether the schedule dropped them before the exchange or the server
/// rejected their update as faulty
/// ([`ClientFault`](crate::coordinator::server::ClientFault)). From the
/// aggregation's point of view a Byzantine client and a dropped-out
/// client are the same event (an update that never landed), so both feed
/// the same ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObservedDropout {
    selected: u64,
    dropped: u64,
    rejected: u64,
}

impl ObservedDropout {
    /// Record one round: how many clients the selector picked, how many
    /// the availability schedule dropped pre-exchange, and how many
    /// survivors the server rejected as faulty post-exchange.
    pub fn note_round(&mut self, selected: usize, dropped: usize, rejected: usize) {
        self.selected += selected as u64;
        self.dropped += dropped as u64;
        self.rejected += rejected as u64;
    }

    /// Cumulative clients picked by the selector.
    pub fn selected(&self) -> u64 {
        self.selected
    }

    /// Cumulative pre-exchange dropouts (availability schedule).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Cumulative post-exchange rejections (faulty/Byzantine updates).
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Fraction of selected clients that contributed nothing so far —
    /// the run's empirical dropout rate, rejections included.
    pub fn observed_rate(&self) -> f64 {
        if self.selected == 0 {
            0.0
        } else {
            (self.dropped + self.rejected) as f64 / self.selected as f64
        }
    }
}

fn check_prob(what: &'static str, value: f64) -> Result<(), AvailabilityError> {
    // NaN fails the range check and is rejected (Config validation style)
    if (0.0..=1.0).contains(&value) {
        Ok(())
    } else {
        Err(AvailabilityError::BadProbability { what, value })
    }
}

impl TryFrom<FaultSpec> for AvailabilityModel {
    type Error = AvailabilityError;

    /// The bugfix path: `FaultSpec`'s public `client_dropout` field was
    /// historically unvalidated; every conversion into the orchestrator
    /// now rejects NaN / out-of-range values.
    fn try_from(faults: FaultSpec) -> Result<Self, AvailabilityError> {
        Self::uniform(faults.client_dropout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_trivial() {
        let m = AvailabilityModel::default();
        assert_eq!(m.dropout_for_round(1), 0.0);
        assert_eq!(m.dropout_for_round(1000), 0.0);
        assert!(!m.has_stragglers());
    }

    #[test]
    fn rejects_bad_probabilities() {
        for p in [-0.1, 1.1, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = AvailabilityModel::uniform(p).unwrap_err();
            assert!(
                matches!(err, AvailabilityError::BadProbability { .. }),
                "p={p} err={err}"
            );
            let err = AvailabilityModel::new(0.0, Vec::new(), p, 0).unwrap_err();
            assert!(matches!(err, AvailabilityError::BadProbability { .. }), "p={p}");
            let phases = vec![Phase { from_round: 5, dropout: p }];
            assert!(AvailabilityModel::new(0.0, phases, 0.0, 0).is_err(), "p={p}");
        }
        // boundaries are fine
        AvailabilityModel::uniform(0.0).unwrap();
        AvailabilityModel::uniform(1.0).unwrap();
    }

    #[test]
    fn faultspec_conversion_is_validated() {
        let ok = AvailabilityModel::try_from(FaultSpec { client_dropout: 0.3 }).unwrap();
        assert_eq!(ok.dropout_for_round(1), 0.3);
        for p in [-0.5, 1.5, f64::NAN] {
            let err = AvailabilityModel::try_from(FaultSpec { client_dropout: p });
            assert!(err.is_err(), "p={p}");
        }
    }

    #[test]
    fn phases_schedule_dropout() {
        let m = AvailabilityModel::new(
            0.0,
            vec![
                Phase { from_round: 10, dropout: 0.2 },
                Phase { from_round: 20, dropout: 0.5 },
            ],
            0.0,
            0,
        )
        .unwrap();
        assert_eq!(m.dropout_for_round(1), 0.0);
        assert_eq!(m.dropout_for_round(9), 0.0);
        assert_eq!(m.dropout_for_round(10), 0.2);
        assert_eq!(m.dropout_for_round(19), 0.2);
        assert_eq!(m.dropout_for_round(20), 0.5);
        assert_eq!(m.dropout_for_round(10_000), 0.5);
    }

    #[test]
    fn rejects_unsorted_or_zero_phases() {
        let unsorted = vec![
            Phase { from_round: 20, dropout: 0.1 },
            Phase { from_round: 10, dropout: 0.2 },
        ];
        let err = AvailabilityModel::new(0.0, unsorted, 0.0, 0).unwrap_err();
        assert!(matches!(err, AvailabilityError::BadPhaseRound { round: 10 }));
        let zero = vec![Phase { from_round: 0, dropout: 0.1 }];
        assert!(AvailabilityModel::new(0.0, zero, 0.0, 0).is_err());
        let dup = vec![
            Phase { from_round: 5, dropout: 0.1 },
            Phase { from_round: 5, dropout: 0.2 },
        ];
        assert!(AvailabilityModel::new(0.0, dup, 0.0, 0).is_err());
    }

    #[test]
    fn rejects_absurd_delay() {
        let err = AvailabilityModel::new(0.0, Vec::new(), 0.5, MAX_STRAGGLER_DELAY_MS + 1);
        assert!(matches!(err.unwrap_err(), AvailabilityError::BadDelay { .. }));
        AvailabilityModel::new(0.0, Vec::new(), 0.5, MAX_STRAGGLER_DELAY_MS).unwrap();
    }

    #[test]
    fn straggler_flag() {
        let m = AvailabilityModel::new(0.0, Vec::new(), 0.5, 10).unwrap();
        assert!(m.has_stragglers());
        // prob without delay (or delay without prob) is inert
        let m = AvailabilityModel::new(0.0, Vec::new(), 0.5, 0).unwrap();
        assert!(!m.has_stragglers());
        let m = AvailabilityModel::new(0.0, Vec::new(), 0.0, 10).unwrap();
        assert!(!m.has_stragglers());
    }

    #[test]
    fn observed_ledger_counts_dropout_and_rejections_alike() {
        let mut led = ObservedDropout::default();
        assert_eq!(led.observed_rate(), 0.0, "empty ledger divides by nothing");
        led.note_round(10, 2, 0); // schedule dropped 2
        led.note_round(10, 0, 3); // server rejected 3
        assert_eq!(led.selected(), 20);
        assert_eq!(led.dropped(), 2);
        assert_eq!(led.rejected(), 3);
        assert_eq!(led.observed_rate(), 5.0 / 20.0);
    }

    #[test]
    fn errors_display() {
        let e = AvailabilityError::BadProbability {
            what: "client dropout probability",
            value: 2.0,
        };
        assert!(format!("{e}").contains("[0, 1]"));
        let e = AvailabilityError::BadDelay { delay_ms: 999_999 };
        assert!(format!("{e}").contains("cap"));
    }
}
