//! The federated coordinator — Layer 3, the paper's protocol machinery.
//!
//! * `selection` — seeded client sampling (participation ratio lambda)
//! * `aggregation` — streaming data-size-weighted FedAvg fold (eq. 2):
//!   O(model) peak memory at any fleet size, bit-identical to the batch
//!   average — plus the string-keyed robust-aggregation registry
//!   (trimmed mean, coordinate median, norm clipping, Krum)
//! * `availability` — per-round dropout schedules and straggler delay
//!   traces (validated probabilities, typed errors), plus the observed
//!   ledger that counts fault rejections as dropout
//! * `adversary` — the Byzantine client axis: typed misbehaviors cast
//!   per registered client id from a server-seeded generator
//! * `client` — local shard materialization + epoch-chunk batching + the
//!   `ClientRuntime` round handler shared by loopback and remote clients
//! * `backend` — compute abstraction: PJRT artifacts or the native mirror
//! * `server` — the round driver for Baseline / TTQ / FedAvg / T-FedAvg
//!   (Algorithm 2): selected clients fan out over a `transport::Transport`
//!   via a worker pool, and every cross-network byte is framed and counted

pub mod adversary;
pub mod aggregation;
pub mod availability;
pub mod backend;
pub mod client;
pub mod selection;
pub mod server;

pub use adversary::{AdversaryError, AdversaryModel, AdversarySpec, Behavior};
pub use aggregation::{
    aggregator_names, krum_distance_matrix, robust_aggregate, weighted_average, Aggregator,
    AggregatorSpec, RobustOutcome,
};
pub use availability::{AvailabilityError, AvailabilityModel, ObservedDropout, Phase};
pub use backend::{Backend, LocalOutcome, NativeBackend, PjrtBackend, TrainMode};
pub use client::{ClientAdversary, ClientRuntime, ShardData};
pub use server::{
    materialize_data, materialize_shard, run_experiment, ClientFault, Orchestrator,
};
