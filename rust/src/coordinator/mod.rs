//! The federated coordinator — Layer 3, the paper's protocol machinery.
//!
//! * `selection` — seeded client sampling (participation ratio lambda)
//! * `aggregation` — data-size-weighted FedAvg averaging (eq. 2)
//! * `client` — local shard materialization + epoch-chunk batching + the
//!   `ClientRuntime` round handler shared by loopback and remote clients
//! * `backend` — compute abstraction: PJRT artifacts or the native mirror
//! * `server` — the round driver for Baseline / TTQ / FedAvg / T-FedAvg
//!   (Algorithm 2): selected clients fan out over a `transport::Transport`
//!   via a worker pool, and every cross-network byte is framed and counted

pub mod aggregation;
pub mod backend;
pub mod client;
pub mod selection;
pub mod server;

pub use backend::{Backend, LocalOutcome, NativeBackend, PjrtBackend, TrainMode};
pub use client::{ClientRuntime, ShardData};
pub use server::{materialize_data, materialize_shard, run_experiment, Orchestrator};
