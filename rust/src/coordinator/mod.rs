//! The federated coordinator — Layer 3, the paper's protocol machinery.
//!
//! * `selection` — seeded client sampling (participation ratio lambda)
//! * `aggregation` — data-size-weighted FedAvg averaging (eq. 2)
//! * `client` — local shard materialization + epoch-chunk batching
//! * `backend` — compute abstraction: PJRT artifacts or the native mirror
//! * `server` — the round loops for Baseline / TTQ / FedAvg / T-FedAvg
//!   (Algorithm 2), with every cross-"network" byte serialized and counted

pub mod aggregation;
pub mod backend;
pub mod client;
pub mod selection;
pub mod server;

pub use backend::{Backend, LocalOutcome, NativeBackend, PjrtBackend, TrainMode};
pub use client::ShardData;
pub use server::{run_experiment, Orchestrator};
