//! Server aggregation: the FedAvg data-size-weighted average (eq. 2 /
//! Algorithm 2 server step), as a *streaming* fold.
//!
//! The seed buffered every rebuilt client model and averaged at the end —
//! `O(clients × model)` floats, which caps fleet size long before the
//! ROADMAP's thousands-of-clients target. [`Aggregator`] instead folds
//! each arriving update into a single model-sized accumulator and lets
//! the caller drop the update immediately, so peak memory is `O(model)`
//! regardless of how many clients report.
//!
//! **Equivalence argument** (DESIGN.md §8): the batch path computed
//! `acc += (n_k / total) · θ_k` in selection order with `total` summed
//! up front. The round driver knows every selected client's sample count
//! *before* dispatch (server-side shard sizes), so the streaming fold
//! applies the identical weight `n_k / total` in the identical order —
//! the same float-op sequence, hence bit-identical results. The batch
//! [`weighted_average`] is now a thin wrapper over the fold and the
//! regression tests compare both against an independent reference.
//!
//! **Robust registry** (DESIGN.md §13): [`AggregatorSpec`] is the
//! string-keyed rule selector next to the codec registry — `mean` (the
//! streaming fold above, byte-identical default), `trimmed_mean:β`,
//! `median`, `norm_clip:τ`, and `krum:f`. The robust rules inherently
//! buffer the round's accepted updates (their math needs cross-client
//! order statistics), so only the default keeps the `O(model)` streaming
//! bound; [`robust_aggregate`] is the shared dispatch.

use std::fmt;

use anyhow::{bail, Result};

use crate::model::{ModelSchema, ParamSet};

/// Streaming eq.-2 accumulator: `θ_{r+1} = Σ_k (n_k / total) · θ_k`.
///
/// `total = Σ_k n_k` must be known at construction (the round driver
/// derives it from its own shard sizes over the surviving selection);
/// each [`fold`](Aggregator::fold) then applies the final weight
/// immediately. [`finish`](Aggregator::finish) verifies that exactly the
/// expected samples arrived and that the result is finite.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::coordinator::aggregation::Aggregator;
/// use tfed::model::{init_params, mlp_schema};
/// use tfed::util::rng::Pcg;
///
/// let schema = mlp_schema();
/// let mut rng = Pcg::seeded(1);
/// let mut agg = Aggregator::for_schema(&schema, 300).unwrap();
/// for _ in 0..3 {
///     let update = init_params(&schema, &mut rng);
///     agg.fold(100, &update).unwrap(); // update can be dropped right here
/// }
/// let global = agg.finish().unwrap();
/// assert_eq!(global.numel(), schema.param_count());
/// ```
pub struct Aggregator {
    acc: ParamSet,
    total: u64,
    folded_samples: u64,
    folded_updates: usize,
}

impl Aggregator {
    /// Start from a zeroed accumulator shaped by `schema`, expecting
    /// `total` samples (> 0) across all folds.
    pub fn for_schema(schema: &ModelSchema, total: u64) -> Result<Self> {
        Self::start(ParamSet::zeros(schema), total)
    }

    /// Start from an explicit (zeroed) accumulator — for callers that
    /// shape the model without a schema at hand.
    pub fn start(acc: ParamSet, total: u64) -> Result<Self> {
        if total == 0 {
            bail!("aggregation expects > 0 total samples");
        }
        Ok(Aggregator { acc, total, folded_samples: 0, folded_updates: 0 })
    }

    /// Fold one client update, weighted by its sample count. The update
    /// is only borrowed; the caller frees it right after, keeping peak
    /// memory at one model.
    pub fn fold(&mut self, num_samples: u64, update: &ParamSet) -> Result<()> {
        if update.tensors.len() != self.acc.tensors.len() {
            bail!(
                "update has {} tensors, accumulator has {}",
                update.tensors.len(),
                self.acc.tensors.len()
            );
        }
        for (a, u) in self.acc.tensors.iter().zip(&update.tensors) {
            if a.data.len() != u.data.len() {
                bail!(
                    "update tensor size mismatch: {} values for accumulator shape {:?}",
                    u.data.len(),
                    a.shape
                );
            }
        }
        let w = (num_samples as f64 / self.total as f64) as f32;
        self.acc.axpy(w, update);
        self.folded_samples = self.folded_samples.saturating_add(num_samples);
        self.folded_updates += 1;
        if crate::obs::enabled() {
            crate::obs::metrics::counter("tfed_agg_folds_total").inc();
            crate::obs::metrics::counter("tfed_agg_samples_total").add(num_samples);
        }
        Ok(())
    }

    /// Updates folded so far.
    pub fn folded(&self) -> usize {
        self.folded_updates
    }

    /// Accumulator footprint in f32 elements — exactly one model,
    /// constant across the whole fold (asserted by the 512-client scale
    /// test, the O(model) memory guarantee).
    pub fn accumulator_elems(&self) -> usize {
        self.acc.numel()
    }

    /// Complete the fold: at least one update, exactly the expected
    /// sample total, and a finite result.
    pub fn finish(self) -> Result<ParamSet> {
        if self.folded_updates == 0 {
            bail!("no updates to aggregate");
        }
        if self.folded_samples != self.total {
            bail!(
                "aggregated {} of {} expected samples",
                self.folded_samples,
                self.total
            );
        }
        if !self.acc.is_finite() {
            bail!("aggregated model contains non-finite values");
        }
        Ok(self.acc)
    }
}

/// `θ_{r+1} = Σ_k (|D_k| / Σ|D_k|) · θ_k` — the batch convenience wrapper
/// over [`Aggregator`] for callers that already hold every update
/// (benches, tests, offline tools). Bit-identical to the streaming fold
/// by construction.
pub fn weighted_average(updates: &[(u64, ParamSet)]) -> Result<ParamSet> {
    if updates.is_empty() {
        bail!("no updates to aggregate");
    }
    let total: u64 = updates.iter().map(|(n, _)| *n).sum();
    if total == 0 {
        bail!("all updates report zero samples");
    }
    let mut acc = updates[0].1.clone();
    acc.scale(0.0);
    let mut agg = Aggregator::start(acc, total)?;
    for (n, p) in updates {
        agg.fold(*n, p)?;
    }
    agg.finish()
}

// ---------------------------------------------------------------------------
// robust-aggregation registry
// ---------------------------------------------------------------------------

/// Largest accepted trim fraction: trimming half (or more) from each end
/// leaves nothing to average.
pub const MAX_TRIM: f64 = 0.5;

/// Default trim fraction for `trimmed_mean` without a parameter.
pub const DEFAULT_TRIM: f64 = 0.2;

/// Default norm-clip threshold multiplier (× the cohort's median norm).
pub const DEFAULT_CLIP_TAU: f64 = 1.0;

/// Default assumed Byzantine count for `krum` without a parameter.
pub const DEFAULT_KRUM_F: u64 = 1;

/// Typed validation/parse error for aggregation-rule parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum AggregatorError {
    /// Rule name not in the registry.
    UnknownAggregator { name: String },
    /// Trim fraction NaN or outside [0, MAX_TRIM).
    BadTrim { value: f64 },
    /// Norm-clip multiplier NaN, non-positive, or infinite.
    BadTau { value: f64 },
    /// A rule parameter failed to parse.
    BadParam { name: String },
}

impl fmt::Display for AggregatorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AggregatorError::UnknownAggregator { name } => {
                write!(
                    f,
                    "unknown aggregator {name:?} (known: {})",
                    aggregator_names().join(", ")
                )
            }
            AggregatorError::BadTrim { value } => {
                write!(f, "trim fraction must be in [0, {MAX_TRIM}), got {value}")
            }
            AggregatorError::BadTau { value } => {
                write!(f, "norm-clip multiplier must be finite and > 0, got {value}")
            }
            AggregatorError::BadParam { name } => {
                write!(f, "malformed aggregator parameter in {name:?}")
            }
        }
    }
}

impl std::error::Error for AggregatorError {}

/// Registry keys `AggregatorSpec::parse` accepts (parameterized rules
/// shown with their syntax).
pub fn aggregator_names() -> Vec<&'static str> {
    vec!["mean", "trimmed_mean[:beta]", "median", "norm_clip[:tau]", "krum[:f]"]
}

/// Which aggregation rule the server runs — carried in
/// `ExperimentConfig` and the Config wire frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggregatorSpec {
    /// The sample-weighted streaming fold above (the byte-identical
    /// default).
    Mean,
    /// Coordinate-wise mean after dropping the `beta` fraction of
    /// smallest and largest values (unweighted; breakdown point `beta`).
    TrimmedMean { beta: f64 },
    /// Coordinate-wise median (unweighted; breakdown point 1/2).
    Median,
    /// Clip each update's L2 norm to `tau ×` the cohort median norm,
    /// then take the sample-weighted mean; clipped ids are reported.
    NormClip { tau: f64 },
    /// Krum (Blanchard et al. 2017): return the single update with the
    /// smallest sum of squared distances to its `n - f - 2` nearest
    /// neighbors, assuming at most `f` Byzantine clients.
    Krum { f: u64 },
}

impl Default for AggregatorSpec {
    fn default() -> Self {
        AggregatorSpec::Mean
    }
}

impl AggregatorSpec {
    /// Serialized size in the Config frame: rule id (u8) + parameter
    /// (f64).
    pub const WIRE_BYTES: usize = 9;

    /// Registry key + parameter, parseable back by [`Self::parse`].
    pub fn name(&self) -> String {
        match self {
            AggregatorSpec::Mean => "mean".into(),
            AggregatorSpec::TrimmedMean { beta } => format!("trimmed_mean:{beta}"),
            AggregatorSpec::Median => "median".into(),
            AggregatorSpec::NormClip { tau } => format!("norm_clip:{tau}"),
            AggregatorSpec::Krum { f } => format!("krum:{f}"),
        }
    }

    /// Parse a registry key with optional `:param` suffix.
    pub fn parse(s: &str) -> Result<Self, AggregatorError> {
        let (key, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        let parse_f64 = |a: &str| {
            a.parse::<f64>().map_err(|_| AggregatorError::BadParam { name: s.into() })
        };
        let spec = match (key, arg) {
            ("mean", None) => AggregatorSpec::Mean,
            ("trimmed_mean", None) => AggregatorSpec::TrimmedMean { beta: DEFAULT_TRIM },
            ("trimmed_mean", Some(a)) => AggregatorSpec::TrimmedMean { beta: parse_f64(a)? },
            ("median", None) => AggregatorSpec::Median,
            ("norm_clip", None) => AggregatorSpec::NormClip { tau: DEFAULT_CLIP_TAU },
            ("norm_clip", Some(a)) => AggregatorSpec::NormClip { tau: parse_f64(a)? },
            ("krum", None) => AggregatorSpec::Krum { f: DEFAULT_KRUM_F },
            ("krum", Some(a)) => AggregatorSpec::Krum {
                f: a.parse::<u64>().map_err(|_| AggregatorError::BadParam { name: s.into() })?,
            },
            _ => return Err(AggregatorError::UnknownAggregator { name: s.into() }),
        };
        spec.check()?;
        Ok(spec)
    }

    /// Validate rule parameters (NaN rejected like Config validation).
    pub fn check(&self) -> Result<(), AggregatorError> {
        match *self {
            AggregatorSpec::Mean | AggregatorSpec::Median | AggregatorSpec::Krum { .. } => Ok(()),
            AggregatorSpec::TrimmedMean { beta } => {
                if (0.0..MAX_TRIM).contains(&beta) {
                    Ok(())
                } else {
                    Err(AggregatorError::BadTrim { value: beta })
                }
            }
            AggregatorSpec::NormClip { tau } => {
                if tau.is_finite() && tau > 0.0 {
                    Ok(())
                } else {
                    Err(AggregatorError::BadTau { value: tau })
                }
            }
        }
    }

    fn id_param(&self) -> (u8, f64) {
        match *self {
            AggregatorSpec::Mean => (0, 0.0),
            AggregatorSpec::TrimmedMean { beta } => (1, beta),
            AggregatorSpec::Median => (2, 0.0),
            AggregatorSpec::NormClip { tau } => (3, tau),
            AggregatorSpec::Krum { f } => (4, f as f64),
        }
    }

    /// Fixed-size Config-frame encoding.
    pub fn to_wire(&self) -> [u8; Self::WIRE_BYTES] {
        let (id, param) = self.id_param();
        let mut out = [0u8; Self::WIRE_BYTES];
        out[0] = id;
        out[1..9].copy_from_slice(&param.to_le_bytes());
        out
    }

    /// Decode and validate a Config-frame encoding.
    pub fn from_wire(bytes: [u8; Self::WIRE_BYTES]) -> Result<Self, AggregatorError> {
        let param = f64::from_le_bytes(bytes[1..9].try_into().unwrap());
        let spec = match bytes[0] {
            0 => AggregatorSpec::Mean,
            1 => AggregatorSpec::TrimmedMean { beta: param },
            2 => AggregatorSpec::Median,
            3 => AggregatorSpec::NormClip { tau: param },
            4 => AggregatorSpec::Krum { f: param as u64 },
            id => {
                return Err(AggregatorError::UnknownAggregator {
                    name: format!("wire id {id}"),
                })
            }
        };
        spec.check()?;
        Ok(spec)
    }
}

/// Result of one robust-aggregation pass.
#[derive(Clone, Debug)]
pub struct RobustOutcome {
    pub global: ParamSet,
    /// Client ids whose updates were norm-clipped (empty for every rule
    /// but `norm_clip`).
    pub clipped: Vec<u32>,
}

/// Run `spec` over the round's accepted updates, given as
/// `(client_id, num_samples, update)` in selection order. `Mean` here is
/// the batch wrapper (bit-identical to the streaming fold); the server
/// keeps its streaming path for the default and calls this for every
/// robust rule.
pub fn robust_aggregate(
    spec: AggregatorSpec,
    updates: &[(u32, u64, ParamSet)],
) -> Result<RobustOutcome> {
    if updates.is_empty() {
        bail!("no updates to aggregate");
    }
    let first = &updates[0].2;
    for (cid, _, u) in updates {
        if u.tensors.len() != first.tensors.len()
            || u.tensors.iter().zip(&first.tensors).any(|(a, b)| a.data.len() != b.data.len())
        {
            bail!("client {cid} update shape disagrees with the cohort");
        }
    }
    let outcome = match spec {
        AggregatorSpec::Mean => {
            let fleet: Vec<(u64, ParamSet)> =
                updates.iter().map(|(_, n, p)| (*n, p.clone())).collect();
            RobustOutcome { global: weighted_average(&fleet)?, clipped: Vec::new() }
        }
        AggregatorSpec::TrimmedMean { beta } => {
            RobustOutcome { global: trimmed_mean(updates, beta), clipped: Vec::new() }
        }
        AggregatorSpec::Median => {
            RobustOutcome { global: coordinate_median(updates), clipped: Vec::new() }
        }
        AggregatorSpec::NormClip { tau } => norm_clip(updates, tau)?,
        AggregatorSpec::Krum { f } => {
            RobustOutcome { global: krum(updates, f), clipped: Vec::new() }
        }
    };
    if !outcome.global.is_finite() {
        bail!("aggregated model contains non-finite values");
    }
    Ok(outcome)
}

/// Coordinate-wise trimmed mean: drop `floor(beta·n)` values from each
/// end of every coordinate's sorted column, average the rest
/// (unweighted). The trim count is clamped so at least one value
/// survives on tiny cohorts.
fn trimmed_mean(updates: &[(u32, u64, ParamSet)], beta: f64) -> ParamSet {
    let n = updates.len();
    let k = ((beta * n as f64).floor() as usize).min((n - 1) / 2);
    reduce_columns(updates, |col| {
        col.sort_unstable_by(f32::total_cmp);
        let kept = &col[k..col.len() - k];
        (kept.iter().map(|&v| v as f64).sum::<f64>() / kept.len() as f64) as f32
    })
}

/// Coordinate-wise median (even cohorts average the two middle values).
fn coordinate_median(updates: &[(u32, u64, ParamSet)]) -> ParamSet {
    reduce_columns(updates, |col| {
        col.sort_unstable_by(f32::total_cmp);
        let n = col.len();
        if n % 2 == 1 {
            col[n / 2]
        } else {
            ((col[n / 2 - 1] as f64 + col[n / 2] as f64) / 2.0) as f32
        }
    })
}

/// Apply `reduce` to every coordinate's column of per-client values.
fn reduce_columns(
    updates: &[(u32, u64, ParamSet)],
    mut reduce: impl FnMut(&mut Vec<f32>) -> f32,
) -> ParamSet {
    let mut out = updates[0].2.clone();
    let mut col = Vec::with_capacity(updates.len());
    for (ti, t) in out.tensors.iter_mut().enumerate() {
        for j in 0..t.data.len() {
            col.clear();
            col.extend(updates.iter().map(|(_, _, p)| p.tensors[ti].data[j]));
            t.data[j] = reduce(&mut col);
        }
    }
    out
}

/// L2 norm of one update (f64 accumulation, like `ParamSet::l2_distance`).
fn l2_norm(p: &ParamSet) -> f64 {
    p.tensors
        .iter()
        .flat_map(|t| t.data.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt()
}

/// Clip each update to `tau ×` the cohort's median norm, then take the
/// sample-weighted mean. Reports which clients got clipped.
fn norm_clip(updates: &[(u32, u64, ParamSet)], tau: f64) -> Result<RobustOutcome> {
    let mut norms: Vec<f64> = updates.iter().map(|(_, _, p)| l2_norm(p)).collect();
    let mut sorted = norms.clone();
    sorted.sort_unstable_by(f64::total_cmp);
    let n = sorted.len();
    let median_norm = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
    };
    let threshold = tau * median_norm;
    let mut clipped = Vec::new();
    let mut fleet: Vec<(u64, ParamSet)> = Vec::with_capacity(n);
    for ((cid, samples, p), norm) in updates.iter().zip(norms.drain(..)) {
        if norm > threshold && norm > 0.0 {
            let mut scaled = p.clone();
            scaled.scale((threshold / norm) as f32);
            clipped.push(*cid);
            fleet.push((*samples, scaled));
        } else {
            fleet.push((*samples, p.clone()));
        }
    }
    Ok(RobustOutcome { global: weighted_average(&fleet)?, clipped })
}

/// Krum selection: squared-distance matrix over the cohort, score each
/// update by the sum of its `n - f - 2` smallest squared distances
/// (clamped to [1, n-1] so small cohorts degrade to nearest-neighbor
/// rather than failing), return the argmin update (ties → lowest index).
fn krum(updates: &[(u32, u64, ParamSet)], f: u64) -> ParamSet {
    let n = updates.len();
    if n == 1 {
        return updates[0].2.clone();
    }
    let dist2 = krum_distance_matrix(updates);
    let neighbors = (n as i64 - f as i64 - 2).clamp(1, n as i64 - 1) as usize;
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for i in 0..n {
        let mut row: Vec<f64> =
            (0..n).filter(|&j| j != i).map(|j| dist2[i * n + j]).collect();
        row.sort_unstable_by(f64::total_cmp);
        let score: f64 = row[..neighbors].iter().sum();
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    updates[best].2.clone()
}

/// Pairwise squared L2 distances, row-major `n × n` (exposed for the
/// golden-fixture property test).
pub fn krum_distance_matrix(updates: &[(u32, u64, ParamSet)]) -> Vec<f64> {
    let n = updates.len();
    let mut dist2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = updates[i].2.l2_distance(&updates[j].2);
            let d2 = d * d;
            dist2[i * n + j] = d2;
            dist2[j * n + i] = d2;
        }
    }
    dist2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::toy_schema;
    use crate::model::init_params;
    use crate::util::proptest::forall;
    use crate::util::rng::Pcg;

    /// The pre-refactor batch implementation, kept verbatim as the
    /// bit-identity reference for both the wrapper and the streaming fold.
    fn batch_reference(updates: &[(u64, ParamSet)]) -> ParamSet {
        let total: u64 = updates.iter().map(|(n, _)| *n).sum();
        let mut acc = updates[0].1.clone();
        acc.scale(0.0);
        for (n, p) in updates {
            acc.axpy((*n as f64 / total as f64) as f32, p);
        }
        acc
    }

    fn assert_bitwise_eq(a: &ParamSet, b: &ParamSet) {
        assert_eq!(a.tensors.len(), b.tensors.len());
        for (x, y) in a.tensors.iter().zip(&b.tensors) {
            for (u, v) in x.data.iter().zip(&y.data) {
                assert_eq!(u.to_bits(), v.to_bits(), "{u} != {v}");
            }
        }
    }

    #[test]
    fn equal_weights_is_mean() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(1);
        let a = init_params(&schema, &mut rng);
        let b = init_params(&schema, &mut rng);
        let avg = weighted_average(&[(5, a.clone()), (5, b.clone())]).unwrap();
        for i in 0..avg.tensors.len() {
            for j in 0..avg.tensors[i].data.len() {
                let want = 0.5 * (a.tensors[i].data[j] + b.tensors[i].data[j]);
                assert!((avg.tensors[i].data[j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn single_update_is_identity() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(2);
        let a = init_params(&schema, &mut rng);
        let avg = weighted_average(&[(100, a.clone())]).unwrap();
        assert!(avg.l2_distance(&a) < 1e-6);
    }

    #[test]
    fn weights_proportional_to_samples() {
        forall(32, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let a = init_params(&schema, &mut prng);
            let b = init_params(&schema, &mut prng);
            let na = 1 + rng.below(1000) as u64;
            let nb = 1 + rng.below(1000) as u64;
            let avg = weighted_average(&[(na, a.clone()), (nb, b.clone())]).unwrap();
            let wa = na as f32 / (na + nb) as f32;
            let v = avg.tensors[0].data[0];
            let want = wa * a.tensors[0].data[0] + (1.0 - wa) * b.tensors[0].data[0];
            assert!((v - want).abs() < 1e-5);
        });
    }

    #[test]
    fn convexity_bounds() {
        // aggregate lies inside the coordinate-wise envelope of the inputs
        forall(16, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let sets: Vec<(u64, ParamSet)> = (0..4)
                .map(|_| (1 + rng.below(50) as u64, init_params(&schema, &mut prng)))
                .collect();
            let avg = weighted_average(&sets).unwrap();
            for i in 0..avg.tensors.len() {
                for j in 0..avg.tensors[i].data.len() {
                    let lo = sets
                        .iter()
                        .map(|(_, p)| p.tensors[i].data[j])
                        .fold(f32::INFINITY, f32::min);
                    let hi = sets
                        .iter()
                        .map(|(_, p)| p.tensors[i].data[j])
                        .fold(f32::NEG_INFINITY, f32::max);
                    let v = avg.tensors[i].data[j];
                    assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
                }
            }
        });
    }

    #[test]
    fn rejects_degenerate_input() {
        assert!(weighted_average(&[]).is_err());
        let schema = toy_schema();
        let mut rng = Pcg::seeded(3);
        let a = init_params(&schema, &mut rng);
        assert!(weighted_average(&[(0, a)]).is_err());
        assert!(Aggregator::for_schema(&schema, 0).is_err());
    }

    #[test]
    fn streaming_matches_batch_bitwise_over_random_fleets() {
        forall(64, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let k = 1 + rng.below(12) as usize;
            let fleet: Vec<(u64, ParamSet)> = (0..k)
                .map(|_| (1 + rng.below(5_000) as u64, init_params(&schema, &mut prng)))
                .collect();
            let total: u64 = fleet.iter().map(|(n, _)| *n).sum();

            let mut agg = Aggregator::for_schema(&schema, total).unwrap();
            for (n, p) in &fleet {
                agg.fold(*n, p).unwrap();
            }
            let streamed = agg.finish().unwrap();

            let reference = batch_reference(&fleet);
            assert_bitwise_eq(&streamed, &reference);
            let wrapped = weighted_average(&fleet).unwrap();
            assert_bitwise_eq(&wrapped, &reference);
        });
    }

    #[test]
    fn streaming_memory_is_one_model_at_512_clients() {
        // O(model) acceptance check: fold 512 clients one at a time, each
        // update generated and dropped inside the loop; the accumulator
        // footprint never grows past a single model.
        let schema = toy_schema();
        let n_clients = 512usize;
        let per_client = 37u64;
        let total = per_client * n_clients as u64;
        let model_elems = schema.param_count();

        let mut agg = Aggregator::for_schema(&schema, total).unwrap();
        for cid in 0..n_clients {
            let mut prng = Pcg::new(0xA66, cid as u64);
            let update = init_params(&schema, &mut prng);
            agg.fold(per_client, &update).unwrap();
            assert_eq!(agg.accumulator_elems(), model_elems, "after client {cid}");
        }
        assert_eq!(agg.folded(), n_clients);
        let streamed = agg.finish().unwrap();

        // regenerate the same fleet and compare bitwise against the
        // pre-refactor batch implementation
        let fleet: Vec<(u64, ParamSet)> = (0..n_clients)
            .map(|cid| {
                let mut prng = Pcg::new(0xA66, cid as u64);
                (per_client, init_params(&schema, &mut prng))
            })
            .collect();
        assert_bitwise_eq(&streamed, &batch_reference(&fleet));
    }

    #[test]
    fn finish_requires_exact_sample_total() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(4);
        let a = init_params(&schema, &mut rng);
        // short: folded < total
        let mut agg = Aggregator::for_schema(&schema, 100).unwrap();
        agg.fold(60, &a).unwrap();
        assert!(agg.finish().is_err());
        // over: folded > total
        let mut agg = Aggregator::for_schema(&schema, 50).unwrap();
        agg.fold(60, &a).unwrap();
        assert!(agg.finish().is_err());
        // empty fold
        let agg = Aggregator::for_schema(&schema, 10).unwrap();
        assert!(agg.finish().is_err());
    }

    #[test]
    fn fold_rejects_shape_mismatch() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(5);
        let good = init_params(&schema, &mut rng);
        let mut agg = Aggregator::for_schema(&schema, 10).unwrap();
        let mut missing = good.clone();
        missing.tensors.pop();
        assert!(agg.fold(5, &missing).is_err());
        let mut resized = good.clone();
        resized.tensors[0].data.push(0.0);
        assert!(agg.fold(5, &resized).is_err());
        agg.fold(10, &good).unwrap();
        agg.finish().unwrap();
    }

    #[test]
    fn finish_rejects_non_finite() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(6);
        let mut a = init_params(&schema, &mut rng);
        a.tensors[0].data[0] = f32::NAN;
        let mut agg = Aggregator::for_schema(&schema, 10).unwrap();
        agg.fold(10, &a).unwrap();
        assert!(agg.finish().is_err());
    }

    // -- robust registry ----------------------------------------------------

    fn fleet(seed: u64, n: usize) -> Vec<(u32, u64, ParamSet)> {
        let schema = toy_schema();
        let mut prng = Pcg::seeded(seed);
        (0..n)
            .map(|cid| (cid as u32, 10 + cid as u64, init_params(&schema, &mut prng)))
            .collect()
    }

    #[test]
    fn spec_parse_name_roundtrip() {
        for s in ["mean", "trimmed_mean:0.1", "median", "norm_clip:2.5", "krum:3"] {
            let spec = AggregatorSpec::parse(s).unwrap();
            assert_eq!(AggregatorSpec::parse(&spec.name()).unwrap(), spec, "{s}");
            let back = AggregatorSpec::from_wire(spec.to_wire()).unwrap();
            assert_eq!(back, spec, "{s} wire");
        }
        // bare parameterized names pick up defaults
        assert_eq!(
            AggregatorSpec::parse("trimmed_mean").unwrap(),
            AggregatorSpec::TrimmedMean { beta: DEFAULT_TRIM }
        );
        assert_eq!(
            AggregatorSpec::parse("norm_clip").unwrap(),
            AggregatorSpec::NormClip { tau: DEFAULT_CLIP_TAU }
        );
        assert_eq!(
            AggregatorSpec::parse("krum").unwrap(),
            AggregatorSpec::Krum { f: DEFAULT_KRUM_F }
        );
    }

    #[test]
    fn spec_rejects_bad_input() {
        assert!(matches!(
            AggregatorSpec::parse("blockchain").unwrap_err(),
            AggregatorError::UnknownAggregator { .. }
        ));
        assert!(matches!(
            AggregatorSpec::parse("trimmed_mean:0.5").unwrap_err(),
            AggregatorError::BadTrim { .. }
        ));
        assert!(matches!(
            AggregatorSpec::parse("trimmed_mean:nan").unwrap_err(),
            AggregatorError::BadParam { .. } | AggregatorError::BadTrim { .. }
        ));
        assert!(matches!(
            AggregatorSpec::parse("norm_clip:0").unwrap_err(),
            AggregatorError::BadTau { .. }
        ));
        assert!(matches!(
            AggregatorSpec::parse("krum:two").unwrap_err(),
            AggregatorError::BadParam { .. }
        ));
        let mut bytes = AggregatorSpec::Mean.to_wire();
        bytes[0] = 77;
        assert!(AggregatorSpec::from_wire(bytes).is_err());
    }

    #[test]
    fn robust_mean_matches_streaming_bitwise() {
        let updates = fleet(21, 7);
        let out = robust_aggregate(AggregatorSpec::Mean, &updates).unwrap();
        let batch: Vec<(u64, ParamSet)> =
            updates.iter().map(|(_, n, p)| (*n, p.clone())).collect();
        assert_bitwise_eq(&out.global, &weighted_average(&batch).unwrap());
        assert!(out.clipped.is_empty());
    }

    #[test]
    fn median_and_trimmed_mean_shrug_off_one_outlier() {
        let mut updates = fleet(22, 5);
        // poison client 0 with a huge scaled update
        for t in &mut updates[0].2.tensors {
            for v in &mut t.data {
                *v *= 1e6;
            }
        }
        let honest_envelope: f32 = updates[1..]
            .iter()
            .flat_map(|(_, _, p)| p.tensors.iter())
            .flat_map(|t| t.data.iter())
            .fold(0.0, |m, &v| m.max(v.abs()));
        for spec in [
            AggregatorSpec::Median,
            AggregatorSpec::TrimmedMean { beta: 0.2 },
        ] {
            let out = robust_aggregate(spec, &updates).unwrap();
            let worst: f32 = out
                .global
                .tensors
                .iter()
                .flat_map(|t| t.data.iter())
                .fold(0.0, |m, &v| m.max(v.abs()));
            assert!(
                worst <= honest_envelope + 1e-6,
                "{spec:?}: {worst} escaped honest envelope {honest_envelope}"
            );
        }
    }

    #[test]
    fn norm_clip_reports_and_bounds_outliers() {
        let mut updates = fleet(23, 5);
        for t in &mut updates[2].2.tensors {
            for v in &mut t.data {
                *v *= 1e4;
            }
        }
        let out = robust_aggregate(AggregatorSpec::NormClip { tau: 1.0 }, &updates).unwrap();
        assert_eq!(out.clipped, vec![2]);
        // the clipped cohort's mean stays near the honest updates' scale
        let norms: Vec<f64> = updates.iter().map(|(_, _, p)| l2_norm(p)).collect();
        let mut sorted = norms.clone();
        sorted.sort_unstable_by(f64::total_cmp);
        assert!(l2_norm(&out.global) <= sorted[2] * 1.5);
    }

    #[test]
    fn krum_picks_a_cohort_member_and_rejects_the_outlier() {
        let mut updates = fleet(24, 6);
        for t in &mut updates[4].2.tensors {
            for v in &mut t.data {
                *v = -*v * 50.0;
            }
        }
        let out = robust_aggregate(AggregatorSpec::Krum { f: 1 }, &updates).unwrap();
        // the selected update is one of the honest members verbatim
        let picked: Vec<usize> = updates
            .iter()
            .enumerate()
            .filter(|(_, (_, _, p))| p.l2_distance(&out.global) == 0.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(picked.len(), 1);
        assert_ne!(picked[0], 4, "krum selected the poisoned update");
    }

    #[test]
    fn robust_rules_survive_tiny_cohorts() {
        for n in 1..4 {
            let updates = fleet(25, n);
            for spec in [
                AggregatorSpec::Mean,
                AggregatorSpec::TrimmedMean { beta: 0.4 },
                AggregatorSpec::Median,
                AggregatorSpec::NormClip { tau: 1.0 },
                AggregatorSpec::Krum { f: 2 },
            ] {
                let out = robust_aggregate(spec, &updates);
                assert!(out.is_ok(), "n={n} {spec:?}: {out:?}");
            }
        }
        assert!(robust_aggregate(AggregatorSpec::Median, &[]).is_err());
    }

    #[test]
    fn robust_rejects_shape_mismatch_and_non_finite() {
        let mut updates = fleet(26, 3);
        updates[1].2.tensors[0].data.push(0.0);
        assert!(robust_aggregate(AggregatorSpec::Median, &updates).is_err());
        let mut updates = fleet(27, 3);
        updates[1].2.tensors[0].data[0] = f32::NAN;
        // NaN sorts to the top under total_cmp and gets trimmed, but the
        // mean path must still reject it
        assert!(robust_aggregate(AggregatorSpec::Mean, &updates).is_err());
    }
}
