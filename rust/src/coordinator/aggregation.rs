//! Server aggregation: the FedAvg data-size-weighted average (eq. 2 /
//! Algorithm 2 server step), as a *streaming* fold.
//!
//! The seed buffered every rebuilt client model and averaged at the end —
//! `O(clients × model)` floats, which caps fleet size long before the
//! ROADMAP's thousands-of-clients target. [`Aggregator`] instead folds
//! each arriving update into a single model-sized accumulator and lets
//! the caller drop the update immediately, so peak memory is `O(model)`
//! regardless of how many clients report.
//!
//! **Equivalence argument** (DESIGN.md §8): the batch path computed
//! `acc += (n_k / total) · θ_k` in selection order with `total` summed
//! up front. The round driver knows every selected client's sample count
//! *before* dispatch (server-side shard sizes), so the streaming fold
//! applies the identical weight `n_k / total` in the identical order —
//! the same float-op sequence, hence bit-identical results. The batch
//! [`weighted_average`] is now a thin wrapper over the fold and the
//! regression tests compare both against an independent reference.

use anyhow::{bail, Result};

use crate::model::{ModelSchema, ParamSet};

/// Streaming eq.-2 accumulator: `θ_{r+1} = Σ_k (n_k / total) · θ_k`.
///
/// `total = Σ_k n_k` must be known at construction (the round driver
/// derives it from its own shard sizes over the surviving selection);
/// each [`fold`](Aggregator::fold) then applies the final weight
/// immediately. [`finish`](Aggregator::finish) verifies that exactly the
/// expected samples arrived and that the result is finite.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::coordinator::aggregation::Aggregator;
/// use tfed::model::{init_params, mlp_schema};
/// use tfed::util::rng::Pcg;
///
/// let schema = mlp_schema();
/// let mut rng = Pcg::seeded(1);
/// let mut agg = Aggregator::for_schema(&schema, 300).unwrap();
/// for _ in 0..3 {
///     let update = init_params(&schema, &mut rng);
///     agg.fold(100, &update).unwrap(); // update can be dropped right here
/// }
/// let global = agg.finish().unwrap();
/// assert_eq!(global.numel(), schema.param_count());
/// ```
pub struct Aggregator {
    acc: ParamSet,
    total: u64,
    folded_samples: u64,
    folded_updates: usize,
}

impl Aggregator {
    /// Start from a zeroed accumulator shaped by `schema`, expecting
    /// `total` samples (> 0) across all folds.
    pub fn for_schema(schema: &ModelSchema, total: u64) -> Result<Self> {
        Self::start(ParamSet::zeros(schema), total)
    }

    /// Start from an explicit (zeroed) accumulator — for callers that
    /// shape the model without a schema at hand.
    pub fn start(acc: ParamSet, total: u64) -> Result<Self> {
        if total == 0 {
            bail!("aggregation expects > 0 total samples");
        }
        Ok(Aggregator { acc, total, folded_samples: 0, folded_updates: 0 })
    }

    /// Fold one client update, weighted by its sample count. The update
    /// is only borrowed; the caller frees it right after, keeping peak
    /// memory at one model.
    pub fn fold(&mut self, num_samples: u64, update: &ParamSet) -> Result<()> {
        if update.tensors.len() != self.acc.tensors.len() {
            bail!(
                "update has {} tensors, accumulator has {}",
                update.tensors.len(),
                self.acc.tensors.len()
            );
        }
        for (a, u) in self.acc.tensors.iter().zip(&update.tensors) {
            if a.data.len() != u.data.len() {
                bail!(
                    "update tensor size mismatch: {} values for accumulator shape {:?}",
                    u.data.len(),
                    a.shape
                );
            }
        }
        let w = (num_samples as f64 / self.total as f64) as f32;
        self.acc.axpy(w, update);
        self.folded_samples = self.folded_samples.saturating_add(num_samples);
        self.folded_updates += 1;
        if crate::obs::enabled() {
            crate::obs::metrics::counter("tfed_agg_folds_total").inc();
            crate::obs::metrics::counter("tfed_agg_samples_total").add(num_samples);
        }
        Ok(())
    }

    /// Updates folded so far.
    pub fn folded(&self) -> usize {
        self.folded_updates
    }

    /// Accumulator footprint in f32 elements — exactly one model,
    /// constant across the whole fold (asserted by the 512-client scale
    /// test, the O(model) memory guarantee).
    pub fn accumulator_elems(&self) -> usize {
        self.acc.numel()
    }

    /// Complete the fold: at least one update, exactly the expected
    /// sample total, and a finite result.
    pub fn finish(self) -> Result<ParamSet> {
        if self.folded_updates == 0 {
            bail!("no updates to aggregate");
        }
        if self.folded_samples != self.total {
            bail!(
                "aggregated {} of {} expected samples",
                self.folded_samples,
                self.total
            );
        }
        if !self.acc.is_finite() {
            bail!("aggregated model contains non-finite values");
        }
        Ok(self.acc)
    }
}

/// `θ_{r+1} = Σ_k (|D_k| / Σ|D_k|) · θ_k` — the batch convenience wrapper
/// over [`Aggregator`] for callers that already hold every update
/// (benches, tests, offline tools). Bit-identical to the streaming fold
/// by construction.
pub fn weighted_average(updates: &[(u64, ParamSet)]) -> Result<ParamSet> {
    if updates.is_empty() {
        bail!("no updates to aggregate");
    }
    let total: u64 = updates.iter().map(|(n, _)| *n).sum();
    if total == 0 {
        bail!("all updates report zero samples");
    }
    let mut acc = updates[0].1.clone();
    acc.scale(0.0);
    let mut agg = Aggregator::start(acc, total)?;
    for (n, p) in updates {
        agg.fold(*n, p)?;
    }
    agg.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::toy_schema;
    use crate::model::init_params;
    use crate::util::proptest::forall;
    use crate::util::rng::Pcg;

    /// The pre-refactor batch implementation, kept verbatim as the
    /// bit-identity reference for both the wrapper and the streaming fold.
    fn batch_reference(updates: &[(u64, ParamSet)]) -> ParamSet {
        let total: u64 = updates.iter().map(|(n, _)| *n).sum();
        let mut acc = updates[0].1.clone();
        acc.scale(0.0);
        for (n, p) in updates {
            acc.axpy((*n as f64 / total as f64) as f32, p);
        }
        acc
    }

    fn assert_bitwise_eq(a: &ParamSet, b: &ParamSet) {
        assert_eq!(a.tensors.len(), b.tensors.len());
        for (x, y) in a.tensors.iter().zip(&b.tensors) {
            for (u, v) in x.data.iter().zip(&y.data) {
                assert_eq!(u.to_bits(), v.to_bits(), "{u} != {v}");
            }
        }
    }

    #[test]
    fn equal_weights_is_mean() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(1);
        let a = init_params(&schema, &mut rng);
        let b = init_params(&schema, &mut rng);
        let avg = weighted_average(&[(5, a.clone()), (5, b.clone())]).unwrap();
        for i in 0..avg.tensors.len() {
            for j in 0..avg.tensors[i].data.len() {
                let want = 0.5 * (a.tensors[i].data[j] + b.tensors[i].data[j]);
                assert!((avg.tensors[i].data[j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn single_update_is_identity() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(2);
        let a = init_params(&schema, &mut rng);
        let avg = weighted_average(&[(100, a.clone())]).unwrap();
        assert!(avg.l2_distance(&a) < 1e-6);
    }

    #[test]
    fn weights_proportional_to_samples() {
        forall(32, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let a = init_params(&schema, &mut prng);
            let b = init_params(&schema, &mut prng);
            let na = 1 + rng.below(1000) as u64;
            let nb = 1 + rng.below(1000) as u64;
            let avg = weighted_average(&[(na, a.clone()), (nb, b.clone())]).unwrap();
            let wa = na as f32 / (na + nb) as f32;
            let v = avg.tensors[0].data[0];
            let want = wa * a.tensors[0].data[0] + (1.0 - wa) * b.tensors[0].data[0];
            assert!((v - want).abs() < 1e-5);
        });
    }

    #[test]
    fn convexity_bounds() {
        // aggregate lies inside the coordinate-wise envelope of the inputs
        forall(16, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let sets: Vec<(u64, ParamSet)> = (0..4)
                .map(|_| (1 + rng.below(50) as u64, init_params(&schema, &mut prng)))
                .collect();
            let avg = weighted_average(&sets).unwrap();
            for i in 0..avg.tensors.len() {
                for j in 0..avg.tensors[i].data.len() {
                    let lo = sets
                        .iter()
                        .map(|(_, p)| p.tensors[i].data[j])
                        .fold(f32::INFINITY, f32::min);
                    let hi = sets
                        .iter()
                        .map(|(_, p)| p.tensors[i].data[j])
                        .fold(f32::NEG_INFINITY, f32::max);
                    let v = avg.tensors[i].data[j];
                    assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
                }
            }
        });
    }

    #[test]
    fn rejects_degenerate_input() {
        assert!(weighted_average(&[]).is_err());
        let schema = toy_schema();
        let mut rng = Pcg::seeded(3);
        let a = init_params(&schema, &mut rng);
        assert!(weighted_average(&[(0, a)]).is_err());
        assert!(Aggregator::for_schema(&schema, 0).is_err());
    }

    #[test]
    fn streaming_matches_batch_bitwise_over_random_fleets() {
        forall(64, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let k = 1 + rng.below(12) as usize;
            let fleet: Vec<(u64, ParamSet)> = (0..k)
                .map(|_| (1 + rng.below(5_000) as u64, init_params(&schema, &mut prng)))
                .collect();
            let total: u64 = fleet.iter().map(|(n, _)| *n).sum();

            let mut agg = Aggregator::for_schema(&schema, total).unwrap();
            for (n, p) in &fleet {
                agg.fold(*n, p).unwrap();
            }
            let streamed = agg.finish().unwrap();

            let reference = batch_reference(&fleet);
            assert_bitwise_eq(&streamed, &reference);
            let wrapped = weighted_average(&fleet).unwrap();
            assert_bitwise_eq(&wrapped, &reference);
        });
    }

    #[test]
    fn streaming_memory_is_one_model_at_512_clients() {
        // O(model) acceptance check: fold 512 clients one at a time, each
        // update generated and dropped inside the loop; the accumulator
        // footprint never grows past a single model.
        let schema = toy_schema();
        let n_clients = 512usize;
        let per_client = 37u64;
        let total = per_client * n_clients as u64;
        let model_elems = schema.param_count();

        let mut agg = Aggregator::for_schema(&schema, total).unwrap();
        for cid in 0..n_clients {
            let mut prng = Pcg::new(0xA66, cid as u64);
            let update = init_params(&schema, &mut prng);
            agg.fold(per_client, &update).unwrap();
            assert_eq!(agg.accumulator_elems(), model_elems, "after client {cid}");
        }
        assert_eq!(agg.folded(), n_clients);
        let streamed = agg.finish().unwrap();

        // regenerate the same fleet and compare bitwise against the
        // pre-refactor batch implementation
        let fleet: Vec<(u64, ParamSet)> = (0..n_clients)
            .map(|cid| {
                let mut prng = Pcg::new(0xA66, cid as u64);
                (per_client, init_params(&schema, &mut prng))
            })
            .collect();
        assert_bitwise_eq(&streamed, &batch_reference(&fleet));
    }

    #[test]
    fn finish_requires_exact_sample_total() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(4);
        let a = init_params(&schema, &mut rng);
        // short: folded < total
        let mut agg = Aggregator::for_schema(&schema, 100).unwrap();
        agg.fold(60, &a).unwrap();
        assert!(agg.finish().is_err());
        // over: folded > total
        let mut agg = Aggregator::for_schema(&schema, 50).unwrap();
        agg.fold(60, &a).unwrap();
        assert!(agg.finish().is_err());
        // empty fold
        let agg = Aggregator::for_schema(&schema, 10).unwrap();
        assert!(agg.finish().is_err());
    }

    #[test]
    fn fold_rejects_shape_mismatch() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(5);
        let good = init_params(&schema, &mut rng);
        let mut agg = Aggregator::for_schema(&schema, 10).unwrap();
        let mut missing = good.clone();
        missing.tensors.pop();
        assert!(agg.fold(5, &missing).is_err());
        let mut resized = good.clone();
        resized.tensors[0].data.push(0.0);
        assert!(agg.fold(5, &resized).is_err());
        agg.fold(10, &good).unwrap();
        agg.finish().unwrap();
    }

    #[test]
    fn finish_rejects_non_finite() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(6);
        let mut a = init_params(&schema, &mut rng);
        a.tensors[0].data[0] = f32::NAN;
        let mut agg = Aggregator::for_schema(&schema, 10).unwrap();
        agg.fold(10, &a).unwrap();
        assert!(agg.finish().is_err());
    }
}
