//! Server aggregation: the FedAvg data-size-weighted average (eq. 2 /
//! Algorithm 2 server step), applied to rebuilt client models.

use anyhow::{bail, Result};

use crate::model::ParamSet;

/// theta_{r+1} = sum_k (|D_k| / sum |D_k|) * theta_k.
pub fn weighted_average(updates: &[(u64, ParamSet)]) -> Result<ParamSet> {
    if updates.is_empty() {
        bail!("no updates to aggregate");
    }
    let total: u64 = updates.iter().map(|(n, _)| *n).sum();
    if total == 0 {
        bail!("all updates report zero samples");
    }
    let mut acc = updates[0].1.clone();
    acc.scale(0.0);
    for (n, p) in updates {
        if p.tensors.len() != acc.tensors.len() {
            bail!("update tensor count mismatch");
        }
        acc.axpy((*n as f64 / total as f64) as f32, p);
    }
    if !acc.is_finite() {
        bail!("aggregated model contains non-finite values");
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::toy_schema;
    use crate::model::init_params;
    use crate::util::proptest::forall;
    use crate::util::rng::Pcg;

    #[test]
    fn equal_weights_is_mean() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(1);
        let a = init_params(&schema, &mut rng);
        let b = init_params(&schema, &mut rng);
        let avg = weighted_average(&[(5, a.clone()), (5, b.clone())]).unwrap();
        for i in 0..avg.tensors.len() {
            for j in 0..avg.tensors[i].data.len() {
                let want = 0.5 * (a.tensors[i].data[j] + b.tensors[i].data[j]);
                assert!((avg.tensors[i].data[j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn single_update_is_identity() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(2);
        let a = init_params(&schema, &mut rng);
        let avg = weighted_average(&[(100, a.clone())]).unwrap();
        assert!(avg.l2_distance(&a) < 1e-6);
    }

    #[test]
    fn weights_proportional_to_samples() {
        forall(32, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let a = init_params(&schema, &mut prng);
            let b = init_params(&schema, &mut prng);
            let na = 1 + rng.below(1000) as u64;
            let nb = 1 + rng.below(1000) as u64;
            let avg = weighted_average(&[(na, a.clone()), (nb, b.clone())]).unwrap();
            let wa = na as f32 / (na + nb) as f32;
            let v = avg.tensors[0].data[0];
            let want = wa * a.tensors[0].data[0] + (1.0 - wa) * b.tensors[0].data[0];
            assert!((v - want).abs() < 1e-5);
        });
    }

    #[test]
    fn convexity_bounds() {
        // aggregate lies inside the coordinate-wise envelope of the inputs
        forall(16, |rng| {
            let schema = toy_schema();
            let mut prng = Pcg::seeded(rng.next_u64());
            let sets: Vec<(u64, ParamSet)> = (0..4)
                .map(|_| (1 + rng.below(50) as u64, init_params(&schema, &mut prng)))
                .collect();
            let avg = weighted_average(&sets).unwrap();
            for i in 0..avg.tensors.len() {
                for j in 0..avg.tensors[i].data.len() {
                    let lo = sets
                        .iter()
                        .map(|(_, p)| p.tensors[i].data[j])
                        .fold(f32::INFINITY, f32::min);
                    let hi = sets
                        .iter()
                        .map(|(_, p)| p.tensors[i].data[j])
                        .fold(f32::NEG_INFINITY, f32::max);
                    let v = avg.tensors[i].data[j];
                    assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
                }
            }
        });
    }

    #[test]
    fn rejects_degenerate_input() {
        assert!(weighted_average(&[]).is_err());
        let schema = toy_schema();
        let mut rng = Pcg::seeded(3);
        let a = init_params(&schema, &mut rng);
        assert!(weighted_average(&[(0, a)]).is_err());
    }
}
