//! The round-based orchestrator: Algorithm 2 (T-FedAvg) plus the FedAvg,
//! Baseline, and TTQ comparison loops.
//!
//! Federated rounds are driven through a [`Transport`]: every payload is
//! framed, checksummed, and counted at the wire (`transport::LinkStats`),
//! so the Table-IV numbers are measured, not estimated. The default
//! transport is the in-process `Loopback`; `tfed serve` swaps in `Tcp` and
//! the same driver runs a real multi-process federation. Selected clients
//! are dispatched concurrently by a worker-thread pool; results are
//! aggregated in selection order and client RNGs are server-derived, so
//! runs are bit-for-bit reproducible at any worker count, on any
//! transport. `Orchestrator::with_sim` swaps in the virtual-time
//! `sim::SimTransport` and a lazily-profiled registered population, so
//! million-client fleets run in seconds of wall time (DESIGN.md §9).

use anyhow::{anyhow, bail, Result};

use crate::comms::{
    pack_ternary, rebuild_update, CodedGlobal, DenseGlobal, Message, TernaryGlobal,
};
use crate::compress::{self, CodecSpec};
use crate::config::{ExperimentConfig, Protocol, Task};
use crate::coordinator::aggregation::Aggregator;
use crate::coordinator::availability::{AvailabilityModel, REAL_STRAGGLE_CAP_MS};
use crate::coordinator::backend::{Backend, TrainMode};
use crate::coordinator::client::{ClientRuntime, ShardData};
use crate::coordinator::selection::{apply_dropout, select_clients, select_cohort};
use crate::sim::{FleetModel, SimSpec, SimTransport};
use crate::data::partition::{partition, PartitionSpec};
use crate::data::synth::SynthSpec;
use crate::eval::{RoundRecord, RunMetrics};
use crate::model::{init_params, ModelSchema, ParamSet};
use crate::obs::{metrics as obs_metrics, trace};
use crate::quant;
use crate::transport::{encode_data_frame, LinkStats, Loopback, RoundAssign, Transport};
use crate::util::parallel::parallel_map_indexed;
use crate::util::rng::Pcg;
use crate::util::timer::Stopwatch;
use crate::{debug, info};

/// Failure-injection knob (robustness tests): probability that a selected
/// client drops out of the round after selection.
///
/// The seed's single-knob predecessor of
/// [`AvailabilityModel`](crate::coordinator::availability::AvailabilityModel);
/// kept as the simple entry point. The probability is validated (in
/// `[0, 1]`, not NaN) when the spec is converted into an availability
/// model — i.e. by every orchestrator constructor — with a typed
/// [`AvailabilityError`](crate::coordinator::availability::AvailabilityError).
#[derive(Clone, Debug, Default)]
pub struct FaultSpec {
    pub client_dropout: f64,
}

impl FaultSpec {
    /// Validating constructor: rejects NaN and out-of-range probabilities
    /// up front instead of at orchestrator construction.
    pub fn new(
        client_dropout: f64,
    ) -> Result<Self, crate::coordinator::availability::AvailabilityError> {
        let spec = FaultSpec { client_dropout };
        AvailabilityModel::try_from(spec.clone())?;
        Ok(spec)
    }
}

/// Synthesize the datasets and compute the client partition (indices only,
/// no feature copies). Deterministic in `cfg` — every process rebuilds the
/// same split.
fn synth_partition(
    cfg: &ExperimentConfig,
    input_dim: usize,
) -> Result<(crate::data::synth::Dataset, crate::data::synth::Dataset, crate::data::partition::Partition)> {
    let spec = match cfg.task {
        Task::MnistLike => SynthSpec::mnist_like(cfg.train_samples, cfg.test_samples, cfg.seed),
        Task::CifarLike => SynthSpec::cifar_like(cfg.train_samples, cfg.test_samples, cfg.seed),
    };
    let (train, test) = spec.generate();
    if train.dim != input_dim {
        bail!("dataset dim {} != model input {}", train.dim, input_dim);
    }
    let pspec = PartitionSpec {
        n_clients: cfg.n_clients,
        nc: cfg.nc,
        beta: cfg.beta,
        alpha: cfg.dirichlet_alpha,
        seed: cfg.seed ^ 0x51AB,
    };
    let part = partition(&train, &pspec)?;
    Ok((train, test, part))
}

/// Materialize every client shard plus the held-out test set (in-process
/// federations, where all clients live in this address space).
pub fn materialize_data(
    cfg: &ExperimentConfig,
    input_dim: usize,
) -> Result<(Vec<ShardData>, ShardData)> {
    let (train, test, part) = synth_partition(cfg, input_dim)?;
    let shards: Vec<ShardData> = part
        .shards
        .iter()
        .map(|s| ShardData::from_dataset(&train, &s.indices))
        .collect();
    Ok((shards, ShardData::whole(&test)))
}

/// Materialize exactly one client's shard — what a remote `tfed client`
/// process needs. Avoids copying the other N-1 shards and the test set.
pub fn materialize_shard(
    cfg: &ExperimentConfig,
    input_dim: usize,
    client_id: usize,
) -> Result<ShardData> {
    let (train, _test, part) = synth_partition(cfg, input_dim)?;
    let shard = part
        .shards
        .get(client_id)
        .ok_or_else(|| anyhow!("client id {client_id} out of range"))?;
    Ok(ShardData::from_dataset(&train, &shard.indices))
}

/// Round-driver worker threads: `TFED_WORKERS` env override, else one per
/// core capped at 8 (client work is compute-bound; more adds no overlap).
fn default_workers() -> usize {
    if let Ok(v) = std::env::var("TFED_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// A virtual registered population (sim runs only): each round samples a
/// `cohort` of registered ids from `0..registered`; registered client `r`
/// trains on data shard `r % n_clients`.
#[derive(Clone, Copy, Debug)]
struct Population {
    registered: usize,
    cohort: usize,
}

/// A fully-initialized experiment ready to run round-by-round.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::config::{ExperimentConfig, Protocol, Task};
/// use tfed::coordinator::backend::make_backend;
/// use tfed::coordinator::server::Orchestrator;
///
/// let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 42);
/// cfg.n_clients = 4;
/// cfg.rounds = 2;
/// cfg.train_samples = 400;
/// cfg.test_samples = 100;
/// cfg.native_backend = true; // pure-Rust backend, no artifacts needed
/// let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
/// let mut orch = Orchestrator::new(cfg, backend.as_ref()).unwrap();
/// orch.run().unwrap();
/// assert!(orch.metrics.final_acc() > 0.0);
/// ```
pub struct Orchestrator<'a> {
    pub cfg: ExperimentConfig,
    backend: &'a dyn Backend,
    /// the links to the client fleet (loopback unless given via
    /// `with_transport`); centralized protocols never touch it
    transport: Box<dyn Transport + 'a>,
    workers: usize,
    /// local shards, retained only for the centralized protocols (the
    /// federated ones live inside the transport's client runtimes)
    shards: Vec<ShardData>,
    shard_sizes: Vec<usize>,
    test: ShardData,
    global: ParamSet,
    /// TTQ factor state carried across rounds (wp || wn)
    ttq_factors: Vec<f32>,
    /// mean trained w^q of the previous round — broadcast as the clients'
    /// next w^q init (Algorithm 2's "initialize w^q", our reading)
    last_wq_mean: Vec<f32>,
    rng: Pcg,
    availability: AvailabilityModel,
    /// virtual registered population (None = every client is real and
    /// selection runs over `0..n_clients`, the historical behavior)
    population: Option<Population>,
    /// cumulative transport stats at the last round boundary
    stats_mark: LinkStats,
    /// obs trace lane (scenario grid-cell index; 0 for standalone runs) —
    /// keeps spans from parallel `--jobs` cells in separate trace groups
    obs_lane: u32,
    /// grid-cell label stamped on telemetry records ("" standalone)
    obs_cell: String,
    pub metrics: RunMetrics,
}

impl<'a> Orchestrator<'a> {
    pub fn new(cfg: ExperimentConfig, backend: &'a dyn Backend) -> Result<Self> {
        Self::with_faults(cfg, backend, FaultSpec::default())
    }

    /// Default setup: clients attached over an in-process `Loopback`
    /// transport (full frame codec, identical accounting to TCP).
    pub fn with_faults(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        faults: FaultSpec,
    ) -> Result<Self> {
        let availability = AvailabilityModel::try_from(faults)?;
        Self::build(cfg, backend, availability, None, None)
    }

    /// Full availability control: phased dropout schedules and straggler
    /// delay traces (the scenario engine's entry point).
    pub fn with_availability(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
    ) -> Result<Self> {
        Self::build(cfg, backend, availability, None, None)
    }

    /// Virtual-time fleet simulation: the in-process fleet is wrapped in
    /// a [`SimTransport`], each round samples `sim.cohort` clients from a
    /// registered population of `sim.registered` (mapped onto the
    /// `n_clients` data shards), and availability stragglers become
    /// virtual delays. `RoundRecord::sim_secs` carries the simulated
    /// round completion time; everything else — payload bytes, training,
    /// `LinkStats` — is byte-identical to a loopback run of the same
    /// cohort. See DESIGN.md §9.
    pub fn with_sim(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
        sim: SimSpec,
    ) -> Result<Self> {
        if cfg.protocol.is_centralized() {
            bail!("the fleet simulator requires a federated protocol");
        }
        sim.validate_for(cfg.n_clients)?;
        Self::build(cfg, backend, availability, None, Some(sim))
    }

    /// Attach an external transport (e.g. `TcpTransport` with remote
    /// clients). The backend is still used server-side for evaluation and
    /// downstream re-quantization.
    pub fn with_transport(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
        transport: Box<dyn Transport + 'a>,
    ) -> Result<Self> {
        if cfg.protocol.is_centralized() {
            bail!("centralized protocols do not use a transport");
        }
        if transport.n_clients() < cfg.n_clients {
            bail!(
                "transport reaches {} clients, config wants {}",
                transport.n_clients(),
                cfg.n_clients
            );
        }
        Self::build(cfg, backend, availability, Some(transport), None)
    }

    fn build(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
        transport: Option<Box<dyn Transport + 'a>>,
        sim: Option<SimSpec>,
    ) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Pcg::new(cfg.seed, 0xC0 + cfg.protocol.weight_bits() as u64);

        let input_dim = backend.schema().input_dim;
        let (mut shards, shard_sizes, test) = if transport.is_some() {
            // remote clients materialize their own shards; the server only
            // needs the split sizes and the held-out test set
            let (_train, test, part) = synth_partition(&cfg, input_dim)?;
            let sizes: Vec<usize> = part.shards.iter().map(|s| s.indices.len()).collect();
            (Vec::new(), sizes, ShardData::whole(&test))
        } else {
            let (shards, test) = materialize_data(&cfg, input_dim)?;
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            (shards, sizes, test)
        };

        let transport: Box<dyn Transport + 'a> = match transport {
            Some(t) => t,
            None if cfg.protocol.is_centralized() => Box::new(Loopback::new(Vec::new())),
            None => {
                let runtimes: Vec<ClientRuntime<'a>> = shards
                    .drain(..)
                    .enumerate()
                    .map(|(cid, shard)| ClientRuntime {
                        client_id: cid as u32,
                        backend,
                        shard,
                        local_epochs: cfg.local_epochs,
                        lr: cfg.lr,
                        codec: cfg.codec,
                    })
                    .collect();
                let fleet = Loopback::new(runtimes);
                match &sim {
                    Some(spec) => Box::new(SimTransport::new(
                        fleet,
                        FleetModel::from_spec(spec),
                        cfg.local_epochs,
                        availability.straggler_prob(),
                        availability.straggler_delay_ms(),
                    )),
                    None => Box::new(fleet),
                }
            }
        };
        let population = sim
            .as_ref()
            .map(|s| Population { registered: s.registered, cohort: s.cohort });

        let global = init_params(backend.schema(), &mut rng);
        let nq = backend.schema().num_quantized();
        let metrics = RunMetrics::new(cfg.summary());
        info!("experiment: {}", cfg.summary());
        Ok(Orchestrator {
            cfg,
            backend,
            transport,
            workers: default_workers(),
            shards,
            shard_sizes,
            test,
            global,
            ttq_factors: vec![backend.wq_init(); 2 * nq],
            last_wq_mean: vec![backend.wq_init(); nq],
            rng,
            availability,
            population,
            stats_mark: LinkStats::default(),
            obs_lane: 0,
            obs_cell: String::new(),
            metrics,
        })
    }

    /// The data shard (and transport link) behind a selection id: the id
    /// itself for real fleets; `id % n_clients` for a simulated
    /// registered population (registered clients share the data
    /// substrate but carry their own RNG, timing, and device profile).
    fn shard_of(&self, id: usize) -> usize {
        if self.population.is_some() {
            id % self.cfg.n_clients
        } else {
            id
        }
    }

    /// Override the round-driver worker-thread count (default: one per
    /// core, capped at 8; `TFED_WORKERS` env). Results are identical at
    /// any setting — only wall time changes.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    /// Assign this run's obs trace lane (the scenario runner passes the
    /// grid-cell index). Purely an observability grouping key — results
    /// are identical at any lane.
    pub fn set_obs_lane(&mut self, lane: u32) {
        self.obs_lane = lane;
    }

    /// Label telemetry records with this run's grid-cell identity (the
    /// scenario runner passes `cell.label()`). Observability metadata
    /// only — results are identical with any label.
    pub fn set_obs_cell(&mut self, label: &str) {
        self.obs_cell = label.to_string();
    }

    /// Current dense global model (server state).
    pub fn global(&self) -> &ParamSet {
        &self.global
    }

    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shard_sizes.clone()
    }

    /// Cumulative transport-layer stats over all links.
    pub fn transport_stats(&self) -> LinkStats {
        self.transport.stats()
    }

    /// Per-link transport stats, indexed by client id.
    pub fn link_stats(&self) -> Vec<LinkStats> {
        self.transport.link_stats()
    }

    /// Notify remote clients that the experiment is over (no-op for the
    /// loopback transport).
    pub fn shutdown_transport(&self) -> Result<()> {
        self.transport.shutdown()
    }

    /// The ternary broadcast model a T-FedAvg client would download next
    /// round (Algorithm 2 downstream payload materialized, bare {-1,0,+1}).
    pub fn broadcast_model(&self) -> ParamSet {
        let qidx = self.backend.schema().quantized_indices();
        let patterns =
            quant::requantize_paramset(&self.global, &qidx, self.backend.server_delta());
        quant::rebuild_from_ternary(&self.global, &qidx, &patterns)
    }

    /// The 2-bit T-FedAvg *inference* model: the broadcast pattern scaled
    /// per layer by the eq.-20 optimal factor (see quant::requantize_scaled
    /// — client training is invariant to this rescaling, so it carries no
    /// extra protocol bytes beyond one f32 per layer).
    pub fn ternary_inference_model(&self) -> ParamSet {
        let qidx = self.backend.schema().quantized_indices();
        let mut out = self.global.clone();
        for &i in &qidx {
            let (it, wq) = quant::requantize_scaled(
                &self.global.tensors[i].data,
                self.backend.server_delta(),
            );
            for (dst, &s) in out.tensors[i].data.iter_mut().zip(&it) {
                *dst = wq * s as f32;
            }
        }
        out
    }

    /// Run one communication round. Returns the round record.
    pub fn round(&mut self, round: usize) -> Result<RoundRecord> {
        let sw = Stopwatch::start();
        trace::set_context(self.obs_lane, round as u32, trace::NO_CLIENT);
        let selected = {
            crate::obs_span!("round.select");
            let selected = match self.population {
                None => {
                    let k = self.cfg.selected_per_round();
                    select_clients(self.cfg.n_clients, k, &mut self.rng)
                }
                Some(p) => select_cohort(p.registered, p.cohort, &mut self.rng),
            };
            let dropout = self.availability.dropout_for_round(round);
            apply_dropout(&selected, dropout, &mut self.rng)
        };
        if crate::obs::enabled() {
            obs_metrics::counter("tfed_rounds_total").inc();
            obs_metrics::counter("tfed_clients_selected_total").add(selected.len() as u64);
        }
        // under the simulator, straggler delays are drawn virtually by
        // the transport (per registered client, per round) — the main
        // RNG stream is untouched and nothing ever sleeps
        let delays = if self.population.is_some() {
            vec![0; selected.len()]
        } else {
            self.straggler_delays(&selected)
        };

        let (train_loss, factors) = match self.cfg.protocol {
            Protocol::TFedAvg | Protocol::FedAvg => {
                self.round_federated(round, &selected, &delays)?
            }
            Protocol::Baseline => self.round_centralized(round, TrainMode::Fp)?,
            Protocol::Ttq => self.round_centralized(round, TrainMode::Ttq)?,
        };

        // a sequential dispatch runs exchanges on this thread and leaves
        // the last client's span context behind; restore the server lane
        trace::set_context(self.obs_lane, round as u32, trace::NO_CLIENT);

        // communication cost measured at the frame layer
        let stats = self.transport.stats();
        let delta = stats.since(&self.stats_mark);
        self.stats_mark = stats;

        // round boundary: a virtual-time transport drains its event
        // queue here and advances the simulated clock
        let virtual_time = self.transport.end_round(round as u32);

        let evaluated = round % self.cfg.eval_every == 0 || round == self.cfg.rounds;
        let (test_loss, test_acc) = if evaluated {
            crate::obs_span!("round.eval");
            let eval_model = match self.cfg.protocol {
                // the paper reports the accuracy of the *quantized* model
                Protocol::TFedAvg => self.ternary_inference_model(),
                Protocol::Ttq => self.ttq_inference_model(),
                _ => self.global.clone(),
            };
            self.backend.evaluate(&eval_model, &self.test)?
        } else {
            (f32::NAN, f32::NAN)
        };
        if evaluated && crate::obs::enabled() {
            obs_metrics::gauge("tfed_eval_acc").set(test_acc as f64);
            obs_metrics::gauge("tfed_eval_loss").set(test_loss as f64);
        }

        let rec = RoundRecord {
            round,
            train_loss,
            test_acc,
            test_loss,
            up_bytes: delta.up_bytes,
            down_bytes: delta.down_bytes,
            up_frames: delta.up_frames,
            down_frames: delta.down_frames,
            wall_secs: sw.secs(),
            sim_secs: virtual_time.map_or(0.0, |t| t.round_secs),
            straggler_delay_ms: virtual_time
                .map_or_else(|| delays.iter().sum(), |t| t.straggler_ms),
            selected,
            factors,
            evaluated,
        };
        if evaluated {
            info!(
                "round {round:>4}: loss={train_loss:.4} acc={test_acc:.4} up={}B down={}B",
                rec.up_bytes, rec.down_bytes
            );
        }
        self.metrics.push(rec.clone());
        // learning-dynamics telemetry (one relaxed load when off; when
        // on, reads server state only — no RNG, no bundle changes)
        if crate::obs::telemetry::enabled() {
            self.record_telemetry(&rec);
        }
        Ok(rec)
    }

    /// Build and store this round's learning-dynamics record
    /// (DESIGN.md §12). The dense fp32 `global` is the shadow
    /// accumulator: quantization stats compare it against the protocol's
    /// quantized projection of the same state. Dense protocols record
    /// zeros (there is no projection to diverge from).
    fn record_telemetry(&self, rec: &RoundRecord) {
        use crate::obs::telemetry;
        let qidx = self.backend.schema().quantized_indices();
        let proj = match self.cfg.protocol {
            Protocol::TFedAvg => Some(self.ternary_inference_model()),
            Protocol::Ttq => Some(self.ttq_inference_model()),
            Protocol::FedAvg | Protocol::Baseline => None,
        };
        let (layer_zero_fraction, sparsity, unbias_residual, divergence, rel) =
            match &proj {
                Some(p) => {
                    let (per_layer, overall) = telemetry::zero_fractions(p, &qidx);
                    let resid = telemetry::unbias_residual(&self.global, p, &qidx);
                    let (div, rel) = telemetry::weight_divergence(&self.global, p, &qidx);
                    (per_layer, overall, resid, div, rel)
                }
                None => (vec![0.0; qidx.len()], 0.0, 0.0, 0.0, 0.0),
            };
        telemetry::record(telemetry::TelemetryRecord {
            lane: self.obs_lane,
            round: rec.round as u64,
            cell: self.obs_cell.clone(),
            protocol: self.cfg.protocol.name().to_string(),
            train_loss: rec.train_loss as f64,
            test_acc: rec.test_acc as f64,
            test_loss: rec.test_loss as f64,
            evaluated: rec.evaluated,
            factors: rec.factors.iter().map(|&f| f as f64).collect(),
            layer_zero_fraction,
            sparsity,
            unbias_residual,
            weight_divergence: divergence,
            rel_divergence: rel,
            cum_up_bytes: self.metrics.total_up_bytes(),
            cum_down_bytes: self.metrics.total_down_bytes(),
            sim_secs: self.metrics.total_sim_secs(),
        });
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for r in 1..=self.cfg.rounds {
            self.round(r)?;
        }
        Ok(())
    }

    // -- federated rounds (FedAvg + T-FedAvg, Algorithm 2) -------------------

    /// Per-slot reply delays for this round's survivors (milliseconds;
    /// 0 = prompt). Draws from the round RNG *only* when stragglers are
    /// configured, so the default path's RNG stream is untouched.
    fn straggler_delays(&mut self, selected: &[usize]) -> Vec<u64> {
        if !self.availability.has_stragglers() {
            return vec![0; selected.len()];
        }
        let p = self.availability.straggler_prob();
        let d = self.availability.straggler_delay_ms();
        selected
            .iter()
            .map(|_| if self.rng.next_f64() < p { d } else { 0 })
            .collect()
    }

    fn round_federated(
        &mut self,
        round: usize,
        selected: &[usize],
        delays: &[u64],
    ) -> Result<(f32, Vec<f32>)> {
        let schema = self.backend.schema().clone();
        let qidx = schema.quantized_indices();
        let shapes: Vec<Vec<usize>> =
            schema.params.iter().map(|p| p.shape.clone()).collect();

        let down_msg = {
            crate::obs_span!("round.broadcast");
            match (self.cfg.protocol, self.cfg.codec) {
                (Protocol::TFedAvg, _) => {
                    Message::TernaryGlobal(self.ternary_broadcast(round, &schema))
                }
                (Protocol::FedAvg, CodecSpec::Dense) => Message::DenseGlobal(DenseGlobal {
                    round: round as u32,
                    tensors: self.global.tensors.iter().map(|t| t.data.clone()).collect(),
                }),
                (Protocol::FedAvg, spec) => {
                    // registry codec: compress the broadcast once,
                    // pre-dispatch. Stochastic codecs draw from a
                    // round-forked generator — one fork per round, before
                    // the per-client forks, so the sequence is identical
                    // on every transport / worker count.
                    let codec = compress::build(spec)?;
                    let mut crng = self.rng.fork(0xC0DE0 + round as u64);
                    Message::CodedGlobal(CodedGlobal {
                        round: round as u32,
                        update: compress::compress(codec.as_ref(), &self.global, &mut crng)?,
                    })
                }
                _ => unreachable!("centralized protocols never reach round_federated"),
            }
        };

        // derive the per-client RNGs up front, in selection order — the
        // same `fork` draw sequence the sequential loop made, so runs
        // reproduce bit-for-bit at any worker count or transport
        let assigns: Vec<RoundAssign> = selected
            .iter()
            .map(|&cid| {
                let tag = cid as u64 + round as u64 * 7919;
                let (rng_seed, rng_stream) = self.rng.fork_params(tag);
                RoundAssign {
                    round: round as u32,
                    client_id: cid as u32,
                    rng_seed,
                    rng_stream,
                    codec: self.cfg.codec,
                }
            })
            .collect();

        let replies = self.dispatch(selected, &assigns, &down_msg, delays)?;
        // single-worker dispatch runs client exchanges on this thread;
        // take the span context back before server-side aggregation
        trace::set_context(self.obs_lane, round as u32, trace::NO_CLIENT);

        // server side: decode + rebuild + fold, in selection order. The
        // streaming Aggregator applies the final eq.-2 weight as each
        // update arrives — the sample total is known up front from the
        // server's own shard sizes — so peak memory is one model, not
        // `clients × model`, and the result is bit-identical to the old
        // batch average (same float-op sequence; see DESIGN.md §8).
        crate::obs_span!("round.aggregate");
        let expected_total: u64 =
            selected.iter().map(|&cid| self.shard_sizes[self.shard_of(cid)] as u64).sum();
        let mut agg = Aggregator::for_schema(&schema, expected_total)?;
        let mut loss_acc = 0f64;
        let mut wq_mean = vec![0f32; qidx.len()];
        for (slot, reply) in replies.into_iter().enumerate() {
            let expect_n = self.shard_sizes[self.shard_of(selected[slot])] as u64;
            let (num_samples, rebuilt) = match (self.cfg.protocol, reply) {
                (Protocol::TFedAvg, Message::TernaryUpdate(u)) => {
                    if u.layers.len() != qidx.len() {
                        bail!(
                            "client {}: {} quantized layers, model has {}",
                            selected[slot],
                            u.layers.len(),
                            qidx.len()
                        );
                    }
                    for (k, l) in u.layers.iter().enumerate() {
                        wq_mean[k] += l.wq / selected.len() as f32;
                    }
                    loss_acc += u.train_loss as f64;
                    (u.num_samples, rebuild_update(&u, &shapes)?)
                }
                (Protocol::FedAvg, Message::DenseUpdate(u))
                    if self.cfg.codec == CodecSpec::Dense =>
                {
                    loss_acc += u.train_loss as f64;
                    let mut p = ParamSet::zeros(&schema);
                    if u.tensors.len() != p.tensors.len() {
                        bail!(
                            "client {}: update has {} tensors, model wants {}",
                            selected[slot],
                            u.tensors.len(),
                            p.tensors.len()
                        );
                    }
                    for ((t, data), shape) in
                        p.tensors.iter_mut().zip(u.tensors).zip(&shapes)
                    {
                        if t.data.len() != data.len() {
                            bail!("tensor size mismatch for shape {shape:?}");
                        }
                        t.data = data;
                    }
                    (u.num_samples, p)
                }
                (Protocol::FedAvg, Message::CodedUpdate(u))
                    if self.cfg.codec != CodecSpec::Dense =>
                {
                    if u.update.codec != self.cfg.codec {
                        bail!(
                            "client {} replied with codec {}, negotiated {}",
                            selected[slot],
                            u.update.codec.name(),
                            self.cfg.codec.name()
                        );
                    }
                    loss_acc += u.train_loss as f64;
                    let codec = compress::build(self.cfg.codec)?;
                    (u.num_samples, compress::decompress(codec.as_ref(), &u.update, &shapes)?)
                }
                (_, other) => bail!(
                    "client {} returned unexpected message kind {}",
                    selected[slot],
                    other.kind()
                ),
            };
            if num_samples != expect_n {
                bail!(
                    "client {} reported {} samples, server expected {}",
                    selected[slot],
                    num_samples,
                    expect_n
                );
            }
            agg.fold(num_samples, &rebuilt)?;
        }

        // server aggregation (eq. 2)
        let folded = agg.folded();
        self.global = agg.finish()?;
        debug!("aggregated {} updates from {} clients", folded, selected.len());
        let factors = if self.cfg.protocol == Protocol::TFedAvg {
            self.last_wq_mean = wq_mean.clone();
            wq_mean
        } else {
            vec![]
        };
        Ok(((loss_acc / selected.len().max(1) as f64) as f32, factors))
    }

    /// Algorithm 2 downstream payload: server re-quantizes the global model
    /// (fixed Delta) -> ternary patterns + fp biases + next-round w^q init.
    fn ternary_broadcast(&self, round: usize, schema: &ModelSchema) -> TernaryGlobal {
        let qidx = schema.quantized_indices();
        let patterns =
            quant::requantize_paramset(&self.global, &qidx, self.backend.server_delta());
        TernaryGlobal {
            round: round as u32,
            layers: qidx
                .iter()
                .zip(&patterns)
                .map(|(&i, p)| (i as u32, pack_ternary(p)))
                .collect(),
            fp_tensors: schema
                .params
                .iter()
                .enumerate()
                .filter(|(i, _)| !qidx.contains(i))
                .map(|(i, _)| (i as u32, self.global.tensors[i].data.clone()))
                .collect(),
            wq_init: self.last_wq_mean.clone(),
        }
    }

    /// Fan the round out over the transport with a worker pool. Results
    /// come back indexed by selection slot, so downstream aggregation
    /// order (and therefore float summation) is schedule-independent.
    /// `delays` (per slot, ms) injects straggler latency before a
    /// client's exchange — it shifts wall time only (capped, see
    /// `straggle`), never results; under the sim transport delays are
    /// virtual and `delays` is all zeros.
    fn dispatch(
        &self,
        selected: &[usize],
        assigns: &[RoundAssign],
        down: &Message,
        delays: &[u64],
    ) -> Result<Vec<Message>> {
        let n = selected.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // selection ids resolve to transport links up front (identity for
        // real fleets; shard mapping for a simulated population)
        let links: Vec<usize> = selected.iter().map(|&cid| self.shard_of(cid)).collect();
        // the broadcast is identical for every client: frame it once and
        // fan the same buffer out
        let down_wire = {
            crate::obs_span!("round.encode");
            encode_data_frame(down)?
        };
        let transport = self.transport.as_ref();
        let lane = self.obs_lane;
        let exchange = |i: usize| {
            // tag whichever thread runs this exchange with the client's
            // span context, so client-side spans group correctly
            trace::set_context(lane, assigns[i].round, assigns[i].client_id);
            straggle(delays[i]);
            transport.round_trip(links[i], &assigns[i], &down_wire)
        };
        if self.workers <= 1 {
            // fail-fast: collect() short-circuits at the first error, so
            // one bad exchange never burns the rest of the cohort's compute
            return (0..n).map(exchange).collect();
        }
        parallel_map_indexed(n, self.workers, exchange).into_iter().collect()
    }

    // -- centralized (Baseline / TTQ) ----------------------------------------

    fn round_centralized(
        &mut self,
        round: usize,
        mode: TrainMode,
    ) -> Result<(f32, Vec<f32>)> {
        let factors0 = match mode {
            TrainMode::Ttq => self.ttq_factors.clone(),
            _ => vec![],
        };
        let mut crng = self.rng.fork(round as u64);
        let out = self.backend.train_local(
            &self.global,
            mode,
            &factors0,
            &self.shards[0],
            self.cfg.local_epochs,
            self.cfg.lr,
            &mut crng,
        )?;
        self.global = out.params.clone();
        let factors = match mode {
            TrainMode::Ttq => {
                // carry the trained factors into the next round (Fig. 12/13)
                self.ttq_factors =
                    out.wp.iter().chain(out.wn.iter()).copied().collect();
                self.ttq_factors.clone()
            }
            _ => vec![],
        };
        Ok((out.mean_loss, factors))
    }

    /// Materialize the TTQ inference model: per layer, scale -> eq. 5
    /// threshold -> {+wp, 0, -wn} (Zhu et al. inference path).
    fn ttq_inference_model(&self) -> ParamSet {
        let schema = self.backend.schema();
        let qidx = schema.quantized_indices();
        let nq = qidx.len();
        let mut out = self.global.clone();
        for (k, &i) in qidx.iter().enumerate() {
            let theta_s = quant::scale(&self.global.tensors[i].data);
            let delta = quant::threshold_max(&theta_s, self.backend.t_k());
            let wp = self.ttq_factors[k];
            let wn = self.ttq_factors[nq + k];
            for (dst, &s) in out.tensors[i].data.iter_mut().zip(&theta_s) {
                *dst = if s > delta {
                    wp
                } else if s < -delta {
                    -wn
                } else {
                    0.0
                };
            }
        }
        out
    }
}

/// Injected straggler latency: block this slot's worker briefly before
/// its exchange (a slow client, as the server experiences it). The real
/// sleep is capped at [`REAL_STRAGGLE_CAP_MS`] — the configured delay is
/// an accounting/modeling quantity (`RoundRecord::straggler_delay_ms`,
/// and full virtual time under the sim transport), not a request to
/// stall the test suite for real.
fn straggle(delay_ms: u64) {
    let capped = delay_ms.min(REAL_STRAGGLE_CAP_MS);
    if capped > 0 {
        std::thread::sleep(std::time::Duration::from_millis(capped));
    }
}

/// Convenience: build an orchestrator and run it to completion.
pub fn run_experiment(
    cfg: ExperimentConfig,
    backend: &dyn Backend,
) -> Result<RunMetrics> {
    let mut orch = Orchestrator::new(cfg, backend)?;
    orch.run()?;
    Ok(orch.metrics.clone())
}
