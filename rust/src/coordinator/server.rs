//! The round-based orchestrator: Algorithm 2 (T-FedAvg) plus the FedAvg,
//! Baseline, and TTQ comparison loops.
//!
//! Every payload that would cross the network is serialized through
//! `comms::Message` and its bytes counted — the Table-IV numbers are
//! measured, not estimated. Execution is in-process and sequential (one
//! CPU core); the message boundary is the fidelity point.

use anyhow::{bail, Result};

use crate::comms::{
    dense_update, rebuild_update, ternary_update, unpack_dequantize, Message,
    TernaryGlobal,
};
use crate::config::{ExperimentConfig, Protocol, Task};
use crate::coordinator::aggregation::weighted_average;
use crate::coordinator::backend::{Backend, TrainMode};
use crate::coordinator::client::ShardData;
use crate::coordinator::selection::{apply_dropout, select_clients};
use crate::data::partition::{partition, PartitionSpec};
use crate::data::synth::SynthSpec;
use crate::metrics::{RoundRecord, RunMetrics};
use crate::model::{init_params, ParamSet};
use crate::quant;
use crate::util::rng::Pcg;
use crate::util::timer::Stopwatch;
use crate::{debug, info};

/// Failure-injection knob (robustness tests): probability that a selected
/// client drops out of the round after selection.
#[derive(Clone, Debug, Default)]
pub struct FaultSpec {
    pub client_dropout: f64,
}

/// A fully-initialized experiment ready to run round-by-round.
pub struct Orchestrator<'a> {
    pub cfg: ExperimentConfig,
    backend: &'a dyn Backend,
    shards: Vec<ShardData>,
    test: ShardData,
    global: ParamSet,
    /// TTQ factor state carried across rounds (wp || wn)
    ttq_factors: Vec<f32>,
    /// mean trained w^q of the previous round — broadcast as the clients'
    /// next w^q init (Algorithm 2's "initialize w^q", our reading)
    last_wq_mean: Vec<f32>,
    rng: Pcg,
    faults: FaultSpec,
    pub metrics: RunMetrics,
}

impl<'a> Orchestrator<'a> {
    pub fn new(cfg: ExperimentConfig, backend: &'a dyn Backend) -> Result<Self> {
        Self::with_faults(cfg, backend, FaultSpec::default())
    }

    pub fn with_faults(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        faults: FaultSpec,
    ) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Pcg::new(cfg.seed, 0xC0 + cfg.protocol.weight_bits() as u64);

        // synthesize + shard the data
        let spec = match cfg.task {
            Task::MnistLike => SynthSpec::mnist_like(cfg.train_samples, cfg.test_samples, cfg.seed),
            Task::CifarLike => SynthSpec::cifar_like(cfg.train_samples, cfg.test_samples, cfg.seed),
        };
        let (train, test) = spec.generate();
        if train.dim != backend.schema().input_dim {
            bail!(
                "dataset dim {} != model input {}",
                train.dim,
                backend.schema().input_dim
            );
        }
        let pspec = PartitionSpec {
            n_clients: cfg.n_clients,
            nc: cfg.nc,
            beta: cfg.beta,
            seed: cfg.seed ^ 0x51AB,
        };
        let part = partition(&train, &pspec)?;
        let shards: Vec<ShardData> = part
            .shards
            .iter()
            .map(|s| ShardData::from_dataset(&train, &s.indices))
            .collect();
        let test = ShardData::whole(&test);

        let global = init_params(backend.schema(), &mut rng);
        let nq = backend.schema().num_quantized();
        let metrics = RunMetrics::new(cfg.summary());
        info!("experiment: {}", cfg.summary());
        Ok(Orchestrator {
            cfg,
            backend,
            shards,
            test,
            global,
            ttq_factors: vec![backend.wq_init(); 2 * nq],
            last_wq_mean: vec![backend.wq_init(); nq],
            rng,
            faults,
            metrics,
        })
    }

    /// Current dense global model (server state).
    pub fn global(&self) -> &ParamSet {
        &self.global
    }

    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.len()).collect()
    }

    /// The ternary broadcast model a T-FedAvg client would download next
    /// round (Algorithm 2 downstream payload materialized, bare {-1,0,+1}).
    pub fn broadcast_model(&self) -> ParamSet {
        let qidx = self.backend.schema().quantized_indices();
        let patterns =
            quant::requantize_paramset(&self.global, &qidx, self.backend.server_delta());
        quant::rebuild_from_ternary(&self.global, &qidx, &patterns)
    }

    /// The 2-bit T-FedAvg *inference* model: the broadcast pattern scaled
    /// per layer by the eq.-20 optimal factor (see quant::requantize_scaled
    /// — client training is invariant to this rescaling, so it carries no
    /// extra protocol bytes beyond one f32 per layer).
    pub fn ternary_inference_model(&self) -> ParamSet {
        let qidx = self.backend.schema().quantized_indices();
        let mut out = self.global.clone();
        for &i in &qidx {
            let (it, wq) = quant::requantize_scaled(
                &self.global.tensors[i].data,
                self.backend.server_delta(),
            );
            for (dst, &s) in out.tensors[i].data.iter_mut().zip(&it) {
                *dst = wq * s as f32;
            }
        }
        out
    }

    /// Run one communication round. Returns the round record.
    pub fn round(&mut self, round: usize) -> Result<RoundRecord> {
        let sw = Stopwatch::start();
        let k = self.cfg.selected_per_round();
        let selected = select_clients(self.cfg.n_clients, k, &mut self.rng);
        let selected = apply_dropout(&selected, self.faults.client_dropout, &mut self.rng);

        let (train_loss, up, down, factors) = match self.cfg.protocol {
            Protocol::TFedAvg => self.round_tfedavg(round, &selected)?,
            Protocol::FedAvg => self.round_fedavg(round, &selected)?,
            Protocol::Baseline => self.round_centralized(round, TrainMode::Fp)?,
            Protocol::Ttq => self.round_centralized(round, TrainMode::Ttq)?,
        };

        let evaluated = round % self.cfg.eval_every == 0 || round == self.cfg.rounds;
        let (test_loss, test_acc) = if evaluated {
            let eval_model = match self.cfg.protocol {
                // the paper reports the accuracy of the *quantized* model
                Protocol::TFedAvg => self.ternary_inference_model(),
                Protocol::Ttq => self.ttq_inference_model(),
                _ => self.global.clone(),
            };
            self.backend.evaluate(&eval_model, &self.test)?
        } else {
            (f32::NAN, f32::NAN)
        };

        let rec = RoundRecord {
            round,
            train_loss,
            test_acc,
            test_loss,
            up_bytes: up,
            down_bytes: down,
            wall_secs: sw.secs(),
            selected,
            factors,
            evaluated,
        };
        if evaluated {
            info!(
                "round {round:>4}: loss={train_loss:.4} acc={test_acc:.4} up={}B down={}B",
                up, down
            );
        }
        self.metrics.push(rec.clone());
        Ok(rec)
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for r in 1..=self.cfg.rounds {
            self.round(r)?;
        }
        Ok(())
    }

    // -- T-FedAvg (Algorithm 2) --------------------------------------------
    fn round_tfedavg(
        &mut self,
        round: usize,
        selected: &[usize],
    ) -> Result<(f32, u64, u64, Vec<f32>)> {
        let schema = self.backend.schema().clone();
        let qidx = schema.quantized_indices();
        let shapes: Vec<Vec<usize>> =
            schema.params.iter().map(|p| p.shape.clone()).collect();

        // downstream: server re-quantizes the global model (fixed Delta)
        // and broadcasts ternary patterns + fp biases
        let patterns =
            quant::requantize_paramset(&self.global, &qidx, self.backend.server_delta());
        let down_msg = Message::TernaryGlobal(TernaryGlobal {
            round: round as u32,
            layers: qidx
                .iter()
                .zip(&patterns)
                .map(|(&i, p)| (i as u32, crate::comms::pack_ternary(p)))
                .collect(),
            fp_tensors: schema
                .params
                .iter()
                .enumerate()
                .filter(|(i, _)| !qidx.contains(i))
                .map(|(i, _)| (i as u32, self.global.tensors[i].data.clone()))
                .collect(),
            wq_init: self.last_wq_mean.clone(),
        });
        let down_bytes_each = down_msg.encode().len() as u64;
        let down_bytes = down_bytes_each * selected.len() as u64;

        let mut updates: Vec<(u64, ParamSet)> = Vec::with_capacity(selected.len());
        let mut up_bytes = 0u64;
        let mut loss_acc = 0f64;
        let mut wq_mean = vec![0f32; qidx.len()];
        for &cid in selected {
            // client: decode the broadcast, rebuild local latent params
            let (start, wq0) = match Message::decode(&down_msg.encode())? {
                Message::TernaryGlobal(g) => {
                    let mut p = ParamSet::zeros(&schema);
                    for (i, packed) in &g.layers {
                        let dense = unpack_dequantize(packed, 1.0)?;
                        p.tensors[*i as usize].data = dense;
                    }
                    for (i, t) in &g.fp_tensors {
                        p.tensors[*i as usize].data = t.clone();
                    }
                    (p, g.wq_init)
                }
                _ => bail!("wrong downstream message kind"),
            };
            // Algorithm 2: "initialize w^q" — seeded from the broadcast
            // (previous round's aggregated factors; see TernaryGlobal)
            let mut crng = self.rng.fork(cid as u64 + round as u64 * 7919);
            let out = self.backend.train_local(
                &start,
                TrainMode::Fttq,
                &wq0,
                &self.shards[cid],
                self.cfg.local_epochs,
                self.cfg.lr,
                &mut crng,
            )?;
            loss_acc += out.mean_loss as f64;
            // upload: ternarize the trained latent weights + trained w^q
            let (pats, deltas) = self.backend.quantize(&out.params)?;
            let upd = ternary_update(
                cid as u32,
                self.shards[cid].len() as u64,
                &qidx,
                &pats,
                &out.wq,
                &deltas,
                &out.params,
                out.mean_loss,
            );
            let encoded = Message::TernaryUpdate(upd).encode();
            up_bytes += encoded.len() as u64;
            // server: decode + rebuild dense model (wq * it)
            let upd = match Message::decode(&encoded)? {
                Message::TernaryUpdate(u) => u,
                _ => bail!("wrong upstream message kind"),
            };
            for (k, l) in upd.layers.iter().enumerate() {
                wq_mean[k] += l.wq / selected.len() as f32;
            }
            let rebuilt = rebuild_update(&upd, &shapes)?;
            updates.push((upd.num_samples, rebuilt));
        }

        // server aggregation (eq. 2)
        self.global = weighted_average(&updates)?;
        self.last_wq_mean = wq_mean.clone();
        debug!("aggregated {} ternary updates", updates.len());
        Ok((
            (loss_acc / selected.len().max(1) as f64) as f32,
            up_bytes,
            down_bytes,
            wq_mean,
        ))
    }

    // -- FedAvg --------------------------------------------------------------
    fn round_fedavg(
        &mut self,
        round: usize,
        selected: &[usize],
    ) -> Result<(f32, u64, u64, Vec<f32>)> {
        let schema = self.backend.schema().clone();
        let shapes: Vec<Vec<usize>> =
            schema.params.iter().map(|p| p.shape.clone()).collect();
        let down_msg = Message::DenseGlobal(crate::comms::DenseGlobal {
            round: round as u32,
            tensors: self.global.tensors.iter().map(|t| t.data.clone()).collect(),
        });
        let down_bytes_each = down_msg.encode().len() as u64;
        let down_bytes = down_bytes_each * selected.len() as u64;

        let mut updates = Vec::with_capacity(selected.len());
        let mut up_bytes = 0u64;
        let mut loss_acc = 0f64;
        for &cid in selected {
            let start = match Message::decode(&down_msg.encode())? {
                Message::DenseGlobal(g) => {
                    let mut p = ParamSet::zeros(&schema);
                    for (t, data) in p.tensors.iter_mut().zip(g.tensors) {
                        t.data = data;
                    }
                    p
                }
                _ => bail!("wrong downstream message kind"),
            };
            let mut crng = self.rng.fork(cid as u64 + round as u64 * 7919);
            let out = self.backend.train_local(
                &start,
                TrainMode::Fp,
                &[],
                &self.shards[cid],
                self.cfg.local_epochs,
                self.cfg.lr,
                &mut crng,
            )?;
            loss_acc += out.mean_loss as f64;
            let upd =
                dense_update(cid as u32, self.shards[cid].len() as u64, &out.params, out.mean_loss);
            let encoded = Message::DenseUpdate(upd).encode();
            up_bytes += encoded.len() as u64;
            let upd = match Message::decode(&encoded)? {
                Message::DenseUpdate(u) => u,
                _ => bail!("wrong upstream message kind"),
            };
            let mut p = ParamSet::zeros(&schema);
            for ((t, data), shape) in p.tensors.iter_mut().zip(upd.tensors).zip(&shapes) {
                if t.data.len() != data.len() {
                    bail!("tensor size mismatch for shape {shape:?}");
                }
                t.data = data;
            }
            updates.push((upd.num_samples, p));
        }
        self.global = weighted_average(&updates)?;
        Ok((
            (loss_acc / selected.len().max(1) as f64) as f32,
            up_bytes,
            down_bytes,
            vec![],
        ))
    }

    // -- centralized (Baseline / TTQ) ----------------------------------------
    fn round_centralized(
        &mut self,
        round: usize,
        mode: TrainMode,
    ) -> Result<(f32, u64, u64, Vec<f32>)> {
        let factors0 = match mode {
            TrainMode::Ttq => self.ttq_factors.clone(),
            _ => vec![],
        };
        let mut crng = self.rng.fork(round as u64);
        let out = self.backend.train_local(
            &self.global,
            mode,
            &factors0,
            &self.shards[0],
            self.cfg.local_epochs,
            self.cfg.lr,
            &mut crng,
        )?;
        self.global = out.params.clone();
        let factors = match mode {
            TrainMode::Ttq => {
                // carry the trained factors into the next round (Fig. 12/13)
                self.ttq_factors =
                    out.wp.iter().chain(out.wn.iter()).copied().collect();
                self.ttq_factors.clone()
            }
            _ => vec![],
        };
        Ok((out.mean_loss, 0, 0, factors))
    }

    /// Materialize the TTQ inference model: per layer, scale -> eq. 5
    /// threshold -> {+wp, 0, -wn} (Zhu et al. inference path).
    fn ttq_inference_model(&self) -> ParamSet {
        let schema = self.backend.schema();
        let qidx = schema.quantized_indices();
        let nq = qidx.len();
        let mut out = self.global.clone();
        for (k, &i) in qidx.iter().enumerate() {
            let theta_s = quant::scale(&self.global.tensors[i].data);
            let delta = quant::threshold_max(&theta_s, self.backend.t_k());
            let wp = self.ttq_factors[k];
            let wn = self.ttq_factors[nq + k];
            for (dst, &s) in out.tensors[i].data.iter_mut().zip(&theta_s) {
                *dst = if s > delta {
                    wp
                } else if s < -delta {
                    -wn
                } else {
                    0.0
                };
            }
        }
        out
    }
}

/// Convenience: build an orchestrator and run it to completion.
pub fn run_experiment(
    cfg: ExperimentConfig,
    backend: &dyn Backend,
) -> Result<RunMetrics> {
    let mut orch = Orchestrator::new(cfg, backend)?;
    orch.run()?;
    Ok(orch.metrics.clone())
}
