//! The round-based orchestrator: Algorithm 2 (T-FedAvg) plus the FedAvg,
//! Baseline, and TTQ comparison loops.
//!
//! Federated rounds are driven through a [`Transport`]: every payload is
//! framed, checksummed, and counted at the wire (`transport::LinkStats`),
//! so the Table-IV numbers are measured, not estimated. The default
//! transport is the in-process `Loopback`; `tfed serve` swaps in `Tcp` and
//! the same driver runs a real multi-process federation. Selected clients
//! are dispatched concurrently by a worker-thread pool; results are
//! aggregated in selection order and client RNGs are server-derived, so
//! runs are bit-for-bit reproducible at any worker count, on any
//! transport. `Orchestrator::with_sim` swaps in the virtual-time
//! `sim::SimTransport` and a lazily-profiled registered population, so
//! million-client fleets run in seconds of wall time (DESIGN.md §9).
//!
//! The server does not trust its clients: every reply is decoded and
//! validated individually, and a malformed, mislabeled, oversized, or
//! sample-count-inflated update becomes a typed [`ClientFault`] that
//! rejects *that client's* contribution — the round aggregates the
//! survivors (under the configured
//! [`AggregatorSpec`](crate::coordinator::aggregation::AggregatorSpec)
//! robust rule) instead of panicking or aborting (DESIGN.md §13).

use std::fmt;

use anyhow::{anyhow, bail, Result};

use crate::comms::{
    pack_ternary, rebuild_update, CodedGlobal, DenseGlobal, Message, TernaryGlobal,
};
use crate::compress::{self, CodecSpec};
use crate::config::{ExperimentConfig, Protocol, Task};
use crate::coordinator::adversary::AdversaryModel;
use crate::coordinator::aggregation::{robust_aggregate, Aggregator, AggregatorSpec};
use crate::coordinator::availability::{
    AvailabilityModel, ObservedDropout, REAL_STRAGGLE_CAP_MS,
};
use crate::coordinator::backend::{Backend, TrainMode};
use crate::coordinator::client::{ClientAdversary, ClientRuntime, ShardData};
use crate::coordinator::selection::{apply_dropout, select_clients, select_cohort};
use crate::sim::{FleetModel, SimSpec, SimTransport};
use crate::data::partition::{partition, PartitionSpec};
use crate::data::synth::SynthSpec;
use crate::eval::{RoundRecord, RunMetrics};
use crate::model::{init_params, ModelSchema, ParamSet};
use crate::obs::{metrics as obs_metrics, trace};
use crate::quant;
use crate::transport::{encode_data_frame, LinkStats, Loopback, RoundAssign, Transport};
use crate::util::parallel::parallel_map_indexed;
use crate::util::rng::Pcg;
use crate::util::timer::Stopwatch;
use crate::{debug, info};

/// Failure-injection knob (robustness tests): probability that a selected
/// client drops out of the round after selection.
///
/// The seed's single-knob predecessor of
/// [`AvailabilityModel`](crate::coordinator::availability::AvailabilityModel);
/// kept as the simple entry point. The probability is validated (in
/// `[0, 1]`, not NaN) when the spec is converted into an availability
/// model — i.e. by every orchestrator constructor — with a typed
/// [`AvailabilityError`](crate::coordinator::availability::AvailabilityError).
#[derive(Clone, Debug, Default)]
pub struct FaultSpec {
    pub client_dropout: f64,
}

impl FaultSpec {
    /// Validating constructor: rejects NaN and out-of-range probabilities
    /// up front instead of at orchestrator construction.
    pub fn new(
        client_dropout: f64,
    ) -> Result<Self, crate::coordinator::availability::AvailabilityError> {
        let spec = FaultSpec { client_dropout };
        AvailabilityModel::try_from(spec.clone())?;
        Ok(spec)
    }
}

/// Why one client's round contribution was rejected. Every arm is a
/// *per-client* verdict: the round continues with the surviving cohort
/// and the rejection is reported in `RoundRecord::rejected` — from the
/// availability ledger's point of view, a Byzantine client and a
/// dropped-out client look the same (an update that never arrived).
#[derive(Clone, Debug)]
pub enum ClientFault {
    /// The exchange itself failed: the link died, a frame checksum
    /// mismatched, or the reply payload refused to decode (corrupt and
    /// oversized adversaries land here).
    Exchange { detail: String },
    /// Reply decoded to a message kind the protocol does not expect.
    WrongKind { kind: u8 },
    /// Coded reply labeled with a codec other than the negotiated one.
    CodecMismatch { got: String, want: String },
    /// Ternary reply with the wrong quantized-layer count.
    LayerCount { got: usize, want: usize },
    /// Tensor count or shape disagrees with the model schema.
    Shape { detail: String },
    /// Payload decompression/rebuild failed.
    Decode { detail: String },
    /// Client-reported sample count disagrees with the server-side shard
    /// size. The server knows every shard's size from its own partition
    /// of the data, so a client cannot grab aggregation weight by
    /// over-reporting `num_samples` (historically this aborted the whole
    /// round; now it costs only the liar their contribution).
    SampleCount { reported: u64, expected: u64 },
    /// Update contains NaN or infinite values.
    NonFinite,
}

impl fmt::Display for ClientFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientFault::Exchange { detail } => write!(f, "exchange failed: {detail}"),
            ClientFault::WrongKind { kind } => {
                write!(f, "unexpected message kind {kind}")
            }
            ClientFault::CodecMismatch { got, want } => {
                write!(f, "replied with codec {got}, negotiated {want}")
            }
            ClientFault::LayerCount { got, want } => {
                write!(f, "{got} quantized layers, model has {want}")
            }
            ClientFault::Shape { detail } => write!(f, "shape mismatch: {detail}"),
            ClientFault::Decode { detail } => write!(f, "undecodable update: {detail}"),
            ClientFault::SampleCount { reported, expected } => {
                write!(f, "reported {reported} samples, server expected {expected}")
            }
            ClientFault::NonFinite => write!(f, "update contains non-finite values"),
        }
    }
}

/// What a federated round hands back to the driver: training loss over
/// the accepted cohort, protocol factors, and the per-client rejection /
/// clipping verdicts for the round record.
struct FederatedOutcome {
    train_loss: f32,
    factors: Vec<f32>,
    rejected: Vec<u32>,
    clipped: Vec<u32>,
}

impl FederatedOutcome {
    fn clean(train_loss: f32, factors: Vec<f32>) -> Self {
        FederatedOutcome { train_loss, factors, rejected: Vec::new(), clipped: Vec::new() }
    }
}

/// One client's reply, decoded and validated against the server's own
/// view of the run (schema shapes, negotiated codec, shard size).
struct DecodedUpdate {
    num_samples: u64,
    train_loss: f32,
    /// per-quantized-layer wq factors (T-FedAvg replies; empty otherwise)
    wqs: Vec<f32>,
    params: ParamSet,
}

/// Synthesize the datasets and compute the client partition (indices only,
/// no feature copies). Deterministic in `cfg` — every process rebuilds the
/// same split.
fn synth_partition(
    cfg: &ExperimentConfig,
    input_dim: usize,
) -> Result<(crate::data::synth::Dataset, crate::data::synth::Dataset, crate::data::partition::Partition)> {
    let spec = match cfg.task {
        Task::MnistLike => SynthSpec::mnist_like(cfg.train_samples, cfg.test_samples, cfg.seed),
        Task::CifarLike => SynthSpec::cifar_like(cfg.train_samples, cfg.test_samples, cfg.seed),
    };
    let (train, test) = spec.generate();
    if train.dim != input_dim {
        bail!("dataset dim {} != model input {}", train.dim, input_dim);
    }
    let pspec = PartitionSpec {
        n_clients: cfg.n_clients,
        nc: cfg.nc,
        beta: cfg.beta,
        alpha: cfg.dirichlet_alpha,
        seed: cfg.seed ^ 0x51AB,
    };
    let part = partition(&train, &pspec)?;
    Ok((train, test, part))
}

/// Materialize every client shard plus the held-out test set (in-process
/// federations, where all clients live in this address space).
pub fn materialize_data(
    cfg: &ExperimentConfig,
    input_dim: usize,
) -> Result<(Vec<ShardData>, ShardData)> {
    let (train, test, part) = synth_partition(cfg, input_dim)?;
    let shards: Vec<ShardData> = part
        .shards
        .iter()
        .map(|s| ShardData::from_dataset(&train, &s.indices))
        .collect();
    Ok((shards, ShardData::whole(&test)))
}

/// Materialize exactly one client's shard — what a remote `tfed client`
/// process needs. Avoids copying the other N-1 shards and the test set.
pub fn materialize_shard(
    cfg: &ExperimentConfig,
    input_dim: usize,
    client_id: usize,
) -> Result<ShardData> {
    let (train, _test, part) = synth_partition(cfg, input_dim)?;
    let shard = part
        .shards
        .get(client_id)
        .ok_or_else(|| anyhow!("client id {client_id} out of range"))?;
    Ok(ShardData::from_dataset(&train, &shard.indices))
}

/// Round-driver worker threads: `TFED_WORKERS` env override, else one per
/// core capped at 8 (client work is compute-bound; more adds no overlap).
fn default_workers() -> usize {
    if let Ok(v) = std::env::var("TFED_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// A virtual registered population (sim runs only): each round samples a
/// `cohort` of registered ids from `0..registered`; registered client `r`
/// trains on data shard `r % n_clients`.
#[derive(Clone, Copy, Debug)]
struct Population {
    registered: usize,
    cohort: usize,
}

/// A fully-initialized experiment ready to run round-by-round.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::config::{ExperimentConfig, Protocol, Task};
/// use tfed::coordinator::backend::make_backend;
/// use tfed::coordinator::server::Orchestrator;
///
/// let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 42);
/// cfg.n_clients = 4;
/// cfg.rounds = 2;
/// cfg.train_samples = 400;
/// cfg.test_samples = 100;
/// cfg.native_backend = true; // pure-Rust backend, no artifacts needed
/// let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
/// let mut orch = Orchestrator::new(cfg, backend.as_ref()).unwrap();
/// orch.run().unwrap();
/// assert!(orch.metrics.final_acc() > 0.0);
/// ```
pub struct Orchestrator<'a> {
    pub cfg: ExperimentConfig,
    backend: &'a dyn Backend,
    /// the links to the client fleet (loopback unless given via
    /// `with_transport`); centralized protocols never touch it
    transport: Box<dyn Transport + 'a>,
    workers: usize,
    /// local shards, retained only for the centralized protocols (the
    /// federated ones live inside the transport's client runtimes)
    shards: Vec<ShardData>,
    shard_sizes: Vec<usize>,
    test: ShardData,
    global: ParamSet,
    /// TTQ factor state carried across rounds (wp || wn)
    ttq_factors: Vec<f32>,
    /// mean trained w^q of the previous round — broadcast as the clients'
    /// next w^q init (Algorithm 2's "initialize w^q", our reading)
    last_wq_mean: Vec<f32>,
    rng: Pcg,
    availability: AvailabilityModel,
    /// what the rounds actually saw: scheduled dropouts plus per-client
    /// fault rejections, both counted as clients that contributed nothing
    observed: ObservedDropout,
    /// virtual registered population (None = every client is real and
    /// selection runs over `0..n_clients`, the historical behavior)
    population: Option<Population>,
    /// cumulative transport stats at the last round boundary
    stats_mark: LinkStats,
    /// obs trace lane (scenario grid-cell index; 0 for standalone runs) —
    /// keeps spans from parallel `--jobs` cells in separate trace groups
    obs_lane: u32,
    /// grid-cell label stamped on telemetry records ("" standalone)
    obs_cell: String,
    pub metrics: RunMetrics,
}

impl<'a> Orchestrator<'a> {
    pub fn new(cfg: ExperimentConfig, backend: &'a dyn Backend) -> Result<Self> {
        Self::with_faults(cfg, backend, FaultSpec::default())
    }

    /// Default setup: clients attached over an in-process `Loopback`
    /// transport (full frame codec, identical accounting to TCP).
    pub fn with_faults(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        faults: FaultSpec,
    ) -> Result<Self> {
        let availability = AvailabilityModel::try_from(faults)?;
        Self::build(cfg, backend, availability, None, None)
    }

    /// Full availability control: phased dropout schedules and straggler
    /// delay traces (the scenario engine's entry point).
    pub fn with_availability(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
    ) -> Result<Self> {
        Self::build(cfg, backend, availability, None, None)
    }

    /// Virtual-time fleet simulation: the in-process fleet is wrapped in
    /// a [`SimTransport`], each round samples `sim.cohort` clients from a
    /// registered population of `sim.registered` (mapped onto the
    /// `n_clients` data shards), and availability stragglers become
    /// virtual delays. `RoundRecord::sim_secs` carries the simulated
    /// round completion time; everything else — payload bytes, training,
    /// `LinkStats` — is byte-identical to a loopback run of the same
    /// cohort. See DESIGN.md §9.
    pub fn with_sim(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
        sim: SimSpec,
    ) -> Result<Self> {
        if cfg.protocol.is_centralized() {
            bail!("the fleet simulator requires a federated protocol");
        }
        sim.validate_for(cfg.n_clients)?;
        Self::build(cfg, backend, availability, None, Some(sim))
    }

    /// Attach an external transport (e.g. `TcpTransport` with remote
    /// clients). The backend is still used server-side for evaluation and
    /// downstream re-quantization.
    pub fn with_transport(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
        transport: Box<dyn Transport + 'a>,
    ) -> Result<Self> {
        if cfg.protocol.is_centralized() {
            bail!("centralized protocols do not use a transport");
        }
        if transport.n_clients() < cfg.n_clients {
            bail!(
                "transport reaches {} clients, config wants {}",
                transport.n_clients(),
                cfg.n_clients
            );
        }
        Self::build(cfg, backend, availability, Some(transport), None)
    }

    fn build(
        cfg: ExperimentConfig,
        backend: &'a dyn Backend,
        availability: AvailabilityModel,
        transport: Option<Box<dyn Transport + 'a>>,
        sim: Option<SimSpec>,
    ) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Pcg::new(cfg.seed, 0xC0 + cfg.protocol.weight_bits() as u64);

        let input_dim = backend.schema().input_dim;
        let (mut shards, shard_sizes, test) = if transport.is_some() {
            // remote clients materialize their own shards; the server only
            // needs the split sizes and the held-out test set
            let (_train, test, part) = synth_partition(&cfg, input_dim)?;
            let sizes: Vec<usize> = part.shards.iter().map(|s| s.indices.len()).collect();
            (Vec::new(), sizes, ShardData::whole(&test))
        } else {
            let (shards, test) = materialize_data(&cfg, input_dim)?;
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            (shards, sizes, test)
        };

        let transport: Box<dyn Transport + 'a> = match transport {
            Some(t) => t,
            None if cfg.protocol.is_centralized() => Box::new(Loopback::new(Vec::new())),
            None => {
                // the fleet's adversarial cast: each runtime carries the
                // whole model and resolves its behavior per registered id
                // at exchange time, so loopback, TCP, and sim populations
                // act out the identical server-seeded cast
                let cast = AdversaryModel::new(cfg.adversary)
                    .map_err(|e| anyhow!("invalid adversary spec: {e}"))?;
                let runtimes: Vec<ClientRuntime<'a>> = shards
                    .drain(..)
                    .enumerate()
                    .map(|(cid, shard)| ClientRuntime {
                        client_id: cid as u32,
                        backend,
                        shard,
                        local_epochs: cfg.local_epochs,
                        lr: cfg.lr,
                        codec: cfg.codec,
                        adversary: ClientAdversary::from_model(cast.clone()),
                    })
                    .collect();
                let fleet = Loopback::new(runtimes);
                match &sim {
                    Some(spec) => Box::new(SimTransport::new(
                        fleet,
                        FleetModel::from_spec(spec),
                        cfg.local_epochs,
                        availability.straggler_prob(),
                        availability.straggler_delay_ms(),
                    )),
                    None => Box::new(fleet),
                }
            }
        };
        let population = sim
            .as_ref()
            .map(|s| Population { registered: s.registered, cohort: s.cohort });

        let global = init_params(backend.schema(), &mut rng);
        let nq = backend.schema().num_quantized();
        let metrics = RunMetrics::new(cfg.summary());
        info!("experiment: {}", cfg.summary());
        Ok(Orchestrator {
            cfg,
            backend,
            transport,
            workers: default_workers(),
            shards,
            shard_sizes,
            test,
            global,
            ttq_factors: vec![backend.wq_init(); 2 * nq],
            last_wq_mean: vec![backend.wq_init(); nq],
            rng,
            availability,
            observed: ObservedDropout::default(),
            population,
            stats_mark: LinkStats::default(),
            obs_lane: 0,
            obs_cell: String::new(),
            metrics,
        })
    }

    /// The data shard (and transport link) behind a selection id: the id
    /// itself for real fleets; `id % n_clients` for a simulated
    /// registered population (registered clients share the data
    /// substrate but carry their own RNG, timing, and device profile).
    fn shard_of(&self, id: usize) -> usize {
        if self.population.is_some() {
            id % self.cfg.n_clients
        } else {
            id
        }
    }

    /// Override the round-driver worker-thread count (default: one per
    /// core, capped at 8; `TFED_WORKERS` env). Results are identical at
    /// any setting — only wall time changes.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    /// Assign this run's obs trace lane (the scenario runner passes the
    /// grid-cell index). Purely an observability grouping key — results
    /// are identical at any lane.
    pub fn set_obs_lane(&mut self, lane: u32) {
        self.obs_lane = lane;
    }

    /// Label telemetry records with this run's grid-cell identity (the
    /// scenario runner passes `cell.label()`). Observability metadata
    /// only — results are identical with any label.
    pub fn set_obs_cell(&mut self, label: &str) {
        self.obs_cell = label.to_string();
    }

    /// Current dense global model (server state).
    pub fn global(&self) -> &ParamSet {
        &self.global
    }

    /// The run's observed-availability ledger: cumulative scheduled
    /// dropouts plus per-client fault rejections.
    pub fn observed_dropout(&self) -> ObservedDropout {
        self.observed
    }

    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shard_sizes.clone()
    }

    /// Cumulative transport-layer stats over all links.
    pub fn transport_stats(&self) -> LinkStats {
        self.transport.stats()
    }

    /// Per-link transport stats, indexed by client id.
    pub fn link_stats(&self) -> Vec<LinkStats> {
        self.transport.link_stats()
    }

    /// Notify remote clients that the experiment is over (no-op for the
    /// loopback transport).
    pub fn shutdown_transport(&self) -> Result<()> {
        self.transport.shutdown()
    }

    /// The ternary broadcast model a T-FedAvg client would download next
    /// round (Algorithm 2 downstream payload materialized, bare {-1,0,+1}).
    pub fn broadcast_model(&self) -> ParamSet {
        let qidx = self.backend.schema().quantized_indices();
        let patterns =
            quant::requantize_paramset(&self.global, &qidx, self.backend.server_delta());
        quant::rebuild_from_ternary(&self.global, &qidx, &patterns)
    }

    /// The 2-bit T-FedAvg *inference* model: the broadcast pattern scaled
    /// per layer by the eq.-20 optimal factor (see quant::requantize_scaled
    /// — client training is invariant to this rescaling, so it carries no
    /// extra protocol bytes beyond one f32 per layer).
    pub fn ternary_inference_model(&self) -> ParamSet {
        let qidx = self.backend.schema().quantized_indices();
        let mut out = self.global.clone();
        for &i in &qidx {
            let (it, wq) = quant::requantize_scaled(
                &self.global.tensors[i].data,
                self.backend.server_delta(),
            );
            for (dst, &s) in out.tensors[i].data.iter_mut().zip(&it) {
                *dst = wq * s as f32;
            }
        }
        out
    }

    /// Run one communication round. Returns the round record.
    pub fn round(&mut self, round: usize) -> Result<RoundRecord> {
        let sw = Stopwatch::start();
        trace::set_context(self.obs_lane, round as u32, trace::NO_CLIENT);
        let (picked, selected) = {
            crate::obs_span!("round.select");
            let picked = match self.population {
                None => {
                    let k = self.cfg.selected_per_round();
                    select_clients(self.cfg.n_clients, k, &mut self.rng)
                }
                Some(p) => select_cohort(p.registered, p.cohort, &mut self.rng),
            };
            let dropout = self.availability.dropout_for_round(round);
            let kept = apply_dropout(&picked, dropout, &mut self.rng);
            (picked.len(), kept)
        };
        if crate::obs::enabled() {
            obs_metrics::counter("tfed_rounds_total").inc();
            obs_metrics::counter("tfed_clients_selected_total").add(selected.len() as u64);
        }
        // under the simulator, straggler delays are drawn virtually by
        // the transport (per registered client, per round) — the main
        // RNG stream is untouched and nothing ever sleeps
        let delays = if self.population.is_some() {
            vec![0; selected.len()]
        } else {
            self.straggler_delays(&selected)
        };

        let outcome = match self.cfg.protocol {
            Protocol::TFedAvg | Protocol::FedAvg => {
                self.round_federated(round, &selected, &delays)?
            }
            Protocol::Baseline => {
                let (l, f) = self.round_centralized(round, TrainMode::Fp)?;
                FederatedOutcome::clean(l, f)
            }
            Protocol::Ttq => {
                let (l, f) = self.round_centralized(round, TrainMode::Ttq)?;
                FederatedOutcome::clean(l, f)
            }
        };
        let FederatedOutcome { train_loss, factors, rejected, clipped } = outcome;
        // both scheduled dropouts and fault rejections land in the
        // observed-availability ledger: from the aggregation's point of
        // view each is a selected client whose update never arrived
        self.observed.note_round(picked, picked - selected.len(), rejected.len());

        // a sequential dispatch runs exchanges on this thread and leaves
        // the last client's span context behind; restore the server lane
        trace::set_context(self.obs_lane, round as u32, trace::NO_CLIENT);

        // communication cost measured at the frame layer
        let stats = self.transport.stats();
        let delta = stats.since(&self.stats_mark);
        self.stats_mark = stats;

        // round boundary: a virtual-time transport drains its event
        // queue here and advances the simulated clock
        let virtual_time = self.transport.end_round(round as u32);

        let evaluated = round % self.cfg.eval_every == 0 || round == self.cfg.rounds;
        let (test_loss, test_acc) = if evaluated {
            crate::obs_span!("round.eval");
            let eval_model = match self.cfg.protocol {
                // the paper reports the accuracy of the *quantized* model
                Protocol::TFedAvg => self.ternary_inference_model(),
                Protocol::Ttq => self.ttq_inference_model(),
                _ => self.global.clone(),
            };
            self.backend.evaluate(&eval_model, &self.test)?
        } else {
            (f32::NAN, f32::NAN)
        };
        if evaluated && crate::obs::enabled() {
            obs_metrics::gauge("tfed_eval_acc").set(test_acc as f64);
            obs_metrics::gauge("tfed_eval_loss").set(test_loss as f64);
        }

        let rec = RoundRecord {
            round,
            train_loss,
            test_acc,
            test_loss,
            up_bytes: delta.up_bytes,
            down_bytes: delta.down_bytes,
            up_frames: delta.up_frames,
            down_frames: delta.down_frames,
            wall_secs: sw.secs(),
            sim_secs: virtual_time.map_or(0.0, |t| t.round_secs),
            straggler_delay_ms: virtual_time
                .map_or_else(|| delays.iter().sum(), |t| t.straggler_ms),
            selected,
            factors,
            evaluated,
            rejected,
            clipped,
        };
        if evaluated {
            info!(
                "round {round:>4}: loss={train_loss:.4} acc={test_acc:.4} up={}B down={}B",
                rec.up_bytes, rec.down_bytes
            );
        }
        self.metrics.push(rec.clone());
        // learning-dynamics telemetry (one relaxed load when off; when
        // on, reads server state only — no RNG, no bundle changes)
        if crate::obs::telemetry::enabled() {
            self.record_telemetry(&rec);
        }
        Ok(rec)
    }

    /// Build and store this round's learning-dynamics record
    /// (DESIGN.md §12). The dense fp32 `global` is the shadow
    /// accumulator: quantization stats compare it against the protocol's
    /// quantized projection of the same state. Dense protocols record
    /// zeros (there is no projection to diverge from).
    fn record_telemetry(&self, rec: &RoundRecord) {
        use crate::obs::telemetry;
        let qidx = self.backend.schema().quantized_indices();
        let proj = match self.cfg.protocol {
            Protocol::TFedAvg => Some(self.ternary_inference_model()),
            Protocol::Ttq => Some(self.ttq_inference_model()),
            Protocol::FedAvg | Protocol::Baseline => None,
        };
        let (layer_zero_fraction, sparsity, unbias_residual, divergence, rel) =
            match &proj {
                Some(p) => {
                    let (per_layer, overall) = telemetry::zero_fractions(p, &qidx);
                    let resid = telemetry::unbias_residual(&self.global, p, &qidx);
                    let (div, rel) = telemetry::weight_divergence(&self.global, p, &qidx);
                    (per_layer, overall, resid, div, rel)
                }
                None => (vec![0.0; qidx.len()], 0.0, 0.0, 0.0, 0.0),
            };
        telemetry::record(telemetry::TelemetryRecord {
            lane: self.obs_lane,
            round: rec.round as u64,
            cell: self.obs_cell.clone(),
            protocol: self.cfg.protocol.name().to_string(),
            train_loss: rec.train_loss as f64,
            test_acc: rec.test_acc as f64,
            test_loss: rec.test_loss as f64,
            evaluated: rec.evaluated,
            factors: rec.factors.iter().map(|&f| f as f64).collect(),
            layer_zero_fraction,
            sparsity,
            unbias_residual,
            weight_divergence: divergence,
            rel_divergence: rel,
            cum_up_bytes: self.metrics.total_up_bytes(),
            cum_down_bytes: self.metrics.total_down_bytes(),
            sim_secs: self.metrics.total_sim_secs(),
            rejected: rec.rejected.len() as u64,
            clipped: rec.clipped.len() as u64,
        });
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for r in 1..=self.cfg.rounds {
            self.round(r)?;
        }
        Ok(())
    }

    // -- federated rounds (FedAvg + T-FedAvg, Algorithm 2) -------------------

    /// Per-slot reply delays for this round's survivors (milliseconds;
    /// 0 = prompt). Draws from the round RNG *only* when stragglers are
    /// configured, so the default path's RNG stream is untouched.
    fn straggler_delays(&mut self, selected: &[usize]) -> Vec<u64> {
        if !self.availability.has_stragglers() {
            return vec![0; selected.len()];
        }
        let p = self.availability.straggler_prob();
        let d = self.availability.straggler_delay_ms();
        selected
            .iter()
            .map(|_| if self.rng.next_f64() < p { d } else { 0 })
            .collect()
    }

    fn round_federated(
        &mut self,
        round: usize,
        selected: &[usize],
        delays: &[u64],
    ) -> Result<FederatedOutcome> {
        let schema = self.backend.schema().clone();
        let qidx = schema.quantized_indices();
        let shapes: Vec<Vec<usize>> =
            schema.params.iter().map(|p| p.shape.clone()).collect();

        let down_msg = {
            crate::obs_span!("round.broadcast");
            match (self.cfg.protocol, self.cfg.codec) {
                (Protocol::TFedAvg, _) => {
                    Message::TernaryGlobal(self.ternary_broadcast(round, &schema))
                }
                (Protocol::FedAvg, CodecSpec::Dense) => Message::DenseGlobal(DenseGlobal {
                    round: round as u32,
                    tensors: self.global.tensors.iter().map(|t| t.data.clone()).collect(),
                }),
                (Protocol::FedAvg, spec) => {
                    // registry codec: compress the broadcast once,
                    // pre-dispatch. Stochastic codecs draw from a
                    // round-forked generator — one fork per round, before
                    // the per-client forks, so the sequence is identical
                    // on every transport / worker count.
                    let codec = compress::build(spec)?;
                    let mut crng = self.rng.fork(0xC0DE0 + round as u64);
                    Message::CodedGlobal(CodedGlobal {
                        round: round as u32,
                        update: compress::compress(codec.as_ref(), &self.global, &mut crng)?,
                    })
                }
                _ => unreachable!("centralized protocols never reach round_federated"),
            }
        };

        // derive the per-client RNGs up front, in selection order — the
        // same `fork` draw sequence the sequential loop made, so runs
        // reproduce bit-for-bit at any worker count or transport
        let assigns: Vec<RoundAssign> = selected
            .iter()
            .map(|&cid| {
                let tag = cid as u64 + round as u64 * 7919;
                let (rng_seed, rng_stream) = self.rng.fork_params(tag);
                RoundAssign {
                    round: round as u32,
                    client_id: cid as u32,
                    rng_seed,
                    rng_stream,
                    codec: self.cfg.codec,
                }
            })
            .collect();

        let replies = self.dispatch(selected, &assigns, &down_msg, delays)?;
        // single-worker dispatch runs client exchanges on this thread;
        // take the span context back before server-side aggregation
        trace::set_context(self.obs_lane, round as u32, trace::NO_CLIENT);

        // server side: decode + validate + aggregate, in selection order.
        // Two-pass design (DESIGN.md §13): the default `mean` aggregator
        // first attempts the historical streaming fold, which applies the
        // final eq.-2 weight as each update arrives — peak memory is one
        // model, not `clients × model`, and the result is bit-identical
        // to the old batch average (same float-op sequence; DESIGN.md §8).
        // Any per-client fault — or any robust aggregation rule — takes
        // the fault-tolerant batch path instead, which rejects bad
        // updates individually and aggregates the survivors.
        crate::obs_span!("round.aggregate");
        let (global, wq_mean, loss_sum, accepted, rejected, clipped) =
            match if self.cfg.aggregator == AggregatorSpec::Mean {
                self.fold_mean_optimistic(selected, &replies, &schema, &shapes, qidx.len())?
            } else {
                None
            } {
                Some((global, wq_mean, loss_sum)) => {
                    (global, wq_mean, loss_sum, selected.len(), Vec::new(), Vec::new())
                }
                None => self.fold_robust(round, selected, &replies, &schema, &shapes, qidx.len())?,
            };
        self.global = global;
        if !clipped.is_empty() && crate::obs::enabled() {
            obs_metrics::counter("tfed_updates_clipped_total").add(clipped.len() as u64);
        }
        debug!(
            "aggregated {} updates from {} clients ({} rejected)",
            accepted,
            selected.len(),
            rejected.len()
        );
        let factors = if self.cfg.protocol == Protocol::TFedAvg {
            self.last_wq_mean = wq_mean.clone();
            wq_mean
        } else {
            vec![]
        };
        Ok(FederatedOutcome {
            train_loss: (loss_sum / accepted.max(1) as f64) as f32,
            factors,
            rejected,
            clipped,
        })
    }

    /// Decode one client's reply and validate it against the server's own
    /// view of the run: message kind, layer/tensor counts, shapes, the
    /// negotiated codec, the server-side shard size, and finiteness. Every
    /// failure is a typed per-client verdict, never a round abort.
    fn decode_update(
        &self,
        cid: usize,
        reply: &Message,
        schema: &ModelSchema,
        shapes: &[Vec<usize>],
        n_quantized: usize,
    ) -> Result<DecodedUpdate, ClientFault> {
        let (num_samples, train_loss, wqs, params) = match (self.cfg.protocol, reply) {
            (Protocol::TFedAvg, Message::TernaryUpdate(u)) => {
                if u.layers.len() != n_quantized {
                    return Err(ClientFault::LayerCount {
                        got: u.layers.len(),
                        want: n_quantized,
                    });
                }
                let rebuilt = rebuild_update(u, shapes)
                    .map_err(|e| ClientFault::Decode { detail: format!("{e:#}") })?;
                let wqs = u.layers.iter().map(|l| l.wq).collect();
                (u.num_samples, u.train_loss, wqs, rebuilt)
            }
            (Protocol::FedAvg, Message::DenseUpdate(u))
                if self.cfg.codec == CodecSpec::Dense =>
            {
                let mut p = ParamSet::zeros(schema);
                if u.tensors.len() != p.tensors.len() {
                    return Err(ClientFault::Shape {
                        detail: format!(
                            "update has {} tensors, model wants {}",
                            u.tensors.len(),
                            p.tensors.len()
                        ),
                    });
                }
                for ((t, data), shape) in p.tensors.iter_mut().zip(&u.tensors).zip(shapes) {
                    if t.data.len() != data.len() {
                        return Err(ClientFault::Shape {
                            detail: format!(
                                "{} values for tensor of shape {shape:?}",
                                data.len()
                            ),
                        });
                    }
                    t.data.clone_from(data);
                }
                (u.num_samples, u.train_loss, Vec::new(), p)
            }
            (Protocol::FedAvg, Message::CodedUpdate(u))
                if self.cfg.codec != CodecSpec::Dense =>
            {
                if u.update.codec != self.cfg.codec {
                    return Err(ClientFault::CodecMismatch {
                        got: u.update.codec.name(),
                        want: self.cfg.codec.name(),
                    });
                }
                let codec = compress::build(self.cfg.codec)
                    .map_err(|e| ClientFault::Decode { detail: format!("{e:#}") })?;
                let p = compress::decompress(codec.as_ref(), &u.update, shapes)
                    .map_err(|e| ClientFault::Decode { detail: format!("{e:#}") })?;
                (u.num_samples, u.train_loss, Vec::new(), p)
            }
            (_, other) => return Err(ClientFault::WrongKind { kind: other.kind() }),
        };
        // never trust the client's sample count: the server partitioned
        // the data itself, so it knows exactly how many samples this
        // client's shard holds
        let expect_n = self.shard_sizes[self.shard_of(cid)] as u64;
        if num_samples != expect_n {
            return Err(ClientFault::SampleCount { reported: num_samples, expected: expect_n });
        }
        if !params.is_finite() {
            return Err(ClientFault::NonFinite);
        }
        Ok(DecodedUpdate { num_samples, train_loss, wqs, params })
    }

    /// Pass 1 — the historical streaming fold (`mean` only): assume the
    /// whole cohort is honest and fold each update as it is decoded, in
    /// selection order. Returns `Ok(None)` at the first per-client fault
    /// so the caller can rerun fault-tolerantly; honest rounds never take
    /// that fallback and keep the byte-identical legacy float-op sequence.
    #[allow(clippy::type_complexity)]
    fn fold_mean_optimistic(
        &self,
        selected: &[usize],
        replies: &[Result<Message>],
        schema: &ModelSchema,
        shapes: &[Vec<usize>],
        n_quantized: usize,
    ) -> Result<Option<(ParamSet, Vec<f32>, f64)>> {
        let expected_total: u64 =
            selected.iter().map(|&cid| self.shard_sizes[self.shard_of(cid)] as u64).sum();
        let mut agg = Aggregator::for_schema(schema, expected_total)?;
        let mut wq_mean = vec![0f32; n_quantized];
        let mut loss_sum = 0f64;
        for (slot, reply) in replies.iter().enumerate() {
            let Ok(msg) = reply else { return Ok(None) };
            let Ok(dec) = self.decode_update(selected[slot], msg, schema, shapes, n_quantized)
            else {
                return Ok(None);
            };
            for (k, wq) in dec.wqs.iter().enumerate() {
                wq_mean[k] += wq / selected.len() as f32;
            }
            loss_sum += dec.train_loss as f64;
            agg.fold(dec.num_samples, &dec.params)?;
        }
        // server aggregation (eq. 2)
        Ok(Some((agg.finish()?, wq_mean, loss_sum)))
    }

    /// Pass 2 — the fault-tolerant batch path: decode every reply, reject
    /// faulty ones individually (typed, logged, counted), and run the
    /// configured robust aggregation rule over the accepted cohort. Used
    /// for every non-`mean` aggregator, and for `mean` once the
    /// optimistic pass hits a fault. Errors only when *no* update
    /// survives — one Byzantine client can no longer abort a round.
    #[allow(clippy::type_complexity)]
    fn fold_robust(
        &self,
        round: usize,
        selected: &[usize],
        replies: &[Result<Message>],
        schema: &ModelSchema,
        shapes: &[Vec<usize>],
        n_quantized: usize,
    ) -> Result<(ParamSet, Vec<f32>, f64, usize, Vec<u32>, Vec<u32>)> {
        let mut updates: Vec<(u32, u64, ParamSet)> = Vec::new();
        let mut wq_rows: Vec<Vec<f32>> = Vec::new();
        let mut loss_sum = 0f64;
        let mut rejected: Vec<u32> = Vec::new();
        for (slot, reply) in replies.iter().enumerate() {
            let cid = selected[slot] as u32;
            let fault = match reply {
                Ok(msg) => {
                    match self.decode_update(selected[slot], msg, schema, shapes, n_quantized)
                    {
                        Ok(dec) => {
                            wq_rows.push(dec.wqs);
                            loss_sum += dec.train_loss as f64;
                            updates.push((cid, dec.num_samples, dec.params));
                            continue;
                        }
                        Err(fault) => fault,
                    }
                }
                Err(e) => ClientFault::Exchange { detail: format!("{e:#}") },
            };
            info!("round {round}: rejecting client {cid}: {fault}");
            if crate::obs::enabled() {
                obs_metrics::counter("tfed_updates_rejected_total").inc();
            }
            rejected.push(cid);
        }
        if updates.is_empty() {
            bail!(
                "round {round}: every update was rejected ({} of {} clients)",
                rejected.len(),
                selected.len()
            );
        }
        let outcome = robust_aggregate(self.cfg.aggregator, &updates)?;
        // protocol factors average over the accepted cohort only: a
        // rejected update's wq never reaches the next broadcast
        let n_ok = updates.len();
        let mut wq_mean = vec![0f32; n_quantized];
        for row in &wq_rows {
            for (k, wq) in row.iter().enumerate() {
                wq_mean[k] += wq / n_ok as f32;
            }
        }
        Ok((outcome.global, wq_mean, loss_sum, n_ok, rejected, outcome.clipped))
    }

    /// Algorithm 2 downstream payload: server re-quantizes the global model
    /// (fixed Delta) -> ternary patterns + fp biases + next-round w^q init.
    fn ternary_broadcast(&self, round: usize, schema: &ModelSchema) -> TernaryGlobal {
        let qidx = schema.quantized_indices();
        let patterns =
            quant::requantize_paramset(&self.global, &qidx, self.backend.server_delta());
        TernaryGlobal {
            round: round as u32,
            layers: qidx
                .iter()
                .zip(&patterns)
                .map(|(&i, p)| (i as u32, pack_ternary(p)))
                .collect(),
            fp_tensors: schema
                .params
                .iter()
                .enumerate()
                .filter(|(i, _)| !qidx.contains(i))
                .map(|(i, _)| (i as u32, self.global.tensors[i].data.clone()))
                .collect(),
            wq_init: self.last_wq_mean.clone(),
        }
    }

    /// Fan the round out over the transport with a worker pool. Results
    /// come back indexed by selection slot, so downstream aggregation
    /// order (and therefore float summation) is schedule-independent.
    /// Each slot carries its own `Result`: a failed exchange (dead link,
    /// frame error, undecodable reply) is *that client's* fault verdict,
    /// not a round abort — the aggregation pass decides what to do with
    /// it. The outer `Result` covers server-side broadcast encoding only.
    /// `delays` (per slot, ms) injects straggler latency before a
    /// client's exchange — it shifts wall time only (capped, see
    /// `straggle`), never results; under the sim transport delays are
    /// virtual and `delays` is all zeros.
    #[allow(clippy::type_complexity)]
    fn dispatch(
        &self,
        selected: &[usize],
        assigns: &[RoundAssign],
        down: &Message,
        delays: &[u64],
    ) -> Result<Vec<Result<Message>>> {
        let n = selected.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // selection ids resolve to transport links up front (identity for
        // real fleets; shard mapping for a simulated population)
        let links: Vec<usize> = selected.iter().map(|&cid| self.shard_of(cid)).collect();
        // the broadcast is identical for every client: frame it once and
        // fan the same buffer out
        let down_wire = {
            crate::obs_span!("round.encode");
            encode_data_frame(down)?
        };
        let transport = self.transport.as_ref();
        let lane = self.obs_lane;
        let exchange = |i: usize| {
            // tag whichever thread runs this exchange with the client's
            // span context, so client-side spans group correctly
            trace::set_context(lane, assigns[i].round, assigns[i].client_id);
            straggle(delays[i]);
            transport.round_trip(links[i], &assigns[i], &down_wire)
        };
        if self.workers <= 1 {
            return Ok((0..n).map(exchange).collect());
        }
        Ok(parallel_map_indexed(n, self.workers, exchange))
    }

    // -- centralized (Baseline / TTQ) ----------------------------------------

    fn round_centralized(
        &mut self,
        round: usize,
        mode: TrainMode,
    ) -> Result<(f32, Vec<f32>)> {
        let factors0 = match mode {
            TrainMode::Ttq => self.ttq_factors.clone(),
            _ => vec![],
        };
        let mut crng = self.rng.fork(round as u64);
        let out = self.backend.train_local(
            &self.global,
            mode,
            &factors0,
            &self.shards[0],
            self.cfg.local_epochs,
            self.cfg.lr,
            &mut crng,
        )?;
        self.global = out.params.clone();
        let factors = match mode {
            TrainMode::Ttq => {
                // carry the trained factors into the next round (Fig. 12/13)
                self.ttq_factors =
                    out.wp.iter().chain(out.wn.iter()).copied().collect();
                self.ttq_factors.clone()
            }
            _ => vec![],
        };
        Ok((out.mean_loss, factors))
    }

    /// Materialize the TTQ inference model: per layer, scale -> eq. 5
    /// threshold -> {+wp, 0, -wn} (Zhu et al. inference path).
    fn ttq_inference_model(&self) -> ParamSet {
        let schema = self.backend.schema();
        let qidx = schema.quantized_indices();
        let nq = qidx.len();
        let mut out = self.global.clone();
        for (k, &i) in qidx.iter().enumerate() {
            let theta_s = quant::scale(&self.global.tensors[i].data);
            let delta = quant::threshold_max(&theta_s, self.backend.t_k());
            let wp = self.ttq_factors[k];
            let wn = self.ttq_factors[nq + k];
            for (dst, &s) in out.tensors[i].data.iter_mut().zip(&theta_s) {
                *dst = if s > delta {
                    wp
                } else if s < -delta {
                    -wn
                } else {
                    0.0
                };
            }
        }
        out
    }
}

/// Injected straggler latency: block this slot's worker briefly before
/// its exchange (a slow client, as the server experiences it). The real
/// sleep is capped at [`REAL_STRAGGLE_CAP_MS`] — the configured delay is
/// an accounting/modeling quantity (`RoundRecord::straggler_delay_ms`,
/// and full virtual time under the sim transport), not a request to
/// stall the test suite for real.
fn straggle(delay_ms: u64) {
    let capped = delay_ms.min(REAL_STRAGGLE_CAP_MS);
    if capped > 0 {
        std::thread::sleep(std::time::Duration::from_millis(capped));
    }
}

/// Convenience: build an orchestrator and run it to completion.
pub fn run_experiment(
    cfg: ExperimentConfig,
    backend: &dyn Backend,
) -> Result<RunMetrics> {
    let mut orch = Orchestrator::new(cfg, backend)?;
    orch.run()?;
    Ok(orch.metrics.clone())
}
