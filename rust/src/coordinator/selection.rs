//! Client selection: the paper's "randomly selected clients" with
//! participation ratio lambda (§III-B Upstream).

use crate::util::rng::Pcg;

/// Select `k` distinct clients out of `n` for one round.
pub fn select_clients(n: usize, k: usize, rng: &mut Pcg) -> Vec<usize> {
    let k = k.min(n).max(1);
    let mut picked = rng.choose(n, k);
    picked.sort_unstable();
    picked
}

/// Select `k` distinct registered clients out of a *virtual* population
/// of `n` (the sim subsystem's cohort sampler). Unlike [`select_clients`]
/// this never allocates O(n): for the sparse case (`k ≪ n`, the
/// million-client regime) it rejection-samples distinct ids in O(k)
/// expected time and memory; dense cohorts fall back to the partial
/// Fisher-Yates. Both paths draw deterministically from `rng` and return
/// sorted ids, so the cohort is reproducible at any worker count.
pub fn select_cohort(n: usize, k: usize, rng: &mut Pcg) -> Vec<usize> {
    assert!(n > 0 && n <= u32::MAX as usize, "population {n} outside [1, u32::MAX]");
    let k = k.min(n).max(1);
    if k * 8 >= n {
        // dense cohort: rejection would thrash; O(n) is small here anyway
        return select_clients(n, k, rng);
    }
    let mut picked = Vec::with_capacity(k);
    let mut seen = std::collections::HashSet::with_capacity(k * 2);
    while picked.len() < k {
        let c = rng.below(n as u32) as usize;
        if seen.insert(c) {
            picked.push(c);
        }
    }
    picked.sort_unstable();
    picked
}

/// Apply failure injection: each selected client independently drops out
/// with probability `p`; at least one survivor is kept (the round would
/// otherwise stall, matching a server that re-samples).
pub fn apply_dropout(selected: &[usize], p: f64, rng: &mut Pcg) -> Vec<usize> {
    if p <= 0.0 {
        return selected.to_vec();
    }
    let mut kept: Vec<usize> =
        selected.iter().copied().filter(|_| rng.next_f64() >= p).collect();
    if kept.is_empty() && !selected.is_empty() {
        let i = rng.below(selected.len() as u32) as usize;
        kept.push(selected[i]);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn selects_k_distinct_sorted() {
        forall(64, |rng| {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(n as u32) as usize;
            let s = select_clients(n, k, rng);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&c| c < n));
        });
    }

    #[test]
    fn different_rounds_select_differently() {
        let mut rng = Pcg::seeded(1);
        let a = select_clients(100, 10, &mut rng);
        let b = select_clients(100, 10, &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn cohort_is_distinct_sorted_and_o_of_k() {
        forall(32, |rng| {
            let n = 1_000 + rng.below(1_000_000) as usize;
            let k = 1 + rng.below(64) as usize;
            let s = select_cohort(n, k, rng);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&c| c < n));
        });
        // dense edge: cohort == population
        let mut rng = Pcg::seeded(9);
        let all = select_cohort(5, 5, &mut rng);
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cohort_is_deterministic_per_seed() {
        let a = select_cohort(1_000_000, 32, &mut Pcg::seeded(4));
        let b = select_cohort(1_000_000, 32, &mut Pcg::seeded(4));
        assert_eq!(a, b);
        let c = select_cohort(1_000_000, 32, &mut Pcg::seeded(5));
        assert_ne!(a, c);
    }

    #[test]
    fn dropout_keeps_at_least_one() {
        forall(64, |rng| {
            let sel: Vec<usize> = (0..10).collect();
            let kept = apply_dropout(&sel, 0.99, rng);
            assert!(!kept.is_empty());
            assert!(kept.iter().all(|c| sel.contains(c)));
        });
    }

    #[test]
    fn zero_dropout_is_identity() {
        let mut rng = Pcg::seeded(2);
        let sel = vec![1, 5, 9];
        assert_eq!(apply_dropout(&sel, 0.0, &mut rng), sel);
    }
}
