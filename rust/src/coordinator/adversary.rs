//! Byzantine client behaviors: the malicious counterpart of
//! [`availability`](crate::coordinator::availability)'s honest-but-flaky
//! axis.
//!
//! An [`AdversaryModel`] answers one question: which behavior does
//! registered client `id` exhibit for the whole run? Assignment draws
//! from a dedicated server-seeded [`Pcg`] keyed by the client id — never
//! from the orchestrator's main stream — so the adversarial cast is
//! identical at any worker count, over all three transports (loopback,
//! TCP, sim), and whether the behavior is applied in-process or by a
//! remote `tfed client` that resolved the same spec from the Config
//! frame.
//!
//! Behaviors split into two families the server must handle differently
//! (DESIGN.md §13):
//!
//! * **statistical attacks** (`scale:f`, `sign_flip`, `replay`) produce
//!   protocol-legal updates with hostile values — absorbed (or not) by
//!   the configured [`AggregatorSpec`](crate::coordinator::aggregation);
//! * **protocol deviations** (`corrupt_frame`, `wrong_codec`,
//!   `wrong_samples`, `oversize`) break the wire contract — detected
//!   server-side as typed per-client faults and fed to the availability
//!   accounting as observed dropout, never a panic.
//!
//! The default spec ([`AdversarySpec::honest`]) assigns `Honest` to
//! everyone without constructing an RNG, so default runs stay
//! bit-identical to the pre-adversary orchestrator.

use std::fmt;

use crate::util::rng::Pcg;

/// Stream salt for the assignment generator: keeps the adversary draws
/// disjoint from every other derived stream even under equal seeds.
const ASSIGN_SALT: u64 = 0xADBE_EF00;

/// Largest accepted `scale:f` magnitude: big enough to break undefended
/// means, small enough that a handful of scaled f32 updates cannot
/// overflow the f64 accumulator into NaN-poisoning the typed-error path.
pub const MAX_SCALE: f64 = 1e9;

/// What a Byzantine client does to every round it participates in.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Behavior {
    /// Protocol-honest client (the default for everyone).
    Honest,
    /// Upload `factor * trained_params` (model-poisoning by scaling).
    Scale(f64),
    /// Upload `-trained_params` (sign-flipped gradient direction).
    SignFlip,
    /// Re-send the previous round's upload (stale-round replay).
    Replay,
    /// Upload a frame whose payload decodes to an internally
    /// inconsistent message (CRC passes; message decode must not).
    CorruptFrame,
    /// Mislabel the payload: wrong codec id / wrong message kind.
    WrongCodec,
    /// Over-report `num_samples` to grab aggregation weight.
    WrongSamples,
    /// Upload a payload larger than the frame codec's `MAX_FRAME`.
    Oversize,
}

impl Behavior {
    /// Stable registry name (what manifests and CLI parse back).
    pub fn name(&self) -> String {
        match self {
            Behavior::Honest => "honest".into(),
            Behavior::Scale(f) => format!("scale:{f}"),
            Behavior::SignFlip => "sign_flip".into(),
            Behavior::Replay => "replay".into(),
            Behavior::CorruptFrame => "corrupt_frame".into(),
            Behavior::WrongCodec => "wrong_codec".into(),
            Behavior::WrongSamples => "wrong_samples".into(),
            Behavior::Oversize => "oversize".into(),
        }
    }

    /// True for the wire-contract-breaking family (detected, not
    /// aggregated); false for statistical attacks and `Honest`.
    pub fn is_protocol_deviation(&self) -> bool {
        matches!(
            self,
            Behavior::CorruptFrame
                | Behavior::WrongCodec
                | Behavior::WrongSamples
                | Behavior::Oversize
        )
    }
}

/// Typed validation/parse error for adversary parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum AdversaryError {
    /// Behavior name not in the registry.
    UnknownBehavior { name: String },
    /// `scale:f` factor NaN, infinite, or beyond [`MAX_SCALE`].
    BadScale { value: f64 },
    /// Adversarial fraction NaN or outside [0, 1].
    BadFraction { value: f64 },
    /// A behavior that takes no parameter got one (or `scale:` is
    /// missing its factor).
    BadParam { name: String },
}

impl fmt::Display for AdversaryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdversaryError::UnknownBehavior { name } => {
                write!(f, "unknown adversary behavior {name:?} (known: {})", behavior_names().join(", "))
            }
            AdversaryError::BadScale { value } => {
                write!(f, "scale factor must be finite with |f| <= {MAX_SCALE:e}, got {value}")
            }
            AdversaryError::BadFraction { value } => {
                write!(f, "adversary fraction must be in [0, 1], got {value}")
            }
            AdversaryError::BadParam { name } => {
                write!(f, "malformed adversary behavior parameter in {name:?}")
            }
        }
    }
}

impl std::error::Error for AdversaryError {}

/// Names `AdversarySpec::parse` accepts (scale shown with its parameter
/// syntax).
pub fn behavior_names() -> Vec<&'static str> {
    vec![
        "honest",
        "scale:<f>",
        "sign_flip",
        "replay",
        "corrupt_frame",
        "wrong_codec",
        "wrong_samples",
        "oversize",
    ]
}

/// The run-level adversary configuration carried in `ExperimentConfig`
/// (and therefore the Config wire frame): one behavior, the fraction of
/// the registered population exhibiting it, and the assignment seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversarySpec {
    pub behavior: Behavior,
    /// Probability that a given registered client id is adversarial.
    pub fraction: f64,
    /// Seed for the dedicated assignment generator (decoupled from the
    /// experiment seed so defenses can be swept against a fixed cast).
    pub seed: u64,
}

impl Default for AdversarySpec {
    fn default() -> Self {
        Self::honest()
    }
}

impl AdversarySpec {
    /// Serialized size in the Config frame: behavior id (u8), scale
    /// param (f64), fraction (f64), seed (u64).
    pub const WIRE_BYTES: usize = 25;

    /// The inert default: nobody is adversarial, no RNG is constructed.
    pub fn honest() -> Self {
        AdversarySpec { behavior: Behavior::Honest, fraction: 0.0, seed: 0 }
    }

    /// True when this spec can mark at least one client adversarial.
    pub fn is_active(&self) -> bool {
        self.behavior != Behavior::Honest && self.fraction > 0.0
    }

    /// Parse a behavior string (`"sign_flip"`, `"scale:10"`, ...) plus
    /// fraction and seed into a validated spec.
    pub fn parse(behavior: &str, fraction: f64, seed: u64) -> Result<Self, AdversaryError> {
        let behavior = match behavior {
            "honest" => Behavior::Honest,
            "sign_flip" => Behavior::SignFlip,
            "replay" => Behavior::Replay,
            "corrupt_frame" => Behavior::CorruptFrame,
            "wrong_codec" => Behavior::WrongCodec,
            "wrong_samples" => Behavior::WrongSamples,
            "oversize" => Behavior::Oversize,
            s => match s.strip_prefix("scale:") {
                Some(arg) => {
                    let f: f64 = arg
                        .parse()
                        .map_err(|_| AdversaryError::BadParam { name: s.into() })?;
                    Behavior::Scale(f)
                }
                None if s == "scale" => {
                    return Err(AdversaryError::BadParam { name: s.into() })
                }
                None => return Err(AdversaryError::UnknownBehavior { name: s.into() }),
            },
        };
        let spec = AdversarySpec { behavior, fraction, seed };
        spec.check()?;
        Ok(spec)
    }

    /// Validate the spec (scale magnitude, fraction range; NaN rejected).
    pub fn check(&self) -> Result<(), AdversaryError> {
        if let Behavior::Scale(f) = self.behavior {
            if !f.is_finite() || f.abs() > MAX_SCALE {
                return Err(AdversaryError::BadScale { value: f });
            }
        }
        if !(0.0..=1.0).contains(&self.fraction) {
            return Err(AdversaryError::BadFraction { value: self.fraction });
        }
        Ok(())
    }

    /// Behavior id + parameter for the wire encoding.
    fn id_param(&self) -> (u8, f64) {
        match self.behavior {
            Behavior::Honest => (0, 0.0),
            Behavior::Scale(f) => (1, f),
            Behavior::SignFlip => (2, 0.0),
            Behavior::Replay => (3, 0.0),
            Behavior::CorruptFrame => (4, 0.0),
            Behavior::WrongCodec => (5, 0.0),
            Behavior::WrongSamples => (6, 0.0),
            Behavior::Oversize => (7, 0.0),
        }
    }

    /// Fixed-size Config-frame encoding.
    pub fn to_wire(&self) -> [u8; Self::WIRE_BYTES] {
        let (id, param) = self.id_param();
        let mut out = [0u8; Self::WIRE_BYTES];
        out[0] = id;
        out[1..9].copy_from_slice(&param.to_le_bytes());
        out[9..17].copy_from_slice(&self.fraction.to_le_bytes());
        out[17..25].copy_from_slice(&self.seed.to_le_bytes());
        out
    }

    /// Decode and validate a Config-frame encoding.
    pub fn from_wire(bytes: [u8; Self::WIRE_BYTES]) -> Result<Self, AdversaryError> {
        let param = f64::from_le_bytes(bytes[1..9].try_into().unwrap());
        let fraction = f64::from_le_bytes(bytes[9..17].try_into().unwrap());
        let seed = u64::from_le_bytes(bytes[17..25].try_into().unwrap());
        let behavior = match bytes[0] {
            0 => Behavior::Honest,
            1 => Behavior::Scale(param),
            2 => Behavior::SignFlip,
            3 => Behavior::Replay,
            4 => Behavior::CorruptFrame,
            5 => Behavior::WrongCodec,
            6 => Behavior::WrongSamples,
            7 => Behavior::Oversize,
            id => {
                return Err(AdversaryError::UnknownBehavior { name: format!("wire id {id}") })
            }
        };
        let spec = AdversarySpec { behavior, fraction, seed };
        spec.check()?;
        Ok(spec)
    }

    /// Summary fragment for run labels (`behavior@fraction`).
    pub fn label(&self) -> String {
        format!("{}@{}", self.behavior.name(), self.fraction)
    }
}

/// Validated per-client behavior assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct AdversaryModel {
    spec: AdversarySpec,
}

impl Default for AdversaryModel {
    fn default() -> Self {
        Self::honest()
    }
}

impl AdversaryModel {
    /// Everyone is honest; `behavior_of` never constructs an RNG.
    pub fn honest() -> Self {
        AdversaryModel { spec: AdversarySpec::honest() }
    }

    /// Validated constructor (the only path to an active model).
    pub fn new(spec: AdversarySpec) -> Result<Self, AdversaryError> {
        spec.check()?;
        Ok(AdversaryModel { spec })
    }

    pub fn spec(&self) -> AdversarySpec {
        self.spec
    }

    /// The behavior client `id` exhibits for the whole run. Pure
    /// function of (spec seed, client id): each client gets its own
    /// single-draw generator, so assignment is independent of worker
    /// count, transport, and iteration order, and any peer holding the
    /// same spec resolves the same cast.
    pub fn behavior_of(&self, client_id: u32) -> Behavior {
        if !self.spec.is_active() {
            return Behavior::Honest;
        }
        let mut rng = Pcg::new(self.spec.seed ^ ASSIGN_SALT, client_id as u64);
        if rng.next_f64() < self.spec.fraction {
            self.spec.behavior
        } else {
            Behavior::Honest
        }
    }

    /// Ids in `0..n` assigned the adversarial behavior (diagnostics and
    /// tests; the round driver asks per client instead).
    pub fn adversaries(&self, n: u32) -> Vec<u32> {
        (0..n).filter(|&id| self.behavior_of(id) != Behavior::Honest).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_inert() {
        let m = AdversaryModel::default();
        assert!(!m.spec().is_active());
        for id in 0..64 {
            assert_eq!(m.behavior_of(id), Behavior::Honest);
        }
        assert!(m.adversaries(64).is_empty());
    }

    #[test]
    fn parse_all_names() {
        for (s, want) in [
            ("honest", Behavior::Honest),
            ("sign_flip", Behavior::SignFlip),
            ("replay", Behavior::Replay),
            ("corrupt_frame", Behavior::CorruptFrame),
            ("wrong_codec", Behavior::WrongCodec),
            ("wrong_samples", Behavior::WrongSamples),
            ("oversize", Behavior::Oversize),
            ("scale:10", Behavior::Scale(10.0)),
            ("scale:-2.5", Behavior::Scale(-2.5)),
        ] {
            let spec = AdversarySpec::parse(s, 0.5, 7).unwrap();
            assert_eq!(spec.behavior, want, "{s}");
            // name() round-trips through parse for every behavior
            let back = AdversarySpec::parse(&spec.behavior.name(), 0.5, 7).unwrap();
            assert_eq!(back.behavior, want, "{s} via name()");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(matches!(
            AdversarySpec::parse("gaslight", 0.5, 0).unwrap_err(),
            AdversaryError::UnknownBehavior { .. }
        ));
        assert!(matches!(
            AdversarySpec::parse("scale", 0.5, 0).unwrap_err(),
            AdversaryError::BadParam { .. }
        ));
        assert!(matches!(
            AdversarySpec::parse("scale:huge", 0.5, 0).unwrap_err(),
            AdversaryError::BadParam { .. }
        ));
        for f in [f64::NAN, f64::INFINITY, MAX_SCALE * 2.0] {
            let err = AdversarySpec::parse(&format!("scale:{f}"), 0.5, 0).unwrap_err();
            assert!(
                matches!(err, AdversaryError::BadScale { .. } | AdversaryError::BadParam { .. }),
                "f={f} err={err}"
            );
        }
        for p in [-0.1, 1.1, f64::NAN] {
            assert!(matches!(
                AdversarySpec::parse("sign_flip", p, 0).unwrap_err(),
                AdversaryError::BadFraction { .. }
            ));
        }
        // boundaries are fine
        AdversarySpec::parse("sign_flip", 0.0, 0).unwrap();
        AdversarySpec::parse("sign_flip", 1.0, 0).unwrap();
    }

    #[test]
    fn wire_roundtrip_every_behavior() {
        for s in [
            "honest",
            "sign_flip",
            "replay",
            "corrupt_frame",
            "wrong_codec",
            "wrong_samples",
            "oversize",
            "scale:123.25",
        ] {
            let spec = AdversarySpec::parse(s, 0.25, 0xFEED).unwrap();
            let back = AdversarySpec::from_wire(spec.to_wire()).unwrap();
            assert_eq!(back, spec, "{s}");
        }
    }

    #[test]
    fn wire_rejects_unknown_id_and_bad_values() {
        let mut bytes = AdversarySpec::honest().to_wire();
        bytes[0] = 99;
        assert!(AdversarySpec::from_wire(bytes).is_err());
        let mut bytes = AdversarySpec::parse("sign_flip", 1.0, 0).unwrap().to_wire();
        bytes[9..17].copy_from_slice(&2.0f64.to_le_bytes()); // fraction 2.0
        assert!(AdversarySpec::from_wire(bytes).is_err());
    }

    #[test]
    fn assignment_is_deterministic_and_order_free() {
        let spec = AdversarySpec::parse("sign_flip", 0.4, 42).unwrap();
        let m = AdversaryModel::new(spec).unwrap();
        let forward: Vec<Behavior> = (0..32).map(|id| m.behavior_of(id)).collect();
        let backward: Vec<Behavior> = (0..32).rev().map(|id| m.behavior_of(id)).collect();
        let backward: Vec<Behavior> = backward.into_iter().rev().collect();
        assert_eq!(forward, backward);
        // a second model from the same spec agrees (remote-client path)
        let m2 = AdversaryModel::new(spec).unwrap();
        for id in 0..32 {
            assert_eq!(m.behavior_of(id), m2.behavior_of(id), "id={id}");
        }
    }

    #[test]
    fn fraction_controls_cast_size() {
        let all = AdversaryModel::new(AdversarySpec::parse("replay", 1.0, 9).unwrap()).unwrap();
        assert_eq!(all.adversaries(50).len(), 50);
        let none = AdversaryModel::new(AdversarySpec::parse("replay", 0.0, 9).unwrap()).unwrap();
        assert!(none.adversaries(50).is_empty());
        // ~40% of a large population, not all-or-nothing
        let some = AdversaryModel::new(AdversarySpec::parse("replay", 0.4, 9).unwrap()).unwrap();
        let k = some.adversaries(1000).len();
        assert!((250..550).contains(&k), "k={k}");
    }

    #[test]
    fn seed_changes_cast_not_size_regime() {
        let a = AdversaryModel::new(AdversarySpec::parse("replay", 0.5, 1).unwrap()).unwrap();
        let b = AdversaryModel::new(AdversarySpec::parse("replay", 0.5, 2).unwrap()).unwrap();
        assert_ne!(a.adversaries(256), b.adversaries(256));
    }

    #[test]
    fn labels_and_errors_display() {
        let spec = AdversarySpec::parse("scale:10", 0.25, 0).unwrap();
        assert_eq!(spec.label(), "scale:10@0.25");
        assert!(!spec.behavior.is_protocol_deviation());
        assert!(Behavior::Oversize.is_protocol_deviation());
        let e = AdversaryError::BadFraction { value: 2.0 };
        assert!(format!("{e}").contains("[0, 1]"));
        let e = AdversaryError::UnknownBehavior { name: "x".into() };
        assert!(format!("{e}").contains("known"));
    }
}
