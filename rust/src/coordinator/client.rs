//! Client-side logic: shard materialization, epoch-chunk batching, and the
//! protocol round handler (`ClientRuntime`) shared by the in-process
//! `Loopback` transport and the remote `tfed client` process.
//!
//! Train artifacts take fixed shapes [NB, B, dim]; a client shard of any
//! size is covered by shuffling, splitting into NB*B-sample chunks, and
//! zero-padding the tail with a {0,1} sample mask (the masked-loss graphs
//! make padding exact — see python/tests/test_train.py).

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::comms::{
    dense_update, ternary_update, unpack_dequantize, CodedGlobal, CodedUpdate, DenseGlobal,
    DenseUpdate, Message, TernaryGlobal,
};
use crate::compress::{self, CodecSpec, CompressedUpdate};
use crate::coordinator::adversary::{AdversaryModel, AdversarySpec, Behavior};
use crate::coordinator::backend::{Backend, TrainMode};
use crate::data::synth::Dataset;
use crate::model::ParamSet;
use crate::transport::MAX_FRAME;
use crate::util::rng::Pcg;

/// A client's materialized local data (features copied out of the shared
/// dataset once, at setup).
#[derive(Clone, Debug)]
pub struct ShardData {
    pub dim: usize,
    pub num_classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<u32>,
}

impl ShardData {
    pub fn from_dataset(data: &Dataset, indices: &[u32]) -> ShardData {
        let mut x = Vec::with_capacity(indices.len() * data.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(data.sample(i as usize));
            y.push(data.labels[i as usize]);
        }
        ShardData { dim: data.dim, num_classes: data.num_classes, x, y }
    }

    pub fn whole(data: &Dataset) -> ShardData {
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        Self::from_dataset(data, &idx)
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// One padded chunk ready for a train/eval artifact call.
pub struct Chunk {
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    pub ms: Vec<f32>,
    /// real (unpadded) samples in this chunk
    pub samples: usize,
}

/// Split `order` (indices into `data`) into chunks of `nb * b` samples,
/// zero-padding the last chunk.
pub fn make_chunks(data: &ShardData, order: &[u32], b: usize, nb: usize) -> Vec<Chunk> {
    let cap = b * nb;
    let dim = data.dim;
    let mut chunks = Vec::with_capacity(order.len().div_ceil(cap));
    for chunk_idx in order.chunks(cap) {
        let mut xs = vec![0f32; cap * dim];
        let mut ys = vec![0i32; cap];
        let mut ms = vec![0f32; cap];
        for (slot, &i) in chunk_idx.iter().enumerate() {
            let i = i as usize;
            xs[slot * dim..(slot + 1) * dim]
                .copy_from_slice(&data.x[i * dim..(i + 1) * dim]);
            ys[slot] = data.y[i] as i32;
            ms[slot] = 1.0;
        }
        chunks.push(Chunk { xs, ys, ms, samples: chunk_idx.len() });
    }
    chunks
}

/// Per-client adversarial state: the run's [`AdversaryModel`] (behavior
/// is resolved per exchange from the round assignment's registered
/// client id, so all transports act out the same server-seeded cast)
/// plus the replay cache the `replay` behavior needs (guarded so a
/// worker pool can share the runtime immutably). The honest default is
/// inert.
#[derive(Debug)]
pub struct ClientAdversary {
    model: AdversaryModel,
    replay: Mutex<Option<Message>>,
}

impl Default for ClientAdversary {
    fn default() -> Self {
        Self::honest()
    }
}

impl ClientAdversary {
    /// The protocol-honest client every default run gets.
    pub fn honest() -> Self {
        Self::from_model(AdversaryModel::honest())
    }

    /// Act out `model`'s cast (what orchestrators and remote clients
    /// build from the wire-delivered config).
    pub fn from_model(model: AdversaryModel) -> Self {
        ClientAdversary { model, replay: Mutex::new(None) }
    }

    /// A cast of one: every registered id acts out `behavior`
    /// (fraction 1.0). Test harness convenience.
    pub fn with_behavior(behavior: Behavior) -> Self {
        let spec = AdversarySpec { behavior, fraction: 1.0, seed: 0 };
        Self::from_model(AdversaryModel::new(spec).expect("fixed behavior spec is valid"))
    }

    /// The behavior registered client `rid` acts out.
    pub fn behavior_of(&self, rid: u32) -> Behavior {
        self.model.behavior_of(rid)
    }

    /// Apply `behavior`'s protocol deviation to an already-built reply;
    /// `replay` swaps in the previous round's upload (first round
    /// replays the fresh one — nothing staler exists). Honest and purely
    /// statistical behaviors return the reply untouched.
    pub fn tamper(&self, behavior: Behavior, fresh: Message, negotiated: CodecSpec) -> Message {
        match behavior {
            Behavior::Replay => {
                let mut cache = self.replay.lock().unwrap();
                let stale = cache.clone().unwrap_or_else(|| fresh.clone());
                *cache = Some(fresh);
                stale
            }
            Behavior::CorruptFrame => corrupt_message(fresh),
            Behavior::WrongCodec => mislabel_message(fresh, negotiated),
            Behavior::WrongSamples => inflate_samples(fresh),
            Behavior::Oversize => oversize_message(fresh),
            _ => fresh,
        }
    }
}

/// The client side of one protocol round: decode the broadcast, train
/// locally, quantize, encode the upload. One instance per client; the
/// `Loopback` transport holds them in-process, the `tfed client`
/// subcommand holds exactly one in its own process. Stateless across
/// rounds (all cross-round state travels in the messages — except the
/// guarded replay cache an adversarial `replay` client keeps), so a
/// worker pool may drive different clients concurrently.
pub struct ClientRuntime<'a> {
    pub client_id: u32,
    pub backend: &'a dyn Backend,
    pub shard: ShardData,
    pub local_epochs: usize,
    pub lr: f32,
    /// negotiated payload codec (from the experiment config); broadcasts
    /// and round assignments carrying any other codec are rejected
    pub codec: CodecSpec,
    /// the run's Byzantine cast (honest by default, from the config's
    /// `AdversarySpec`); behavior resolves per exchange from the round
    /// assignment's registered client id, so loopback, TCP, and the
    /// sim's registered population all act out the same cast
    pub adversary: ClientAdversary,
}

impl ClientRuntime<'_> {
    /// Handle one downstream broadcast; returns the upstream update.
    /// `rng` is the round-assigned generator (seeded by the server) and
    /// `rid` the assignment's registered client id, so the result is
    /// independent of where or when this client runs. An adversarial
    /// runtime trains honestly, then applies its behavior to the trained
    /// parameters (statistical attacks) or the outgoing message
    /// (protocol deviations).
    pub fn handle_round(&self, rng: &mut Pcg, rid: u32, down: &Message) -> Result<Message> {
        let behavior = self.adversary.behavior_of(rid);
        let fresh = match down {
            Message::TernaryGlobal(g) => self.ternary_round(rng, behavior, g),
            Message::DenseGlobal(g) => self.dense_round(rng, behavior, g),
            Message::CodedGlobal(g) => self.coded_round(rng, behavior, g),
            other => bail!("client received upstream message kind {}", other.kind()),
        }?;
        Ok(self.adversary.tamper(behavior, fresh, self.codec))
    }

    /// T-FedAvg (Algorithm 2): rebuild bare {-1,0,+1} latent weights + fp
    /// biases, train FTTQ from the broadcast w^q init, re-ternarize, upload.
    fn ternary_round(
        &self,
        rng: &mut Pcg,
        behavior: Behavior,
        g: &TernaryGlobal,
    ) -> Result<Message> {
        let schema = self.backend.schema();
        let start = {
            crate::obs_span!("client.decode");
            let mut start = ParamSet::zeros(schema);
            for (i, packed) in &g.layers {
                let idx = *i as usize;
                let t = start
                    .tensors
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("broadcast layer index {idx} out of range"))?;
                let dense = unpack_dequantize(packed, 1.0)?;
                if dense.len() != t.data.len() {
                    bail!(
                        "broadcast layer {idx}: {} values for shape {:?}",
                        dense.len(),
                        t.shape
                    );
                }
                t.data = dense;
            }
            for (i, data) in &g.fp_tensors {
                let idx = *i as usize;
                let t = start
                    .tensors
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("broadcast tensor index {idx} out of range"))?;
                if data.len() != t.data.len() {
                    bail!(
                        "broadcast tensor {idx}: {} values for shape {:?}",
                        data.len(),
                        t.shape
                    );
                }
                t.data = data.clone();
            }
            start
        };
        let out = {
            crate::obs_span!("client.train");
            self.backend.train_local(
                &start,
                TrainMode::Fttq,
                &g.wq_init,
                &self.shard,
                self.local_epochs,
                self.lr,
                rng,
            )?
        };
        crate::obs_span!("client.encode");
        let mut out = out;
        attack_params(behavior, &mut out.params);
        let (patterns, deltas) = self.backend.quantize(&out.params)?;
        let qidx = schema.quantized_indices();
        let upd = ternary_update(
            self.client_id,
            self.shard.len() as u64,
            &qidx,
            &patterns,
            &out.wq,
            &deltas,
            &out.params,
            out.mean_loss,
        );
        Ok(Message::TernaryUpdate(upd))
    }

    /// Registry-codec round (fp16 / quant / stc / generic ternary):
    /// decompress the broadcast, train full precision, compress the
    /// trained parameters with the same codec. Stochastic codecs draw
    /// from the round-assigned `rng` *after* training, so upload encoding
    /// is as reproducible as the training itself.
    fn coded_round(&self, rng: &mut Pcg, behavior: Behavior, g: &CodedGlobal) -> Result<Message> {
        if g.update.codec != self.codec {
            bail!(
                "broadcast codec {} does not match negotiated codec {}",
                g.update.codec.name(),
                self.codec.name()
            );
        }
        let schema = self.backend.schema();
        let shapes: Vec<Vec<usize>> = schema.params.iter().map(|p| p.shape.clone()).collect();
        let codec = compress::build(self.codec)?;
        let start = {
            crate::obs_span!("client.decode");
            compress::decompress(codec.as_ref(), &g.update, &shapes)?
        };
        let out = {
            crate::obs_span!("client.train");
            self.backend.train_local(
                &start,
                TrainMode::Fp,
                &[],
                &self.shard,
                self.local_epochs,
                self.lr,
                rng,
            )?
        };
        crate::obs_span!("client.encode");
        let mut out = out;
        attack_params(behavior, &mut out.params);
        let update = compress::compress(codec.as_ref(), &out.params, rng)?;
        Ok(Message::CodedUpdate(CodedUpdate {
            client_id: self.client_id,
            num_samples: self.shard.len() as u64,
            train_loss: out.mean_loss,
            update,
        }))
    }

    /// FedAvg: load the dense broadcast, train full precision, upload.
    fn dense_round(&self, rng: &mut Pcg, behavior: Behavior, g: &DenseGlobal) -> Result<Message> {
        let schema = self.backend.schema();
        let start = {
            crate::obs_span!("client.decode");
            let mut start = ParamSet::zeros(schema);
            if g.tensors.len() != start.tensors.len() {
                bail!(
                    "broadcast has {} tensors, model wants {}",
                    g.tensors.len(),
                    start.tensors.len()
                );
            }
            for (t, data) in start.tensors.iter_mut().zip(&g.tensors) {
                if data.len() != t.data.len() {
                    bail!("broadcast tensor: {} values for shape {:?}", data.len(), t.shape);
                }
                t.data = data.clone();
            }
            start
        };
        let out = {
            crate::obs_span!("client.train");
            self.backend.train_local(
                &start,
                TrainMode::Fp,
                &[],
                &self.shard,
                self.local_epochs,
                self.lr,
                rng,
            )?
        };
        crate::obs_span!("client.encode");
        let mut out = out;
        attack_params(behavior, &mut out.params);
        Ok(Message::DenseUpdate(dense_update(
            self.client_id,
            self.shard.len() as u64,
            &out.params,
            out.mean_loss,
        )))
    }
}

/// Statistical attacks transform the trained parameters *before*
/// encoding, so they ride every codec's legal wire format.
fn attack_params(behavior: Behavior, params: &mut ParamSet) {
    match behavior {
        Behavior::Scale(f) => params.scale(f as f32),
        Behavior::SignFlip => params.scale(-1.0),
        _ => {}
    }
}

/// (client_id, num_samples, train_loss) of any upstream update message.
fn update_identity(msg: &Message) -> (u32, u64, f32) {
    match msg {
        Message::TernaryUpdate(u) => (u.client_id, u.num_samples, u.train_loss),
        Message::DenseUpdate(u) => (u.client_id, u.num_samples, u.train_loss),
        Message::CodedUpdate(u) => (u.client_id, u.num_samples, u.train_loss),
        _ => (0, 0, 0.0),
    }
}

/// `corrupt_frame`: damage the payload so the server's decode path fails
/// with a typed per-client error while the frame layer stays legal.
fn corrupt_message(msg: Message) -> Message {
    match msg {
        Message::TernaryUpdate(mut u) => {
            // dropping one packed byte breaks the nb == len.div_ceil(4)
            // invariant the wire decoder enforces
            match u.layers.iter_mut().find(|l| !l.pattern.bytes.is_empty()) {
                Some(layer) => {
                    layer.pattern.bytes.pop();
                }
                None => u.fp_tensors.push((u32::MAX, Vec::new())),
            }
            Message::TernaryUpdate(u)
        }
        Message::DenseUpdate(mut u) => {
            match u.tensors.iter_mut().find(|t| !t.is_empty()) {
                Some(t) => {
                    t.pop();
                }
                None => u.tensors.clear(),
            }
            Message::DenseUpdate(u)
        }
        Message::CodedUpdate(mut u) => {
            match u.update.tensors.iter_mut().find(|t| !t.is_empty()) {
                Some(t) => t.truncate(t.len() / 2),
                None => u.update.tensors.clear(),
            }
            Message::CodedUpdate(u)
        }
        other => other,
    }
}

/// `wrong_codec`: answer with a payload the negotiated protocol does not
/// expect — a mislabeled codec id, or the wrong message kind entirely.
fn mislabel_message(msg: Message, negotiated: CodecSpec) -> Message {
    match msg {
        Message::CodedUpdate(mut u) => {
            u.update.codec =
                if negotiated == CodecSpec::Fp16 { CodecSpec::Dense } else { CodecSpec::Fp16 };
            Message::CodedUpdate(u)
        }
        Message::TernaryUpdate(u) => Message::DenseUpdate(DenseUpdate {
            client_id: u.client_id,
            num_samples: u.num_samples,
            tensors: Vec::new(),
            train_loss: u.train_loss,
        }),
        Message::DenseUpdate(u) => Message::CodedUpdate(CodedUpdate {
            client_id: u.client_id,
            num_samples: u.num_samples,
            train_loss: u.train_loss,
            update: CompressedUpdate { codec: CodecSpec::Fp16, tensors: Vec::new() },
        }),
        other => other,
    }
}

/// `wrong_samples`: over-report the shard size to grab aggregation weight
/// (the server verifies the claim against its own shard bookkeeping).
fn inflate_samples(msg: Message) -> Message {
    match msg {
        Message::TernaryUpdate(mut u) => {
            u.num_samples = u.num_samples * 2 + 1;
            Message::TernaryUpdate(u)
        }
        Message::DenseUpdate(mut u) => {
            u.num_samples = u.num_samples * 2 + 1;
            Message::DenseUpdate(u)
        }
        Message::CodedUpdate(mut u) => {
            u.num_samples = u.num_samples * 2 + 1;
            Message::CodedUpdate(u)
        }
        other => other,
    }
}

/// `oversize`: reply with a payload the frame layer must refuse to encode
/// (one tensor of MAX_FRAME / 4 + 1 floats exceeds the frame cap by
/// construction, before headers).
fn oversize_message(msg: Message) -> Message {
    let (client_id, num_samples, train_loss) = update_identity(&msg);
    Message::DenseUpdate(DenseUpdate {
        client_id,
        num_samples,
        tensors: vec![vec![0.0f32; MAX_FRAME / 4 + 1]],
        train_loss,
    })
}

/// A shuffled epoch order over a shard.
pub fn epoch_order(n: usize, rng: &mut Pcg) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(n: usize, dim: usize) -> ShardData {
        ShardData {
            dim,
            num_classes: 10,
            x: (0..n * dim).map(|i| i as f32).collect(),
            y: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    #[test]
    fn chunks_cover_all_samples_once() {
        let data = shard(100, 4);
        let order: Vec<u32> = (0..100).collect();
        let chunks = make_chunks(&data, &order, 8, 4); // cap 32
        assert_eq!(chunks.len(), 4); // 32+32+32+4
        let total: usize = chunks.iter().map(|c| c.samples).sum();
        assert_eq!(total, 100);
        // mask sums equal real sample counts
        for c in &chunks {
            let msum: f32 = c.ms.iter().sum();
            assert_eq!(msum as usize, c.samples);
        }
        // padded tail is zeros with zero mask
        let last = &chunks[3];
        assert_eq!(last.samples, 4);
        assert_eq!(last.ms[4], 0.0);
        assert!(last.xs[4 * 4..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn chunk_features_match_order() {
        let data = shard(10, 2);
        let order = vec![3u32, 7];
        let chunks = make_chunks(&data, &order, 2, 1);
        assert_eq!(chunks.len(), 1);
        assert_eq!(&chunks[0].xs[..2], &[6.0, 7.0]); // sample 3
        assert_eq!(chunks[0].ys[1], 7);
    }

    #[test]
    fn epoch_order_is_permutation() {
        let mut rng = Pcg::seeded(1);
        let mut o = epoch_order(50, &mut rng);
        o.sort_unstable();
        assert_eq!(o, (0..50).collect::<Vec<u32>>());
    }

    fn dense_msg(cid: u32, n: u64) -> Message {
        Message::DenseUpdate(DenseUpdate {
            client_id: cid,
            num_samples: n,
            tensors: vec![vec![1.0, 2.0, 3.0]],
            train_loss: 0.5,
        })
    }

    #[test]
    fn honest_tamper_is_identity() {
        let adv = ClientAdversary::honest();
        assert_eq!(adv.behavior_of(7), Behavior::Honest);
        let msg = dense_msg(3, 10);
        assert_eq!(adv.tamper(Behavior::Honest, msg.clone(), CodecSpec::Dense), msg);
        // statistical behaviors also leave the built message untouched
        assert_eq!(adv.tamper(Behavior::SignFlip, msg.clone(), CodecSpec::Dense), msg);
    }

    #[test]
    fn with_behavior_casts_every_registered_id() {
        let adv = ClientAdversary::with_behavior(Behavior::SignFlip);
        for rid in [0u32, 1, 99, 1_000_000] {
            assert_eq!(adv.behavior_of(rid), Behavior::SignFlip);
        }
    }

    #[test]
    fn replay_returns_previous_round_upload() {
        let adv = ClientAdversary::with_behavior(Behavior::Replay);
        let r1 = dense_msg(3, 10);
        let r2 = Message::DenseUpdate(DenseUpdate {
            client_id: 3,
            num_samples: 10,
            tensors: vec![vec![9.0, 9.0, 9.0]],
            train_loss: 0.1,
        });
        // first round has nothing staler than itself
        assert_eq!(adv.tamper(Behavior::Replay, r1.clone(), CodecSpec::Dense), r1);
        // second round replays the first
        assert_eq!(adv.tamper(Behavior::Replay, r2.clone(), CodecSpec::Dense), r1);
        // third round replays the second
        assert_eq!(adv.tamper(Behavior::Replay, dense_msg(3, 10), CodecSpec::Dense), r2);
    }

    #[test]
    fn corrupt_dense_drops_a_value() {
        let adv = ClientAdversary::honest();
        match adv.tamper(Behavior::CorruptFrame, dense_msg(1, 5), CodecSpec::Dense) {
            Message::DenseUpdate(u) => assert_eq!(u.tensors[0].len(), 2),
            other => panic!("unexpected kind {}", other.kind()),
        }
    }

    #[test]
    fn corrupt_ternary_breaks_wire_decode() {
        use crate::comms::{TernaryLayer, TernaryUpdate};
        use crate::compress::pack_ternary;
        let honest = Message::TernaryUpdate(TernaryUpdate {
            client_id: 2,
            num_samples: 7,
            layers: vec![TernaryLayer {
                param_index: 0,
                pattern: pack_ternary(&[1, -1, 0, 1, -1]),
                wq: 0.8,
                delta: 0.1,
            }],
            fp_tensors: vec![(1, vec![0.25, -0.5])],
            train_loss: 0.3,
        });
        assert!(Message::decode(&honest.encode()).is_ok());
        let adv = ClientAdversary::honest();
        let bad = adv.tamper(Behavior::CorruptFrame, honest, CodecSpec::Ternary);
        let err = Message::decode(&bad.encode()).unwrap_err().to_string();
        assert!(err.contains("inconsistent"), "got: {err}");
    }

    #[test]
    fn mislabel_swaps_codec_or_kind() {
        let adv = ClientAdversary::honest();
        let coded = Message::CodedUpdate(CodedUpdate {
            client_id: 4,
            num_samples: 6,
            train_loss: 0.2,
            update: CompressedUpdate { codec: CodecSpec::Fp16, tensors: vec![vec![0, 1]] },
        });
        match adv.tamper(Behavior::WrongCodec, coded, CodecSpec::Fp16) {
            Message::CodedUpdate(u) => assert_eq!(u.update.codec, CodecSpec::Dense),
            other => panic!("unexpected kind {}", other.kind()),
        }
        // a dense reply mutates into a whole different message kind
        match adv.tamper(Behavior::WrongCodec, dense_msg(4, 6), CodecSpec::Dense) {
            Message::CodedUpdate(u) => {
                assert_eq!(u.client_id, 4);
                assert_eq!(u.num_samples, 6);
            }
            other => panic!("unexpected kind {}", other.kind()),
        }
    }

    #[test]
    fn inflate_overreports_samples_only() {
        let adv = ClientAdversary::honest();
        match adv.tamper(Behavior::WrongSamples, dense_msg(5, 10), CodecSpec::Dense) {
            Message::DenseUpdate(u) => {
                assert_eq!(u.num_samples, 21);
                assert_eq!(u.tensors[0], vec![1.0, 2.0, 3.0]);
            }
            other => panic!("unexpected kind {}", other.kind()),
        }
    }

    #[test]
    fn oversize_exceeds_frame_cap() {
        let adv = ClientAdversary::honest();
        match adv.tamper(Behavior::Oversize, dense_msg(6, 4), CodecSpec::Dense) {
            Message::DenseUpdate(u) => {
                assert_eq!(u.client_id, 6);
                assert!(u.tensors[0].len() * 4 > MAX_FRAME);
            }
            other => panic!("unexpected kind {}", other.kind()),
        }
    }

    #[test]
    fn shard_from_dataset() {
        let ds = Dataset {
            dim: 3,
            num_classes: 10,
            features: (0..30).map(|i| i as f32).collect(),
            labels: (0..10).collect(),
        };
        let s = ShardData::from_dataset(&ds, &[2, 5]);
        assert_eq!(s.len(), 2);
        assert_eq!(&s.x[..3], &[6.0, 7.0, 8.0]);
        assert_eq!(s.y, vec![2, 5]);
    }
}
