//! Client-side logic: shard materialization, epoch-chunk batching, and the
//! protocol round handler (`ClientRuntime`) shared by the in-process
//! `Loopback` transport and the remote `tfed client` process.
//!
//! Train artifacts take fixed shapes [NB, B, dim]; a client shard of any
//! size is covered by shuffling, splitting into NB*B-sample chunks, and
//! zero-padding the tail with a {0,1} sample mask (the masked-loss graphs
//! make padding exact — see python/tests/test_train.py).

use anyhow::{anyhow, bail, Result};

use crate::comms::{
    dense_update, ternary_update, unpack_dequantize, CodedGlobal, CodedUpdate, DenseGlobal,
    Message, TernaryGlobal,
};
use crate::compress::{self, CodecSpec};
use crate::coordinator::backend::{Backend, TrainMode};
use crate::data::synth::Dataset;
use crate::model::ParamSet;
use crate::util::rng::Pcg;

/// A client's materialized local data (features copied out of the shared
/// dataset once, at setup).
#[derive(Clone, Debug)]
pub struct ShardData {
    pub dim: usize,
    pub num_classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<u32>,
}

impl ShardData {
    pub fn from_dataset(data: &Dataset, indices: &[u32]) -> ShardData {
        let mut x = Vec::with_capacity(indices.len() * data.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(data.sample(i as usize));
            y.push(data.labels[i as usize]);
        }
        ShardData { dim: data.dim, num_classes: data.num_classes, x, y }
    }

    pub fn whole(data: &Dataset) -> ShardData {
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        Self::from_dataset(data, &idx)
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// One padded chunk ready for a train/eval artifact call.
pub struct Chunk {
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    pub ms: Vec<f32>,
    /// real (unpadded) samples in this chunk
    pub samples: usize,
}

/// Split `order` (indices into `data`) into chunks of `nb * b` samples,
/// zero-padding the last chunk.
pub fn make_chunks(data: &ShardData, order: &[u32], b: usize, nb: usize) -> Vec<Chunk> {
    let cap = b * nb;
    let dim = data.dim;
    let mut chunks = Vec::with_capacity(order.len().div_ceil(cap));
    for chunk_idx in order.chunks(cap) {
        let mut xs = vec![0f32; cap * dim];
        let mut ys = vec![0i32; cap];
        let mut ms = vec![0f32; cap];
        for (slot, &i) in chunk_idx.iter().enumerate() {
            let i = i as usize;
            xs[slot * dim..(slot + 1) * dim]
                .copy_from_slice(&data.x[i * dim..(i + 1) * dim]);
            ys[slot] = data.y[i] as i32;
            ms[slot] = 1.0;
        }
        chunks.push(Chunk { xs, ys, ms, samples: chunk_idx.len() });
    }
    chunks
}

/// The client side of one protocol round: decode the broadcast, train
/// locally, quantize, encode the upload. One instance per client; the
/// `Loopback` transport holds them in-process, the `tfed client`
/// subcommand holds exactly one in its own process. Stateless across
/// rounds (all cross-round state travels in the messages), so a worker
/// pool may drive different clients concurrently.
pub struct ClientRuntime<'a> {
    pub client_id: u32,
    pub backend: &'a dyn Backend,
    pub shard: ShardData,
    pub local_epochs: usize,
    pub lr: f32,
    /// negotiated payload codec (from the experiment config); broadcasts
    /// and round assignments carrying any other codec are rejected
    pub codec: CodecSpec,
}

impl ClientRuntime<'_> {
    /// Handle one downstream broadcast; returns the upstream update.
    /// `rng` is the round-assigned generator (seeded by the server), so the
    /// result is independent of where or when this client runs.
    pub fn handle_round(&self, rng: &mut Pcg, down: &Message) -> Result<Message> {
        match down {
            Message::TernaryGlobal(g) => self.ternary_round(rng, g),
            Message::DenseGlobal(g) => self.dense_round(rng, g),
            Message::CodedGlobal(g) => self.coded_round(rng, g),
            other => bail!("client received upstream message kind {}", other.kind()),
        }
    }

    /// T-FedAvg (Algorithm 2): rebuild bare {-1,0,+1} latent weights + fp
    /// biases, train FTTQ from the broadcast w^q init, re-ternarize, upload.
    fn ternary_round(&self, rng: &mut Pcg, g: &TernaryGlobal) -> Result<Message> {
        let schema = self.backend.schema();
        let start = {
            crate::obs_span!("client.decode");
            let mut start = ParamSet::zeros(schema);
            for (i, packed) in &g.layers {
                let idx = *i as usize;
                let t = start
                    .tensors
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("broadcast layer index {idx} out of range"))?;
                let dense = unpack_dequantize(packed, 1.0)?;
                if dense.len() != t.data.len() {
                    bail!(
                        "broadcast layer {idx}: {} values for shape {:?}",
                        dense.len(),
                        t.shape
                    );
                }
                t.data = dense;
            }
            for (i, data) in &g.fp_tensors {
                let idx = *i as usize;
                let t = start
                    .tensors
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("broadcast tensor index {idx} out of range"))?;
                if data.len() != t.data.len() {
                    bail!(
                        "broadcast tensor {idx}: {} values for shape {:?}",
                        data.len(),
                        t.shape
                    );
                }
                t.data = data.clone();
            }
            start
        };
        let out = {
            crate::obs_span!("client.train");
            self.backend.train_local(
                &start,
                TrainMode::Fttq,
                &g.wq_init,
                &self.shard,
                self.local_epochs,
                self.lr,
                rng,
            )?
        };
        crate::obs_span!("client.encode");
        let (patterns, deltas) = self.backend.quantize(&out.params)?;
        let qidx = schema.quantized_indices();
        let upd = ternary_update(
            self.client_id,
            self.shard.len() as u64,
            &qidx,
            &patterns,
            &out.wq,
            &deltas,
            &out.params,
            out.mean_loss,
        );
        Ok(Message::TernaryUpdate(upd))
    }

    /// Registry-codec round (fp16 / quant / stc / generic ternary):
    /// decompress the broadcast, train full precision, compress the
    /// trained parameters with the same codec. Stochastic codecs draw
    /// from the round-assigned `rng` *after* training, so upload encoding
    /// is as reproducible as the training itself.
    fn coded_round(&self, rng: &mut Pcg, g: &CodedGlobal) -> Result<Message> {
        if g.update.codec != self.codec {
            bail!(
                "broadcast codec {} does not match negotiated codec {}",
                g.update.codec.name(),
                self.codec.name()
            );
        }
        let schema = self.backend.schema();
        let shapes: Vec<Vec<usize>> = schema.params.iter().map(|p| p.shape.clone()).collect();
        let codec = compress::build(self.codec)?;
        let start = {
            crate::obs_span!("client.decode");
            compress::decompress(codec.as_ref(), &g.update, &shapes)?
        };
        let out = {
            crate::obs_span!("client.train");
            self.backend.train_local(
                &start,
                TrainMode::Fp,
                &[],
                &self.shard,
                self.local_epochs,
                self.lr,
                rng,
            )?
        };
        crate::obs_span!("client.encode");
        let update = compress::compress(codec.as_ref(), &out.params, rng)?;
        Ok(Message::CodedUpdate(CodedUpdate {
            client_id: self.client_id,
            num_samples: self.shard.len() as u64,
            train_loss: out.mean_loss,
            update,
        }))
    }

    /// FedAvg: load the dense broadcast, train full precision, upload.
    fn dense_round(&self, rng: &mut Pcg, g: &DenseGlobal) -> Result<Message> {
        let schema = self.backend.schema();
        let start = {
            crate::obs_span!("client.decode");
            let mut start = ParamSet::zeros(schema);
            if g.tensors.len() != start.tensors.len() {
                bail!(
                    "broadcast has {} tensors, model wants {}",
                    g.tensors.len(),
                    start.tensors.len()
                );
            }
            for (t, data) in start.tensors.iter_mut().zip(&g.tensors) {
                if data.len() != t.data.len() {
                    bail!("broadcast tensor: {} values for shape {:?}", data.len(), t.shape);
                }
                t.data = data.clone();
            }
            start
        };
        let out = {
            crate::obs_span!("client.train");
            self.backend.train_local(
                &start,
                TrainMode::Fp,
                &[],
                &self.shard,
                self.local_epochs,
                self.lr,
                rng,
            )?
        };
        crate::obs_span!("client.encode");
        Ok(Message::DenseUpdate(dense_update(
            self.client_id,
            self.shard.len() as u64,
            &out.params,
            out.mean_loss,
        )))
    }
}

/// A shuffled epoch order over a shard.
pub fn epoch_order(n: usize, rng: &mut Pcg) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(n: usize, dim: usize) -> ShardData {
        ShardData {
            dim,
            num_classes: 10,
            x: (0..n * dim).map(|i| i as f32).collect(),
            y: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    #[test]
    fn chunks_cover_all_samples_once() {
        let data = shard(100, 4);
        let order: Vec<u32> = (0..100).collect();
        let chunks = make_chunks(&data, &order, 8, 4); // cap 32
        assert_eq!(chunks.len(), 4); // 32+32+32+4
        let total: usize = chunks.iter().map(|c| c.samples).sum();
        assert_eq!(total, 100);
        // mask sums equal real sample counts
        for c in &chunks {
            let msum: f32 = c.ms.iter().sum();
            assert_eq!(msum as usize, c.samples);
        }
        // padded tail is zeros with zero mask
        let last = &chunks[3];
        assert_eq!(last.samples, 4);
        assert_eq!(last.ms[4], 0.0);
        assert!(last.xs[4 * 4..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn chunk_features_match_order() {
        let data = shard(10, 2);
        let order = vec![3u32, 7];
        let chunks = make_chunks(&data, &order, 2, 1);
        assert_eq!(chunks.len(), 1);
        assert_eq!(&chunks[0].xs[..2], &[6.0, 7.0]); // sample 3
        assert_eq!(chunks[0].ys[1], 7);
    }

    #[test]
    fn epoch_order_is_permutation() {
        let mut rng = Pcg::seeded(1);
        let mut o = epoch_order(50, &mut rng);
        o.sort_unstable();
        assert_eq!(o, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn shard_from_dataset() {
        let ds = Dataset {
            dim: 3,
            num_classes: 10,
            features: (0..30).map(|i| i as f32).collect(),
            labels: (0..10).collect(),
        };
        let s = ShardData::from_dataset(&ds, &[2, 5]);
        assert_eq!(s.len(), 2);
        assert_eq!(&s.x[..3], &[6.0, 7.0, 8.0]);
        assert_eq!(s.y, vec![2, 5]);
    }
}
