//! Client-side data handling: shard materialization + epoch-chunk batching.
//!
//! Train artifacts take fixed shapes [NB, B, dim]; a client shard of any
//! size is covered by shuffling, splitting into NB*B-sample chunks, and
//! zero-padding the tail with a {0,1} sample mask (the masked-loss graphs
//! make padding exact — see python/tests/test_train.py).

use crate::data::synth::Dataset;
use crate::util::rng::Pcg;

/// A client's materialized local data (features copied out of the shared
/// dataset once, at setup).
#[derive(Clone, Debug)]
pub struct ShardData {
    pub dim: usize,
    pub num_classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<u32>,
}

impl ShardData {
    pub fn from_dataset(data: &Dataset, indices: &[u32]) -> ShardData {
        let mut x = Vec::with_capacity(indices.len() * data.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(data.sample(i as usize));
            y.push(data.labels[i as usize]);
        }
        ShardData { dim: data.dim, num_classes: data.num_classes, x, y }
    }

    pub fn whole(data: &Dataset) -> ShardData {
        let idx: Vec<u32> = (0..data.len() as u32).collect();
        Self::from_dataset(data, &idx)
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// One padded chunk ready for a train/eval artifact call.
pub struct Chunk {
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    pub ms: Vec<f32>,
    /// real (unpadded) samples in this chunk
    pub samples: usize,
}

/// Split `order` (indices into `data`) into chunks of `nb * b` samples,
/// zero-padding the last chunk.
pub fn make_chunks(data: &ShardData, order: &[u32], b: usize, nb: usize) -> Vec<Chunk> {
    let cap = b * nb;
    let dim = data.dim;
    let mut chunks = Vec::with_capacity(order.len().div_ceil(cap));
    for chunk_idx in order.chunks(cap) {
        let mut xs = vec![0f32; cap * dim];
        let mut ys = vec![0i32; cap];
        let mut ms = vec![0f32; cap];
        for (slot, &i) in chunk_idx.iter().enumerate() {
            let i = i as usize;
            xs[slot * dim..(slot + 1) * dim]
                .copy_from_slice(&data.x[i * dim..(i + 1) * dim]);
            ys[slot] = data.y[i] as i32;
            ms[slot] = 1.0;
        }
        chunks.push(Chunk { xs, ys, ms, samples: chunk_idx.len() });
    }
    chunks
}

/// A shuffled epoch order over a shard.
pub fn epoch_order(n: usize, rng: &mut Pcg) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(n: usize, dim: usize) -> ShardData {
        ShardData {
            dim,
            num_classes: 10,
            x: (0..n * dim).map(|i| i as f32).collect(),
            y: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    #[test]
    fn chunks_cover_all_samples_once() {
        let data = shard(100, 4);
        let order: Vec<u32> = (0..100).collect();
        let chunks = make_chunks(&data, &order, 8, 4); // cap 32
        assert_eq!(chunks.len(), 4); // 32+32+32+4
        let total: usize = chunks.iter().map(|c| c.samples).sum();
        assert_eq!(total, 100);
        // mask sums equal real sample counts
        for c in &chunks {
            let msum: f32 = c.ms.iter().sum();
            assert_eq!(msum as usize, c.samples);
        }
        // padded tail is zeros with zero mask
        let last = &chunks[3];
        assert_eq!(last.samples, 4);
        assert_eq!(last.ms[4], 0.0);
        assert!(last.xs[4 * 4..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn chunk_features_match_order() {
        let data = shard(10, 2);
        let order = vec![3u32, 7];
        let chunks = make_chunks(&data, &order, 2, 1);
        assert_eq!(chunks.len(), 1);
        assert_eq!(&chunks[0].xs[..2], &[6.0, 7.0]); // sample 3
        assert_eq!(chunks[0].ys[1], 7);
    }

    #[test]
    fn epoch_order_is_permutation() {
        let mut rng = Pcg::seeded(1);
        let mut o = epoch_order(50, &mut rng);
        o.sort_unstable();
        assert_eq!(o, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn shard_from_dataset() {
        let ds = Dataset {
            dim: 3,
            num_classes: 10,
            features: (0..30).map(|i| i as f32).collect(),
            labels: (0..10).collect(),
        };
        let s = ShardData::from_dataset(&ds, &[2, 5]);
        assert_eq!(s.len(), 2);
        assert_eq!(&s.x[..3], &[6.0, 7.0, 8.0]);
        assert_eq!(s.y, vec![2, 5]);
    }
}
