//! Compute backend abstraction: local training / eval / quantization.
//!
//! `PjrtBackend` drives the AOT HLO artifacts (the production path);
//! `NativeBackend` runs the pure-Rust mirror (fast coordinator tests, and
//! the cross-validation baseline for §Perf).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::client::{epoch_order, make_chunks, ShardData};
use crate::model::registry::{self, ModelDef};
use crate::model::{ModelSchema, ParamSet, Tensor};
use crate::native::{KernelPolicy, LayerGraph, Mode as NativeMode};
use crate::quant;
use crate::runtime::manifest::{Dtype, IoSpec};
use crate::runtime::{Engine, Value};
use crate::util::rng::Pcg;

/// Which local-training math to run (matches the artifact "mode").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    Fp,
    Fttq,
    Ttq,
}

impl TrainMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            TrainMode::Fp => "fp",
            TrainMode::Fttq => "fttq",
            TrainMode::Ttq => "ttq",
        }
    }
}

/// Result of one client's local round.
#[derive(Clone, Debug)]
pub struct LocalOutcome {
    pub params: ParamSet,
    /// fttq: trained w^q per quantized layer
    pub wq: Vec<f32>,
    /// ttq: trained factors (wp, wn) per quantized layer
    pub wp: Vec<f32>,
    pub wn: Vec<f32>,
    pub mean_loss: f32,
}

/// Local compute: E epochs of training, evaluation, upload quantization.
///
/// `Sync` is required because the concurrent round driver shares one
/// backend across transport worker threads (all methods take `&self`; the
/// native backend is stateless per call, and the PJRT engine is internally
/// synchronized).
pub trait Backend: Sync {
    fn schema(&self) -> &ModelSchema;
    fn t_k(&self) -> f32;
    fn wq_init(&self) -> f32;
    fn server_delta(&self) -> f32;

    /// Train `epochs` local epochs from `start`. `factors0` seeds the
    /// quantization factors: fttq wants L values (w^q per layer), ttq wants
    /// 2L (wp then wn); ignored for fp.
    fn train_local(
        &self,
        start: &ParamSet,
        mode: TrainMode,
        factors0: &[f32],
        data: &ShardData,
        epochs: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> Result<LocalOutcome>;

    /// FTTQ upload quantization of trained weights:
    /// -> (ternary pattern per quantized layer, delta per layer).
    fn quantize(&self, params: &ParamSet) -> Result<(Vec<Vec<i8>>, Vec<f32>)>;

    /// (mean CE loss, accuracy) of `params` on `data`.
    fn evaluate(&self, params: &ParamSet, data: &ShardData) -> Result<(f32, f32)>;
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Runs local training/eval through the compiled HLO artifacts.
pub struct PjrtBackend {
    engine: Arc<Engine>,
    model: String,
    schema: ModelSchema,
    batch: usize,
}

impl PjrtBackend {
    pub fn new(engine: Arc<Engine>, model: &str, batch: usize) -> Result<PjrtBackend> {
        let entry = engine.manifest.model(model)?;
        let schema = entry.schema.clone();
        // fail early if the batch size has no artifacts
        engine.manifest.train_artifact(model, "fttq", batch)?;
        Ok(PjrtBackend { engine, model: model.to_string(), schema, batch })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    fn opt_state_spec(&self, mode: TrainMode) -> Result<Vec<IoSpec>> {
        let entry = self.engine.manifest.model(&self.model)?;
        Ok(match mode {
            TrainMode::Fp => entry.opt_state_fp.clone(),
            TrainMode::Fttq => entry.opt_state_fttq.clone(),
            TrainMode::Ttq => entry.opt_state_ttq.clone(),
        })
    }

    fn zeros_for(spec: &[IoSpec]) -> Vec<Value> {
        spec.iter()
            .map(|s| match s.dtype {
                Dtype::F32 => Value::F32 {
                    shape: s.shape.clone(),
                    data: vec![0.0; s.numel()],
                },
                Dtype::S32 => Value::I32 {
                    shape: s.shape.clone(),
                    data: vec![0; s.numel()],
                },
            })
            .collect()
    }

    fn params_to_values(params: &ParamSet) -> Vec<Value> {
        params
            .tensors
            .iter()
            .map(|t| Value::F32 { shape: t.shape.clone(), data: t.data.clone() })
            .collect()
    }

    fn values_to_params(&self, values: &[Value]) -> Result<ParamSet> {
        let mut tensors = Vec::with_capacity(values.len());
        for (v, spec) in values.iter().zip(&self.schema.params) {
            tensors.push(Tensor::new(spec.shape.clone(), v.as_f32()?.to_vec())?);
        }
        Ok(ParamSet { tensors })
    }
}

impl Backend for PjrtBackend {
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }

    fn t_k(&self) -> f32 {
        self.engine.manifest.t_k
    }

    fn wq_init(&self) -> f32 {
        self.engine.manifest.wq_init
    }

    fn server_delta(&self) -> f32 {
        self.engine.manifest.server_delta
    }

    fn train_local(
        &self,
        start: &ParamSet,
        mode: TrainMode,
        factors0: &[f32],
        data: &ShardData,
        epochs: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> Result<LocalOutcome> {
        if data.is_empty() {
            bail!("client shard is empty");
        }
        let art = self
            .engine
            .manifest
            .train_artifact(&self.model, mode.as_str(), self.batch)?
            .clone();
        let (b, nb) = (art.batch, art.nb);
        let nq = self.schema.num_quantized();

        let n_params = self.schema.params.len();
        let mut params: Vec<Value> = Self::params_to_values(start);
        // factor values
        let mut factors: Vec<Value> = match mode {
            TrainMode::Fp => vec![],
            TrainMode::Fttq => {
                let f = if factors0.is_empty() {
                    vec![self.wq_init(); nq]
                } else {
                    factors0.to_vec()
                };
                if f.len() != nq {
                    bail!("fttq wants {nq} factors, got {}", f.len());
                }
                vec![Value::f32(vec![nq], f)?]
            }
            TrainMode::Ttq => {
                let f = if factors0.is_empty() {
                    vec![self.wq_init(); 2 * nq]
                } else {
                    factors0.to_vec()
                };
                if f.len() != 2 * nq {
                    bail!("ttq wants {} factors, got {}", 2 * nq, f.len());
                }
                vec![
                    Value::f32(vec![nq], f[..nq].to_vec())?,
                    Value::f32(vec![nq], f[nq..].to_vec())?,
                ]
            }
        };
        let n_factors = factors.len();
        let mut opt: Vec<Value> = Self::zeros_for(&self.opt_state_spec(mode)?);
        let n_opt = opt.len();

        let mut loss_acc = 0f64;
        let mut loss_n = 0f64;
        for _ in 0..epochs {
            let order = epoch_order(data.len(), rng);
            for chunk in make_chunks(data, &order, b, nb) {
                let mut inputs =
                    Vec::with_capacity(n_params + n_factors + n_opt + 4);
                inputs.extend(params.iter().cloned());
                inputs.extend(factors.iter().cloned());
                inputs.extend(opt.iter().cloned());
                inputs.push(Value::f32(vec![nb, b, data.dim], chunk.xs)?);
                inputs.push(Value::i32(vec![nb, b], chunk.ys)?);
                inputs.push(Value::f32(vec![nb, b], chunk.ms)?);
                inputs.push(Value::scalar_f32(lr));
                let out = self.engine.execute(&art.name, &inputs)?;
                let loss = out.last().unwrap().scalar()?;
                loss_acc += loss as f64 * chunk.samples as f64;
                loss_n += chunk.samples as f64;
                params = out[..n_params].to_vec();
                factors = out[n_params..n_params + n_factors].to_vec();
                opt = out[n_params + n_factors..n_params + n_factors + n_opt].to_vec();
            }
        }

        let params = self.values_to_params(&params)?;
        let (wq, wp, wn) = match mode {
            TrainMode::Fp => (vec![], vec![], vec![]),
            TrainMode::Fttq => (factors[0].as_f32()?.to_vec(), vec![], vec![]),
            TrainMode::Ttq => (
                vec![],
                factors[0].as_f32()?.to_vec(),
                factors[1].as_f32()?.to_vec(),
            ),
        };
        Ok(LocalOutcome {
            params,
            wq,
            wp,
            wn,
            mean_loss: (loss_acc / loss_n.max(1.0)) as f32,
        })
    }

    fn quantize(&self, params: &ParamSet) -> Result<(Vec<Vec<i8>>, Vec<f32>)> {
        let art = self.engine.manifest.quantize_artifact(&self.model)?.clone();
        let qidx = self.schema.quantized_indices();
        let inputs: Vec<Value> = qidx
            .iter()
            .map(|&i| {
                let t = &params.tensors[i];
                Value::f32(t.shape.clone(), t.data.clone())
            })
            .collect::<Result<_>>()?;
        let out = self.engine.execute(&art.name, &inputs)?;
        let mut patterns = Vec::with_capacity(qidx.len());
        let mut deltas = Vec::with_capacity(qidx.len());
        for k in 0..qidx.len() {
            let it_f32 = out[k].as_f32()?;
            patterns.push(
                it_f32
                    .iter()
                    .map(|&v| {
                        if v > 0.5 {
                            1i8
                        } else if v < -0.5 {
                            -1
                        } else {
                            0
                        }
                    })
                    .collect(),
            );
            deltas.push(out[qidx.len() + k].scalar()?);
        }
        Ok((patterns, deltas))
    }

    fn evaluate(&self, params: &ParamSet, data: &ShardData) -> Result<(f32, f32)> {
        let art = self.engine.manifest.eval_artifact(&self.model)?.clone();
        let (b, nb) = (art.batch, art.nb);
        let order: Vec<u32> = (0..data.len() as u32).collect();
        let base = Self::params_to_values(params);
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut count = 0f64;
        for chunk in make_chunks(data, &order, b, nb) {
            let mut inputs = base.clone();
            inputs.push(Value::f32(vec![nb, b, data.dim], chunk.xs)?);
            inputs.push(Value::i32(vec![nb, b], chunk.ys)?);
            inputs.push(Value::f32(vec![nb, b], chunk.ms)?);
            let out = self.engine.execute(&art.name, &inputs)?;
            loss_sum += out[0].scalar()? as f64;
            correct += out[1].scalar()? as f64;
            count += out[2].scalar()? as f64;
        }
        if count == 0.0 {
            bail!("evaluated zero samples");
        }
        Ok(((loss_sum / count) as f32, (correct / count) as f32))
    }
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Pure-Rust backend over the [`LayerGraph`] native core (fp, fttq, and
/// ttq modes; any registry model — `mlp`, `mlp-large`, `cnn` — or an
/// inferred dense graph from a (w, b)-paired schema).
pub struct NativeBackend {
    def: ModelDef,
    batch: usize,
    t_k: f32,
    wq_init: f32,
    server_delta: f32,
    policy: KernelPolicy,
}

impl NativeBackend {
    /// Infer a dense (+ReLU) graph from a (w, b)-paired schema. Rejects
    /// schemas whose bias shapes disagree with their weights (the seed
    /// trainer silently accepted them).
    pub fn new(schema: ModelSchema, batch: usize) -> Result<NativeBackend> {
        Ok(Self::from_def(registry::dense_from_schema(&schema)?, batch))
    }

    /// Look the model up in the native registry
    /// ([`crate::model::registry::MODEL_NAMES`]).
    pub fn for_model(model: &str, batch: usize) -> Result<NativeBackend> {
        Ok(Self::from_def(registry::model_def(model)?, batch))
    }

    /// Wrap an already-validated model definition.
    pub fn from_def(def: ModelDef, batch: usize) -> NativeBackend {
        NativeBackend {
            def,
            batch,
            t_k: 0.05,
            wq_init: 0.05,
            server_delta: 0.05,
            policy: default_policy(),
        }
    }

    /// Kernel execution policy (tier, thread count, reference loops).
    /// The fp tiers are bit-identical at every setting — only wall time
    /// moves. The packed tier computes on the 2-bit ternary cells and is
    /// deterministic against its own contract (DESIGN.md §15) but not
    /// byte-identical to the fp tiers.
    pub fn set_policy(&mut self, policy: KernelPolicy) {
        self.policy = policy;
    }

    fn net(&self, mode: TrainMode) -> Result<LayerGraph> {
        let m = match mode {
            TrainMode::Fp => NativeMode::Fp,
            TrainMode::Fttq => NativeMode::Fttq,
            TrainMode::Ttq => NativeMode::Ttq,
        };
        Ok(LayerGraph::from_def(&self.def, m, self.t_k, self.policy)?)
    }
}

impl Backend for NativeBackend {
    fn schema(&self) -> &ModelSchema {
        &self.def.schema
    }

    fn t_k(&self) -> f32 {
        self.t_k
    }

    fn wq_init(&self) -> f32 {
        self.wq_init
    }

    fn server_delta(&self) -> f32 {
        self.server_delta
    }

    fn train_local(
        &self,
        start: &ParamSet,
        mode: TrainMode,
        factors0: &[f32],
        data: &ShardData,
        epochs: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> Result<LocalOutcome> {
        if data.is_empty() {
            bail!("client shard is empty");
        }
        let net = self.net(mode)?;
        let nq = net.num_quantized();
        let want = net.factors_len();
        let mut params = start.clone();
        let mut factors = if factors0.is_empty() {
            vec![self.wq_init; want]
        } else {
            factors0.to_vec()
        };
        if factors.len() != want {
            bail!("{} wants {want} factors, got {}", mode.as_str(), factors.len());
        }
        let dim = data.dim;
        let mut loss_acc = 0f64;
        let mut loss_n = 0f64;
        for _ in 0..epochs {
            let order = epoch_order(data.len(), rng);
            for batch_idx in order.chunks(self.batch) {
                let n = batch_idx.len();
                let mut x = Vec::with_capacity(n * dim);
                let mut y = Vec::with_capacity(n);
                for &i in batch_idx {
                    let i = i as usize;
                    x.extend_from_slice(&data.x[i * dim..(i + 1) * dim]);
                    y.push(data.y[i]);
                }
                let loss = net.train_batch(&mut params, &mut factors, &x, &y, n, lr)?;
                loss_acc += loss as f64 * n as f64;
                loss_n += n as f64;
            }
        }
        let (wq, wp, wn) = match mode {
            TrainMode::Fp => (vec![], vec![], vec![]),
            TrainMode::Fttq => (factors, vec![], vec![]),
            TrainMode::Ttq => (vec![], factors[..nq].to_vec(), factors[nq..].to_vec()),
        };
        Ok(LocalOutcome {
            params,
            wq,
            wp,
            wn,
            mean_loss: (loss_acc / loss_n.max(1.0)) as f32,
        })
    }

    fn quantize(&self, params: &ParamSet) -> Result<(Vec<Vec<i8>>, Vec<f32>)> {
        let qidx = self.def.schema.quantized_indices();
        let mut patterns = Vec::new();
        let mut deltas = Vec::new();
        for &i in &qidx {
            let (it, d) = quant::fttq_quantize(&params.tensors[i].data, self.t_k);
            patterns.push(it);
            deltas.push(d);
        }
        Ok((patterns, deltas))
    }

    fn evaluate(&self, params: &ParamSet, data: &ShardData) -> Result<(f32, f32)> {
        // evaluation is always full-precision math over the given values,
        // streamed in training-batch-size chunks: per-sample math and the
        // f64 loss accumulation order are identical to one whole-set pass
        // (rows are independent in every kernel), but transient memory
        // stays O(batch) — a conv model over a 2k-sample test set would
        // otherwise materialize a ~50 MB whole-set im2col matrix
        let net = self.net(TrainMode::Fp)?;
        let n = data.len();
        let dim = data.dim;
        let mut loss = 0f64;
        let mut correct = 0usize;
        let mut i = 0;
        while i < n {
            let m = self.batch.min(n - i);
            net.evaluate_accumulate(
                params,
                &[],
                &data.x[i * dim..(i + m) * dim],
                &data.y[i..i + m],
                m,
                &mut loss,
                &mut correct,
            );
            i += m;
        }
        Ok(((loss / n as f64) as f32, correct as f32 / n as f32))
    }
}

/// Default native kernel policy: single-thread blocked fp kernels (the
/// round driver already fans worker threads out over clients, so nested
/// parallelism would oversubscribe). `TFED_KERNEL_TIER=<spec>` selects a
/// full tier spec (`naive | blocked[:N] | packed[:N] | packed-naive`,
/// see [`KernelPolicy::parse`]); the older `TFED_KERNEL_THREADS=N` opts
/// into row-parallel fp kernels only. The fp tiers change wall time
/// only — results stay bit-identical (DESIGN.md §10); the packed tier is
/// a different float-op order with its own determinism contract
/// (DESIGN.md §15).
fn default_policy() -> KernelPolicy {
    if let Ok(v) = std::env::var("TFED_KERNEL_TIER") {
        if let Ok(p) = KernelPolicy::parse(&v) {
            return p;
        }
    }
    if let Ok(v) = std::env::var("TFED_KERNEL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return KernelPolicy::threaded(n.max(1));
        }
    }
    KernelPolicy::default()
}

/// Build the backend named by the config. The native backend needs no
/// engine/artifacts — `model` is a native-registry name (`mlp`,
/// `mlp-large`, `cnn`); the PJRT path resolves it against the artifact
/// manifest instead.
pub fn make_backend(
    engine: Option<Arc<Engine>>,
    model: &str,
    batch: usize,
    native: bool,
) -> Result<Box<dyn Backend>> {
    make_backend_with_policy(engine, model, batch, native, None)
}

/// [`make_backend`] with an explicit kernel policy (CLI `--kernel`, the
/// scenario-manifest `kernel` key). `None` keeps the env-derived default.
/// An explicit policy is a native-kernel execution knob; asking the PJRT
/// backend to honor one is a config error, not a silent no-op.
pub fn make_backend_with_policy(
    engine: Option<Arc<Engine>>,
    model: &str,
    batch: usize,
    native: bool,
    policy: Option<KernelPolicy>,
) -> Result<Box<dyn Backend>> {
    if native {
        let mut b = NativeBackend::for_model(model, batch)?;
        if let Some(p) = policy {
            b.set_policy(p);
        }
        Ok(Box::new(b))
    } else {
        if policy.is_some() {
            bail!("kernel tier selection applies to the native backend only");
        }
        let engine = engine.ok_or_else(|| anyhow!("PJRT backend requires an engine"))?;
        Ok(Box::new(PjrtBackend::new(engine, model, batch)?))
    }
}
