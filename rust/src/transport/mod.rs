//! The network boundary: framed wire protocol, transports, link stats.
//!
//! The seed coordinator counted bytes at a simulated in-process boundary;
//! this subsystem moves the same `comms::Message` payloads through a real
//! message-framing layer so the paper's Table-IV numbers are measured on
//! actual wire traffic (see DESIGN.md §4):
//!
//! * `frame` — length-prefixed, CRC-checked frame codec with explicit
//!   `MAX_FRAME` bounds and typed truncation/corruption errors
//! * `stats` — per-link [`LinkStats`] (up/down bytes, frames, round trips)
//! * `loopback` — in-process transport over the same codec: deterministic,
//!   byte-for-byte identical accounting to TCP; the default for tests and
//!   the single-process orchestrator
//! * `tcp` — `std::net` transport, one threaded connection per client;
//!   powers the `tfed serve` / `tfed client` subcommands
//!
//! A third implementation lives in [`crate::sim`]: `SimTransport` wraps
//! `Loopback` (byte-identical payloads and `LinkStats`) and converts wire
//! bytes into virtual transfer times; it reports per-round simulated time
//! through [`Transport::end_round`], which real transports leave at the
//! default `None`.
//!
//! ## Protocol
//!
//! ```text
//! client                          server
//!   | -- Hello{client_id} --------> |       (registration)
//!   | <------- Config{cfg} -------- |       (experiment parameters)
//!   |                               |  per round, per selected client:
//!   | <- Assign{round,seed,codec} - |       (control)
//!   | <--- Data{TernaryGlobal} ---- |       (downstream payload)
//!   | ---- Data{TernaryUpdate} ---> |       (upstream payload)
//!   | <-------- Shutdown ---------- |       (experiment over)
//! ```
//!
//! The round assignment carries the server-derived RNG seed, so results are
//! bit-identical regardless of transport, worker-thread interleaving, or
//! process placement. It also names the round's payload codec
//! (`compress::CodecSpec`) — both ends verify it against their configured
//! codec before decoding a payload, so a codec mismatch is a clean
//! negotiation error, never silent garbage.

pub mod frame;
pub mod loopback;
pub mod stats;
pub mod tcp;

use anyhow::{bail, Result};

use crate::comms::messages::{Reader, Writer};
use crate::comms::Message;
use crate::compress::CodecSpec;
use crate::config::{ExperimentConfig, Protocol, Task};
use crate::coordinator::adversary::AdversarySpec;
use crate::coordinator::aggregation::AggregatorSpec;

pub use frame::{crc32, Frame, FrameError, FrameKind, HEADER_BYTES, MAX_FRAME};
pub use loopback::Loopback;
pub use stats::LinkStats;
pub use tcp::{TcpBinding, TcpClient, TcpTransport};

/// Per-round, per-client work order. `rng_seed`/`rng_stream` reproduce the
/// exact `Pcg` the sequential seed orchestrator would have forked, so a
/// remote client trains with the same randomness as an in-process one.
/// `codec` is the negotiated payload codec for this round's data frames —
/// both ends verify it against their configured codec before touching a
/// payload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundAssign {
    pub round: u32,
    pub client_id: u32,
    pub rng_seed: u64,
    pub rng_stream: u64,
    pub codec: CodecSpec,
}

/// Control-plane messages (everything that is not a model payload).
#[derive(Clone, Debug, PartialEq)]
pub enum Ctrl {
    Hello { client_id: u32 },
    Config(ExperimentConfig),
    Assign(RoundAssign),
    Shutdown,
}

impl Ctrl {
    pub fn to_frame(&self) -> Frame {
        let mut w = Writer::new();
        let kind = match self {
            Ctrl::Hello { client_id } => {
                w.u32(*client_id);
                FrameKind::Hello
            }
            Ctrl::Config(cfg) => {
                encode_config(&mut w, cfg);
                FrameKind::Config
            }
            Ctrl::Assign(a) => {
                w.u32(a.round);
                w.u32(a.client_id);
                w.u64(a.rng_seed);
                w.u64(a.rng_stream);
                w.bytes(&a.codec.to_wire());
                FrameKind::Assign
            }
            Ctrl::Shutdown => FrameKind::Shutdown,
        };
        Frame { kind, payload: w.into_bytes() }
    }

    pub fn from_frame(f: &Frame) -> Result<Ctrl> {
        let mut r = Reader::new(&f.payload);
        let ctrl = match f.kind {
            FrameKind::Hello => Ctrl::Hello { client_id: r.u32()? },
            FrameKind::Config => Ctrl::Config(decode_config(&mut r)?),
            FrameKind::Assign => Ctrl::Assign(RoundAssign {
                round: r.u32()?,
                client_id: r.u32()?,
                rng_seed: r.u64()?,
                rng_stream: r.u64()?,
                codec: CodecSpec::from_wire(
                    r.raw(CodecSpec::WIRE_BYTES)?.try_into().unwrap(),
                )?,
            }),
            FrameKind::Shutdown => Ctrl::Shutdown,
            FrameKind::Data => bail!("data frame is not a control message"),
        };
        if !r.exhausted() {
            bail!("trailing bytes in {:?} control frame", f.kind);
        }
        Ok(ctrl)
    }
}

fn encode_config(w: &mut Writer, cfg: &ExperimentConfig) {
    w.u8(match cfg.protocol {
        Protocol::Baseline => 0,
        Protocol::Ttq => 1,
        Protocol::FedAvg => 2,
        Protocol::TFedAvg => 3,
    });
    w.u8(match cfg.task {
        Task::MnistLike => 0,
        Task::CifarLike => 1,
    });
    w.u64(cfg.n_clients as u64);
    w.f64(cfg.participation);
    w.u64(cfg.nc as u64);
    w.f64(cfg.beta);
    w.f64(cfg.dirichlet_alpha);
    w.u64(cfg.batch as u64);
    w.u64(cfg.local_epochs as u64);
    w.u64(cfg.rounds as u64);
    w.f32(cfg.lr);
    w.u64(cfg.seed);
    w.u64(cfg.eval_every as u64);
    w.u64(cfg.train_samples as u64);
    w.u64(cfg.test_samples as u64);
    w.u8(cfg.native_backend as u8);
    w.bytes(&cfg.codec.to_wire());
    // model override: length-prefixed utf-8 (empty = task default)
    let model = cfg.model.as_bytes();
    w.u32(model.len() as u32);
    w.bytes(model);
    // frame version 3: aggregation rule + adversary assignment, so a
    // remote client resolves its own behavior from the same spec
    w.bytes(&cfg.aggregator.to_wire());
    w.bytes(&cfg.adversary.to_wire());
}

fn decode_config(r: &mut Reader) -> Result<ExperimentConfig> {
    let protocol = match r.u8()? {
        0 => Protocol::Baseline,
        1 => Protocol::Ttq,
        2 => Protocol::FedAvg,
        3 => Protocol::TFedAvg,
        k => bail!("unknown protocol tag {k}"),
    };
    let task = match r.u8()? {
        0 => Task::MnistLike,
        1 => Task::CifarLike,
        k => bail!("unknown task tag {k}"),
    };
    let n_clients = r.u64()? as usize;
    let participation = r.f64()?;
    let nc = r.u64()? as usize;
    let beta = r.f64()?;
    let dirichlet_alpha = r.f64()?;
    let batch = r.u64()? as usize;
    let local_epochs = r.u64()? as usize;
    let rounds = r.u64()? as usize;
    let lr = r.f32()?;
    let seed = r.u64()?;
    let eval_every = r.u64()? as usize;
    let train_samples = r.u64()? as usize;
    let test_samples = r.u64()? as usize;
    let native_backend = r.u8()? != 0;
    let codec = CodecSpec::from_wire(r.raw(CodecSpec::WIRE_BYTES)?.try_into().unwrap())?;
    let model_len = r.u32()? as usize;
    let model = String::from_utf8(r.raw(model_len)?.to_vec())
        .map_err(|_| anyhow::anyhow!("config model name is not valid utf-8"))?;
    let aggregator = AggregatorSpec::from_wire(
        r.raw(AggregatorSpec::WIRE_BYTES)?.try_into().unwrap(),
    )?;
    let adversary =
        AdversarySpec::from_wire(r.raw(AdversarySpec::WIRE_BYTES)?.try_into().unwrap())?;
    Ok(ExperimentConfig {
        protocol,
        task,
        n_clients,
        participation,
        nc,
        beta,
        dirichlet_alpha,
        batch,
        local_epochs,
        rounds,
        lr,
        seed,
        eval_every,
        train_samples,
        test_samples,
        native_backend,
        model,
        codec,
        aggregator,
        adversary,
    })
}

/// Encode a protocol message as one data frame's wire bytes. The round
/// driver calls this once per round and fans the same buffer out to every
/// selected client (broadcast payloads are identical per client, so
/// re-serializing per link would be pure waste).
pub fn encode_data_frame(msg: &Message) -> Result<Vec<u8>, FrameError> {
    Frame::data(msg.encode()).encode()
}

/// One round's simulated timing, reported by a virtual-time transport
/// (`sim::SimTransport`) at the round boundary. Real transports have no
/// virtual clock and report nothing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VirtualRoundTime {
    /// virtual duration of the round (last cohort arrival − round start)
    pub round_secs: f64,
    /// virtual clock after the round, seconds since the start of the run
    pub clock_secs: f64,
    /// total straggler delay injected this round (delay accounting), ms
    pub straggler_ms: u64,
}

/// Server-side view of the links to a fleet of clients.
///
/// Implementations must be callable from multiple round-driver worker
/// threads concurrently for *distinct* client ids (per-link interior
/// locking); per-client exchanges are strictly request/response.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::transport::{Loopback, Transport};
///
/// // attach `ClientRuntime`s for a live fleet; empty is a valid transport
/// let fleet = Loopback::new(Vec::new());
/// assert_eq!(fleet.n_clients(), 0);
/// assert_eq!(fleet.stats().up_bytes, 0);
/// ```
pub trait Transport: Sync {
    /// Number of reachable clients (ids `0..n_clients`).
    fn n_clients(&self) -> usize;

    /// One full exchange with client `cid`: deliver the round assignment
    /// and the downstream payload — `down_wire` is a pre-encoded data
    /// frame from [`encode_data_frame`] — and return the client's
    /// upstream payload.
    fn round_trip(&self, cid: usize, assign: &RoundAssign, down_wire: &[u8]) -> Result<Message>;

    /// Fleet-total stats (all links merged).
    fn stats(&self) -> LinkStats {
        let mut total = LinkStats::default();
        for s in self.link_stats() {
            total.merge(&s);
        }
        total
    }

    /// Per-link stats snapshot, indexed by client id.
    fn link_stats(&self) -> Vec<LinkStats>;

    /// Tell every client the experiment is over (no-op for loopback).
    fn shutdown(&self) -> Result<()>;

    /// Round boundary: a virtual-time transport drains its event queue,
    /// advances the clock, and returns the round's simulated timing.
    /// Real transports (loopback, TCP) run on the wall clock and return
    /// `None` — the default.
    fn end_round(&self, round: u32) -> Option<VirtualRoundTime> {
        let _ = round;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctrl_frames_roundtrip() {
        let cases = vec![
            Ctrl::Hello { client_id: 42 },
            Ctrl::Config(ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 7)),
            Ctrl::Config(
                ExperimentConfig::table2(Protocol::Baseline, Task::CifarLike, 1),
            ),
            Ctrl::Assign(RoundAssign {
                round: 3,
                client_id: 9,
                rng_seed: 0xDEAD_BEEF_0BAD_CAFE,
                rng_stream: 12345,
                codec: CodecSpec::Ternary,
            }),
            Ctrl::Assign(RoundAssign {
                round: 8,
                client_id: 0,
                rng_seed: 1,
                rng_stream: 2,
                codec: CodecSpec::Stc { k: 0.05 },
            }),
            Ctrl::Shutdown,
        ];
        for ctrl in cases {
            let f = ctrl.to_frame();
            assert!(f.kind.is_ctrl());
            let bytes = f.encode().unwrap();
            let back = Ctrl::from_frame(&Frame::decode(&bytes).unwrap()).unwrap();
            assert_eq!(back, ctrl);
        }
    }

    #[test]
    fn config_codec_preserves_every_field() {
        let mut cfg = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 99);
        cfg.n_clients = 17;
        cfg.participation = 0.31;
        cfg.nc = 3;
        cfg.beta = 0.45;
        cfg.dirichlet_alpha = 0.5;
        cfg.native_backend = true;
        cfg.model = "mlp-large".into();
        cfg.codec = CodecSpec::Quant { bits: 4 };
        cfg.aggregator = AggregatorSpec::TrimmedMean { beta: 0.15 };
        cfg.adversary = AdversarySpec::parse("scale:-4.5", 0.3, 0xBAD5EED).unwrap();
        let f = Ctrl::Config(cfg.clone()).to_frame();
        match Ctrl::from_frame(&f).unwrap() {
            Ctrl::Config(got) => assert_eq!(got, cfg),
            other => panic!("wrong ctrl {other:?}"),
        }
    }

    #[test]
    fn config_rejects_bad_aggregator_and_adversary_wire() {
        let cfg = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        let f = Ctrl::Config(cfg).to_frame();
        // the aggregator id byte sits right after the model length prefix
        // (empty model): flip it to an unknown rule id
        let agg_off = f.payload.len() - AggregatorSpec::WIRE_BYTES - AdversarySpec::WIRE_BYTES;
        let mut bad = f.clone();
        bad.payload[agg_off] = 200;
        assert!(Ctrl::from_frame(&bad).is_err());
        let mut bad = f.clone();
        bad.payload[agg_off + AggregatorSpec::WIRE_BYTES] = 200; // behavior id
        assert!(Ctrl::from_frame(&bad).is_err());
    }

    #[test]
    fn ctrl_rejects_garbage() {
        // truncated hello payload
        let f = Frame { kind: FrameKind::Hello, payload: vec![1, 2] };
        assert!(Ctrl::from_frame(&f).is_err());
        // trailing bytes
        let mut f = Ctrl::Hello { client_id: 1 }.to_frame();
        f.payload.push(0);
        assert!(Ctrl::from_frame(&f).is_err());
        // data frames are not control messages
        let f = Frame::data(vec![]);
        assert!(Ctrl::from_frame(&f).is_err());
        // unknown protocol tag
        let mut f = Ctrl::Config(ExperimentConfig::table2(
            Protocol::TFedAvg,
            Task::MnistLike,
            1,
        ))
        .to_frame();
        f.payload[0] = 9;
        assert!(Ctrl::from_frame(&f).is_err());
    }
}
