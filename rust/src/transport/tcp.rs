//! TCP transport over `std::net` — one connection per client, no external
//! dependencies.
//!
//! Server side: [`TcpBinding::bind`] grabs the listen socket (port 0 gives
//! an ephemeral port, reported by `local_addr`), then
//! [`TcpBinding::accept_clients`] blocks until every expected client has
//! registered with a `Hello` frame and been handed the serialized
//! `ExperimentConfig`. The resulting [`TcpTransport`] serves the round
//! driver: worker threads lock distinct per-client links and run strict
//! request/response exchanges.
//!
//! Client side: [`TcpClient::connect`] dials, registers, and receives the
//! config; [`TcpClient::serve`] then answers round assignments until the
//! server says `Shutdown`. Used by the `tfed client` subcommand and the
//! `tcp_round` example.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::comms::Message;
use crate::config::ExperimentConfig;
use crate::coordinator::client::ClientRuntime;
use crate::transport::frame::{Frame, FrameKind};
use crate::transport::stats::LinkStats;
use crate::transport::{Ctrl, RoundAssign, Transport};
use crate::util::rng::Pcg;
use crate::{info, warn};

// ---------------------------------------------------------------------------
// server side
// ---------------------------------------------------------------------------

/// How long a freshly-accepted connection gets to complete the
/// Hello/Config registration exchange before it is dropped.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// A bound-but-not-yet-populated listener. Splitting bind from accept lets
/// callers learn the ephemeral port before clients dial in.
pub struct TcpBinding {
    listener: TcpListener,
}

impl TcpBinding {
    pub fn bind(addr: &str) -> Result<TcpBinding> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TcpBinding { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept connections until all `n` client slots (ids `0..n`) are
    /// registered; each registrant is sent the experiment config. A peer
    /// that fails its handshake — including one that connects and then
    /// goes silent past [`HANDSHAKE_TIMEOUT`] — is dropped with a warning
    /// and the slot stays open for a retry, so one bad dialer can stall
    /// registration for at most the timeout, never poison the fleet.
    pub fn accept_clients(self, n: usize, cfg: &ExperimentConfig) -> Result<TcpTransport> {
        let mut slots: Vec<Option<(TcpStream, LinkStats)>> = Vec::new();
        slots.resize_with(n, || None);
        let mut filled = 0;
        while filled < n {
            let (stream, peer) = self.listener.accept()?;
            match Self::handshake(stream, n, cfg, &slots) {
                Ok((cid, stream, stats)) => {
                    slots[cid] = Some((stream, stats));
                    filled += 1;
                    info!("client {cid} registered from {peer} ({filled}/{n})");
                }
                Err(e) => warn!("rejected connection from {peer}: {e:#}"),
            }
        }
        Ok(TcpTransport {
            links: slots
                .into_iter()
                .map(|s| {
                    let (stream, stats) = s.expect("all slots filled");
                    Mutex::new(TcpLink { stream, stats })
                })
                .collect(),
        })
    }

    fn handshake(
        mut stream: TcpStream,
        n: usize,
        cfg: &ExperimentConfig,
        slots: &[Option<(TcpStream, LinkStats)>],
    ) -> Result<(usize, TcpStream, LinkStats)> {
        stream.set_nodelay(true).ok();
        // bound the registration exchange so a silent peer cannot wedge
        // the accept loop; the timeout comes off again for round traffic
        // (local training legitimately takes arbitrarily long)
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let mut stats = LinkStats::default();
        let hello = Frame::read_from(&mut stream)?;
        stats.record_ctrl(hello.wire_len());
        stats.record_frame(hello.kind, hello.wire_len());
        let cid = match Ctrl::from_frame(&hello)? {
            Ctrl::Hello { client_id } => client_id as usize,
            other => bail!("expected hello, got {other:?}"),
        };
        if cid >= n {
            bail!("client id {cid} out of range (expecting 0..{n})");
        }
        if slots[cid].is_some() {
            bail!("client id {cid} already registered");
        }
        let sent = Ctrl::Config(cfg.clone()).to_frame().write_to(&mut stream)?;
        stats.record_ctrl(sent);
        stats.record_frame(FrameKind::Config, sent);
        stream.set_read_timeout(None)?;
        Ok((cid, stream, stats))
    }
}

struct TcpLink {
    stream: TcpStream,
    stats: LinkStats,
}

/// Server-side `Transport` over one TCP connection per client.
pub struct TcpTransport {
    links: Vec<Mutex<TcpLink>>,
}

impl Transport for TcpTransport {
    fn n_clients(&self) -> usize {
        self.links.len()
    }

    fn round_trip(&self, cid: usize, assign: &RoundAssign, down_wire: &[u8]) -> Result<Message> {
        let link = self
            .links
            .get(cid)
            .ok_or_else(|| anyhow!("client {cid} not connected"))?;
        let mut link = link.lock().unwrap();
        let sent = Ctrl::Assign(*assign).to_frame().write_to(&mut link.stream)?;
        link.stats.record_ctrl(sent);
        link.stats.record_frame(FrameKind::Assign, sent);
        link.stream.write_all(down_wire)?;
        link.stats.record_down(down_wire.len());
        link.stats.record_frame(FrameKind::Data, down_wire.len());
        let reply = Frame::read_from(&mut link.stream)
            .with_context(|| format!("reading client {cid} reply"))?;
        if reply.kind != FrameKind::Data {
            bail!("client {cid}: expected data frame, got {:?}", reply.kind);
        }
        link.stats.record_up(reply.wire_len());
        link.stats.record_frame(FrameKind::Data, reply.wire_len());
        link.stats.record_round_trip();
        Ok(Message::decode(&reply.payload)?)
    }

    fn link_stats(&self) -> Vec<LinkStats> {
        self.links.iter().map(|l| l.lock().unwrap().stats).collect()
    }

    fn shutdown(&self) -> Result<()> {
        for (cid, link) in self.links.iter().enumerate() {
            let mut link = link.lock().unwrap();
            if let Err(e) = Ctrl::Shutdown.to_frame().write_to(&mut link.stream) {
                // a client that already hung up is not an error at teardown
                warn!("client {cid}: shutdown notify failed: {e}");
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// client side
// ---------------------------------------------------------------------------

/// One client's connection to the coordinator.
pub struct TcpClient {
    stream: TcpStream,
    client_id: u32,
    pub stats: LinkStats,
}

impl TcpClient {
    /// Dial the coordinator, register as `client_id`, receive the
    /// experiment config the fleet is running.
    pub fn connect(addr: &str, client_id: u32) -> Result<(TcpClient, ExperimentConfig)> {
        let mut stream =
            TcpStream::connect(addr).with_context(|| format!("dialing {addr}"))?;
        stream.set_nodelay(true).ok();
        let mut stats = LinkStats::default();
        let sent = Ctrl::Hello { client_id }.to_frame().write_to(&mut stream)?;
        stats.record_ctrl(sent);
        stats.record_frame(FrameKind::Hello, sent);
        let f = Frame::read_from(&mut stream)?;
        stats.record_ctrl(f.wire_len());
        stats.record_frame(f.kind, f.wire_len());
        let cfg = match Ctrl::from_frame(&f)? {
            Ctrl::Config(cfg) => cfg,
            other => bail!("expected config after hello, got {other:?}"),
        };
        Ok((TcpClient { stream, client_id, stats }, cfg))
    }

    /// Answer round assignments until the server sends `Shutdown`.
    /// Returns the number of rounds served.
    pub fn serve(&mut self, runtime: &ClientRuntime<'_>) -> Result<u64> {
        let mut pending: Option<RoundAssign> = None;
        let mut rounds = 0u64;
        loop {
            let f = Frame::read_from(&mut self.stream)?;
            match f.kind {
                FrameKind::Assign => {
                    self.stats.record_ctrl(f.wire_len());
                    self.stats.record_frame(FrameKind::Assign, f.wire_len());
                    let a = match Ctrl::from_frame(&f)? {
                        Ctrl::Assign(a) => a,
                        other => bail!("bad assign frame: {other:?}"),
                    };
                    if a.client_id != self.client_id {
                        bail!(
                            "assignment for client {} delivered to client {}",
                            a.client_id,
                            self.client_id
                        );
                    }
                    pending = Some(a);
                }
                FrameKind::Data => {
                    // server -> client is downstream from the link's view
                    self.stats.record_down(f.wire_len());
                    self.stats.record_frame(FrameKind::Data, f.wire_len());
                    let a = pending
                        .take()
                        .ok_or_else(|| anyhow!("data frame with no round assignment"))?;
                    if a.codec != runtime.codec {
                        bail!(
                            "round assigned codec {} but this client is configured for {}",
                            a.codec.name(),
                            runtime.codec.name()
                        );
                    }
                    let down = Message::decode(&f.payload)?;
                    let mut rng = Pcg::new(a.rng_seed, a.rng_stream);
                    let up = runtime.handle_round(&mut rng, a.client_id, &down)?;
                    let sent = {
                        crate::obs_span!("client.upload");
                        Frame::data(up.encode()).write_to(&mut self.stream)?
                    };
                    self.stats.record_up(sent);
                    self.stats.record_frame(FrameKind::Data, sent);
                    self.stats.record_round_trip();
                    rounds += 1;
                }
                FrameKind::Shutdown => {
                    self.stats.record_ctrl(f.wire_len());
                    self.stats.record_frame(FrameKind::Shutdown, f.wire_len());
                    return Ok(rounds);
                }
                kind => bail!("unexpected frame kind {kind:?} on client link"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::DenseGlobal;
    use crate::coordinator::backend::NativeBackend;
    use crate::coordinator::client::ShardData;
    use crate::model::{init_params, mlp_schema};

    #[test]
    fn handshake_round_trip_and_shutdown_over_localhost() {
        let binding = TcpBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap().to_string();
        let cfg = ExperimentConfig::table2(
            crate::config::Protocol::FedAvg,
            crate::config::Task::MnistLike,
            1,
        );
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();

        std::thread::scope(|s| {
            let client = s.spawn(|| {
                let (mut client, got_cfg) = TcpClient::connect(&addr, 0).unwrap();
                let mut rng = Pcg::seeded(7);
                let runtime = ClientRuntime {
                    client_id: 0,
                    backend: &backend,
                    shard: ShardData {
                        dim: 784,
                        num_classes: 10,
                        x: (0..784 * 8).map(|_| rng.normal() * 0.2).collect(),
                        y: (0..8).collect(),
                    },
                    local_epochs: 1,
                    lr: 0.05,
                    codec: got_cfg.codec,
                    adversary: Default::default(),
                };
                let rounds = client.serve(&runtime).unwrap();
                (got_cfg, rounds, client.stats)
            });

            let transport = binding.accept_clients(1, &cfg).unwrap();
            let schema = mlp_schema();
            let mut rng = Pcg::seeded(3);
            let params = init_params(&schema, &mut rng);
            let down = Message::DenseGlobal(DenseGlobal {
                round: 1,
                tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
            });
            let down_wire = crate::transport::encode_data_frame(&down).unwrap();
            let assign = RoundAssign {
                round: 1,
                client_id: 0,
                rng_seed: 5,
                rng_stream: 0,
                codec: cfg.codec,
            };
            let up = transport.round_trip(0, &assign, &down_wire).unwrap();
            assert!(matches!(up, Message::DenseUpdate(_)));
            transport.shutdown().unwrap();

            let (got_cfg, rounds, client_stats) = client.join().unwrap();
            assert_eq!(got_cfg, cfg);
            assert_eq!(rounds, 1);
            // both ends agree on the wire traffic (mirrored directions)
            let server_stats = transport.stats();
            assert_eq!(server_stats.down_bytes, client_stats.down_bytes);
            assert_eq!(server_stats.up_bytes, client_stats.up_bytes);
            assert!(server_stats.up_bytes > 0);
        });
    }

    #[test]
    fn out_of_range_hello_is_rejected_but_listener_survives() {
        let binding = TcpBinding::bind("127.0.0.1:0").unwrap();
        let addr = binding.local_addr().unwrap().to_string();
        let cfg = ExperimentConfig::table2(
            crate::config::Protocol::FedAvg,
            crate::config::Task::MnistLike,
            2,
        );
        std::thread::scope(|s| {
            s.spawn(|| {
                // bad client: id out of range — server must reject and keep going
                let bad = TcpClient::connect(&addr, 99);
                assert!(bad.is_err());
                // good client takes the slot afterwards
                let (_client, got) = TcpClient::connect(&addr, 0).unwrap();
                assert_eq!(got, cfg);
            });
            let transport = binding.accept_clients(1, &cfg).unwrap();
            assert_eq!(transport.n_clients(), 1);
        });
    }
}
