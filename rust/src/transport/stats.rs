//! Per-link traffic accounting — the transport-layer source of truth for
//! the paper's Table-IV communication numbers.
//!
//! Every byte is counted where it crosses (or, for `Loopback`, would
//! cross) the wire: full frame size, header included. Model payloads
//! (`FrameKind::Data`) land in the up/down counters the benches read;
//! control frames (hello, config, round assignment, shutdown) are tracked
//! separately so protocol overhead is visible but does not pollute the
//! compression-ratio measurements.
//!
//! On top of the directional counters, [`LinkStats::record_frame`] keeps
//! per-[`FrameKind`] frame counts and a log2 frame-size histogram, and
//! (only when obs is enabled) mirrors them into the `obs::metrics`
//! registry — `tfed_frames_total{kind=...}` and `tfed_frame_wire_bytes`.
//! The pre-existing fields and their accounting are untouched.

use crate::obs;
use crate::transport::frame::FrameKind;

/// Number of [`FrameKind`] variants (`kind_frames` index = `kind as u8 - 1`).
pub const FRAME_KINDS: usize = 5;

/// Log2 frame-size buckets: `MAX_FRAME` (64 MiB payload + header) has a
/// 27-bit wire length, so bucket indices 0..=27 cover every legal frame.
pub const FRAME_SIZE_BUCKETS: usize = 28;

/// Counters for one server<->client link. Directions are named from the
/// server's perspective: `up` = client -> server, `down` = server -> client.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// wire bytes of upstream data frames (header + payload)
    pub up_bytes: u64,
    /// wire bytes of downstream data frames
    pub down_bytes: u64,
    pub up_frames: u64,
    pub down_frames: u64,
    /// completed request/response exchanges
    pub round_trips: u64,
    /// wire bytes of control frames, both directions
    pub ctrl_bytes: u64,
    pub ctrl_frames: u64,
    /// frames by [`FrameKind`] (data, hello, config, assign, shutdown)
    pub kind_frames: [u64; FRAME_KINDS],
    /// frame wire sizes by bit length (bucket `k` = sizes of `k` bits)
    pub frame_size_log2: [u64; FRAME_SIZE_BUCKETS],
}

impl LinkStats {
    pub fn record_up(&mut self, wire_bytes: usize) {
        self.up_bytes += wire_bytes as u64;
        self.up_frames += 1;
    }

    pub fn record_down(&mut self, wire_bytes: usize) {
        self.down_bytes += wire_bytes as u64;
        self.down_frames += 1;
    }

    pub fn record_ctrl(&mut self, wire_bytes: usize) {
        self.ctrl_bytes += wire_bytes as u64;
        self.ctrl_frames += 1;
    }

    pub fn record_round_trip(&mut self) {
        self.round_trips += 1;
    }

    /// Per-kind frame accounting, called alongside the directional
    /// `record_*` for every frame that crosses the link. Feeds the obs
    /// registry when (and only when) observability is enabled.
    pub fn record_frame(&mut self, kind: FrameKind, wire_bytes: usize) {
        self.kind_frames[kind as usize - 1] += 1;
        self.frame_size_log2[size_bucket(wire_bytes)] += 1;
        if obs::enabled() {
            obs_record_frame(kind, wire_bytes);
        }
    }

    /// Fold another link's counters into this one (fleet totals).
    pub fn merge(&mut self, other: &LinkStats) {
        self.up_bytes += other.up_bytes;
        self.down_bytes += other.down_bytes;
        self.up_frames += other.up_frames;
        self.down_frames += other.down_frames;
        self.round_trips += other.round_trips;
        self.ctrl_bytes += other.ctrl_bytes;
        self.ctrl_frames += other.ctrl_frames;
        for (a, b) in self.kind_frames.iter_mut().zip(other.kind_frames.iter()) {
            *a += b;
        }
        for (a, b) in self.frame_size_log2.iter_mut().zip(other.frame_size_log2.iter()) {
            *a += b;
        }
    }

    /// Counter deltas since an earlier snapshot (per-round accounting).
    pub fn since(&self, mark: &LinkStats) -> LinkStats {
        LinkStats {
            up_bytes: self.up_bytes.saturating_sub(mark.up_bytes),
            down_bytes: self.down_bytes.saturating_sub(mark.down_bytes),
            up_frames: self.up_frames.saturating_sub(mark.up_frames),
            down_frames: self.down_frames.saturating_sub(mark.down_frames),
            round_trips: self.round_trips.saturating_sub(mark.round_trips),
            ctrl_bytes: self.ctrl_bytes.saturating_sub(mark.ctrl_bytes),
            ctrl_frames: self.ctrl_frames.saturating_sub(mark.ctrl_frames),
            kind_frames: std::array::from_fn(|i| {
                self.kind_frames[i].saturating_sub(mark.kind_frames[i])
            }),
            frame_size_log2: std::array::from_fn(|i| {
                self.frame_size_log2[i].saturating_sub(mark.frame_size_log2[i])
            }),
        }
    }

    /// All bytes moved over the link (data + control).
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes + self.ctrl_bytes
    }
}

/// Frame-size histogram bucket: bit length, capped at the top bucket.
fn size_bucket(wire_bytes: usize) -> usize {
    obs::metrics::bucket_index(wire_bytes as u64).min(FRAME_SIZE_BUCKETS - 1)
}

/// Registry mirror of `record_frame`; handles are resolved once and
/// cached so the per-frame cost is two relaxed atomic adds.
fn obs_record_frame(kind: FrameKind, wire_bytes: usize) {
    use crate::obs::metrics::{counter, histogram, Counter, Histogram};
    use std::sync::OnceLock;
    static HIST: OnceLock<&'static Histogram> = OnceLock::new();
    static KINDS: OnceLock<[&'static Counter; FRAME_KINDS]> = OnceLock::new();
    let hist = HIST.get_or_init(|| histogram("tfed_frame_wire_bytes"));
    let kinds = KINDS.get_or_init(|| {
        ["data", "hello", "config", "assign", "shutdown"]
            .map(|k| counter(&format!("tfed_frames_total{{kind=\"{k}\"}}")))
    });
    hist.observe(wire_bytes as u64);
    kinds[kind as usize - 1].inc();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut s = LinkStats::default();
        s.record_down(100);
        s.record_up(30);
        s.record_ctrl(14);
        s.record_round_trip();
        assert_eq!(s.down_bytes, 100);
        assert_eq!(s.up_bytes, 30);
        assert_eq!(s.ctrl_bytes, 14);
        assert_eq!((s.up_frames, s.down_frames, s.ctrl_frames), (1, 1, 1));
        assert_eq!(s.round_trips, 1);
        assert_eq!(s.total_bytes(), 144);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = LinkStats::default();
        a.record_up(10);
        let mut b = LinkStats::default();
        b.record_up(5);
        b.record_down(7);
        b.record_round_trip();
        a.merge(&b);
        assert_eq!(a.up_bytes, 15);
        assert_eq!(a.up_frames, 2);
        assert_eq!(a.down_bytes, 7);
        assert_eq!(a.round_trips, 1);
    }

    #[test]
    fn frame_kinds_and_sizes_accumulate() {
        let mut s = LinkStats::default();
        s.record_frame(FrameKind::Data, 100); // 7-bit wire length
        s.record_frame(FrameKind::Data, 30); // 5 bits
        s.record_frame(FrameKind::Assign, 14); // 4 bits
        assert_eq!(s.kind_frames[FrameKind::Data as usize - 1], 2);
        assert_eq!(s.kind_frames[FrameKind::Assign as usize - 1], 1);
        assert_eq!((s.frame_size_log2[4], s.frame_size_log2[5], s.frame_size_log2[7]), (1, 1, 1));
        // merge and since are elementwise over the new arrays
        let mark = s;
        s.record_frame(FrameKind::Shutdown, 14);
        assert_eq!(s.since(&mark).kind_frames, [0, 0, 0, 0, 1]);
        let mut t = LinkStats::default();
        t.merge(&s);
        assert_eq!(t.kind_frames, s.kind_frames);
        assert_eq!(t.frame_size_log2, s.frame_size_log2);
        // absurd sizes fold into the top bucket instead of indexing out
        let mut big = LinkStats::default();
        big.record_frame(FrameKind::Data, usize::MAX);
        assert_eq!(big.frame_size_log2[FRAME_SIZE_BUCKETS - 1], 1);
    }

    #[test]
    fn since_is_delta() {
        let mut s = LinkStats::default();
        s.record_up(10);
        let mark = s;
        s.record_up(25);
        s.record_down(40);
        let d = s.since(&mark);
        assert_eq!(d.up_bytes, 25);
        assert_eq!(d.up_frames, 1);
        assert_eq!(d.down_bytes, 40);
        assert_eq!(s.since(&s), LinkStats::default());
    }
}
