//! Per-link traffic accounting — the transport-layer source of truth for
//! the paper's Table-IV communication numbers.
//!
//! Every byte is counted where it crosses (or, for `Loopback`, would
//! cross) the wire: full frame size, header included. Model payloads
//! (`FrameKind::Data`) land in the up/down counters the benches read;
//! control frames (hello, config, round assignment, shutdown) are tracked
//! separately so protocol overhead is visible but does not pollute the
//! compression-ratio measurements.

/// Counters for one server<->client link. Directions are named from the
/// server's perspective: `up` = client -> server, `down` = server -> client.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// wire bytes of upstream data frames (header + payload)
    pub up_bytes: u64,
    /// wire bytes of downstream data frames
    pub down_bytes: u64,
    pub up_frames: u64,
    pub down_frames: u64,
    /// completed request/response exchanges
    pub round_trips: u64,
    /// wire bytes of control frames, both directions
    pub ctrl_bytes: u64,
    pub ctrl_frames: u64,
}

impl LinkStats {
    pub fn record_up(&mut self, wire_bytes: usize) {
        self.up_bytes += wire_bytes as u64;
        self.up_frames += 1;
    }

    pub fn record_down(&mut self, wire_bytes: usize) {
        self.down_bytes += wire_bytes as u64;
        self.down_frames += 1;
    }

    pub fn record_ctrl(&mut self, wire_bytes: usize) {
        self.ctrl_bytes += wire_bytes as u64;
        self.ctrl_frames += 1;
    }

    pub fn record_round_trip(&mut self) {
        self.round_trips += 1;
    }

    /// Fold another link's counters into this one (fleet totals).
    pub fn merge(&mut self, other: &LinkStats) {
        self.up_bytes += other.up_bytes;
        self.down_bytes += other.down_bytes;
        self.up_frames += other.up_frames;
        self.down_frames += other.down_frames;
        self.round_trips += other.round_trips;
        self.ctrl_bytes += other.ctrl_bytes;
        self.ctrl_frames += other.ctrl_frames;
    }

    /// Counter deltas since an earlier snapshot (per-round accounting).
    pub fn since(&self, mark: &LinkStats) -> LinkStats {
        LinkStats {
            up_bytes: self.up_bytes.saturating_sub(mark.up_bytes),
            down_bytes: self.down_bytes.saturating_sub(mark.down_bytes),
            up_frames: self.up_frames.saturating_sub(mark.up_frames),
            down_frames: self.down_frames.saturating_sub(mark.down_frames),
            round_trips: self.round_trips.saturating_sub(mark.round_trips),
            ctrl_bytes: self.ctrl_bytes.saturating_sub(mark.ctrl_bytes),
            ctrl_frames: self.ctrl_frames.saturating_sub(mark.ctrl_frames),
        }
    }

    /// All bytes moved over the link (data + control).
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes + self.ctrl_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut s = LinkStats::default();
        s.record_down(100);
        s.record_up(30);
        s.record_ctrl(14);
        s.record_round_trip();
        assert_eq!(s.down_bytes, 100);
        assert_eq!(s.up_bytes, 30);
        assert_eq!(s.ctrl_bytes, 14);
        assert_eq!((s.up_frames, s.down_frames, s.ctrl_frames), (1, 1, 1));
        assert_eq!(s.round_trips, 1);
        assert_eq!(s.total_bytes(), 144);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = LinkStats::default();
        a.record_up(10);
        let mut b = LinkStats::default();
        b.record_up(5);
        b.record_down(7);
        b.record_round_trip();
        a.merge(&b);
        assert_eq!(a.up_bytes, 15);
        assert_eq!(a.up_frames, 2);
        assert_eq!(a.down_bytes, 7);
        assert_eq!(a.round_trips, 1);
    }

    #[test]
    fn since_is_delta() {
        let mut s = LinkStats::default();
        s.record_up(10);
        let mark = s;
        s.record_up(25);
        s.record_down(40);
        let d = s.since(&mark);
        assert_eq!(d.up_bytes, 25);
        assert_eq!(d.up_frames, 1);
        assert_eq!(d.down_bytes, 40);
        assert_eq!(s.since(&s), LinkStats::default());
    }
}
