//! In-process transport: the client fleet lives behind channels-free
//! mutex-guarded endpoints, but every payload still runs through the frame
//! codec, so data-frame accounting and failure behavior are identical to
//! TCP — `Loopback` and `Tcp` report the same up/down bytes and frames
//! for the same run. (Ctrl counters differ by design: TCP additionally
//! records the one-time Hello/Config handshake and the Shutdown frame,
//! which have no loopback equivalent.)
//!
//! This is the default transport for `Orchestrator::new` (tests, benches,
//! the single-process CLI) and the determinism reference the TCP
//! integration test compares against.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::comms::Message;
use crate::coordinator::client::ClientRuntime;
use crate::transport::frame::{Frame, FrameKind};
use crate::transport::stats::LinkStats;
use crate::transport::{Ctrl, RoundAssign, Transport};
use crate::util::rng::Pcg;

struct Link<'a> {
    runtime: ClientRuntime<'a>,
    stats: LinkStats,
}

/// In-process `Transport` over the full frame codec.
pub struct Loopback<'a> {
    links: Vec<Mutex<Link<'a>>>,
}

impl<'a> Loopback<'a> {
    /// One link per client runtime; client ids are the vector positions
    /// (runtime `client_id` fields must agree).
    pub fn new(runtimes: Vec<ClientRuntime<'a>>) -> Loopback<'a> {
        Loopback {
            links: runtimes
                .into_iter()
                .map(|runtime| Mutex::new(Link { runtime, stats: LinkStats::default() }))
                .collect(),
        }
    }

    /// One full exchange, additionally reporting the upstream data
    /// frame's wire length (header included — the same number the link's
    /// `LinkStats` records). The sim transport feeds it to the bandwidth
    /// model without re-serializing the reply.
    pub fn round_trip_measured(
        &self,
        cid: usize,
        assign: &RoundAssign,
        down_wire: &[u8],
    ) -> Result<(Message, usize)> {
        let link = self
            .links
            .get(cid)
            .ok_or_else(|| anyhow!("client {cid} not attached to loopback"))?;
        let mut link = link.lock().unwrap();

        // the round assignment crosses the "wire" like any control frame
        let abytes = Ctrl::Assign(*assign).to_frame().encode()?;
        link.stats.record_ctrl(abytes.len());
        link.stats.record_frame(FrameKind::Assign, abytes.len());
        let assign = match Ctrl::from_frame(&Frame::decode(&abytes)?)? {
            Ctrl::Assign(a) => a,
            other => bail!("expected assign frame, got {other:?}"),
        };
        if assign.codec != link.runtime.codec {
            bail!(
                "round assigned codec {} but client {cid} is configured for {}",
                assign.codec.name(),
                link.runtime.codec.name()
            );
        }

        // downstream payload arrives as prebuilt frame bytes, decoded at
        // the "client" exactly as the TCP path would
        link.stats.record_down(down_wire.len());
        link.stats.record_frame(FrameKind::Data, down_wire.len());
        let received = Frame::decode(down_wire)?;
        if received.kind != FrameKind::Data {
            bail!("expected data frame downstream");
        }
        let down = Message::decode(&received.payload)?;

        // client-side work with the server-assigned RNG
        let mut rng = Pcg::new(assign.rng_seed, assign.rng_stream);
        let up = link.runtime.handle_round(&mut rng, assign.client_id, &down)?;

        // upstream payload back through the codec
        crate::obs_span!("client.upload");
        let ubytes = Frame::data(up.encode()).encode()?;
        link.stats.record_up(ubytes.len());
        link.stats.record_frame(FrameKind::Data, ubytes.len());
        let up = Message::decode(&Frame::decode(&ubytes)?.payload)?;
        link.stats.record_round_trip();
        Ok((up, ubytes.len()))
    }
}

impl Transport for Loopback<'_> {
    fn n_clients(&self) -> usize {
        self.links.len()
    }

    fn round_trip(&self, cid: usize, assign: &RoundAssign, down_wire: &[u8]) -> Result<Message> {
        self.round_trip_measured(cid, assign, down_wire).map(|(up, _)| up)
    }

    fn link_stats(&self) -> Vec<LinkStats> {
        self.links.iter().map(|l| l.lock().unwrap().stats).collect()
    }

    fn shutdown(&self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::DenseGlobal;
    use crate::compress::CodecSpec;
    use crate::coordinator::backend::NativeBackend;
    use crate::coordinator::client::ShardData;
    use crate::model::{init_params, mlp_schema};
    use crate::transport::encode_data_frame;
    use crate::transport::frame::HEADER_BYTES;

    fn tiny_shard(seed: u64, n: usize) -> ShardData {
        let mut rng = Pcg::seeded(seed);
        ShardData {
            dim: 784,
            num_classes: 10,
            x: (0..n * 784).map(|_| rng.normal() * 0.3).collect(),
            y: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    fn dense_broadcast(seed: u64) -> Message {
        let schema = mlp_schema();
        let mut rng = Pcg::seeded(seed);
        let params = init_params(&schema, &mut rng);
        Message::DenseGlobal(DenseGlobal {
            round: 1,
            tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
        })
    }

    fn assign(cid: u32) -> RoundAssign {
        RoundAssign {
            round: 1,
            client_id: cid,
            rng_seed: 99,
            rng_stream: cid as u64,
            codec: CodecSpec::Dense,
        }
    }

    #[test]
    fn round_trip_counts_frames_and_bytes() {
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
        let lb = Loopback::new(vec![ClientRuntime {
            client_id: 0,
            backend: &backend,
            shard: tiny_shard(1, 16),
            local_epochs: 1,
            lr: 0.05,
            codec: CodecSpec::Dense,
            adversary: Default::default(),
        }]);
        let down = dense_broadcast(2);
        let wire = encode_data_frame(&down).unwrap();
        let up = lb.round_trip(0, &assign(0), &wire).unwrap();
        let s = lb.stats();
        assert_eq!(s.down_bytes as usize, wire.len());
        assert_eq!(wire.len(), down.encode().len() + HEADER_BYTES);
        assert_eq!(s.up_bytes as usize, up.encode().len() + HEADER_BYTES);
        assert_eq!((s.up_frames, s.down_frames, s.round_trips), (1, 1, 1));
        assert!(s.ctrl_bytes > 0);
        // per-kind view agrees: two data frames (down + up), one assign
        assert_eq!(s.kind_frames[FrameKind::Data as usize - 1], 2);
        assert_eq!(s.kind_frames[FrameKind::Assign as usize - 1], 1);
        assert_eq!(s.frame_size_log2.iter().sum::<u64>(), 3);
        match up {
            Message::DenseUpdate(u) => {
                assert_eq!(u.client_id, 0);
                assert_eq!(u.num_samples, 16);
                assert!(u.train_loss.is_finite());
            }
            other => panic!("wrong reply {other:?}"),
        }
    }

    #[test]
    fn same_assignment_is_deterministic() {
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
        let mk = || {
            Loopback::new(vec![ClientRuntime {
                client_id: 0,
                backend: &backend,
                shard: tiny_shard(3, 12),
                local_epochs: 1,
                lr: 0.05,
                codec: CodecSpec::Dense,
                adversary: Default::default(),
            }])
        };
        let wire = encode_data_frame(&dense_broadcast(4)).unwrap();
        let a = mk().round_trip(0, &assign(0), &wire).unwrap();
        let b = mk().round_trip(0, &assign(0), &wire).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn unknown_client_is_clean_error() {
        let lb = Loopback::new(vec![]);
        let wire = encode_data_frame(&dense_broadcast(5)).unwrap();
        assert!(lb.round_trip(0, &assign(0), &wire).is_err());
    }
}
