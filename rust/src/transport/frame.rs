//! Length-prefixed, CRC-checked frame codec — the wire unit of the
//! transport layer.
//!
//! Wire layout (all integers little-endian):
//!
//! | offset | size | field                              |
//! |--------|------|------------------------------------|
//! | 0      | 4    | magic `0x4D524654` ("TFRM")        |
//! | 4      | 1    | version (currently 3)              |
//! | 5      | 1    | kind ([`FrameKind`])               |
//! | 6      | 4    | payload length (<= [`MAX_FRAME`])  |
//! | 10     | 4    | CRC-32 (IEEE) of the payload       |
//! | 14     | len  | payload                            |
//!
//! Data frames carry `comms::Message` bytes (which embed their own magic +
//! kind tag — defense in depth); control frames carry the small
//! [`super::Ctrl`] payloads that drive client registration and round
//! assignment. Every decode path returns a typed [`FrameError`] — never a
//! panic, never an unbounded allocation — so a corrupt or hostile peer
//! cannot take down the coordinator.

use std::fmt;
use std::io::{ErrorKind, Read, Write};

/// "TFRM" — distinct from the message-layer magic "TFED".
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"TFRM");
/// Bumped 1 -> 2 when the Config frame grew the model-override field and
/// 2 -> 3 when it grew the aggregator + adversary specs, so a
/// mixed-version server/client pairing fails the version check with a
/// clear error instead of a confusing trailing-bytes/short-read decode.
pub const FRAME_VERSION: u8 = 3;
/// Fixed header size: magic + version + kind + length + CRC.
pub const HEADER_BYTES: usize = 14;
/// Upper bound on one frame's payload. The largest legitimate payload is a
/// dense f32 model (~2.4 MB for the reduced ResNet); 64 MiB leaves room
/// for much bigger models while keeping a corrupt length from triggering a
/// giant allocation.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// A `comms::Message` (model payload — counted in up/down stats).
    Data = 1,
    /// Client registration: "I am client N".
    Hello = 2,
    /// Server -> client: the serialized `ExperimentConfig`.
    Config = 3,
    /// Server -> client: per-round assignment (round, client, RNG seed).
    Assign = 4,
    /// Server -> client: the experiment is over, disconnect.
    Shutdown = 5,
}

impl FrameKind {
    pub fn from_u8(k: u8) -> Option<FrameKind> {
        Some(match k {
            1 => FrameKind::Data,
            2 => FrameKind::Hello,
            3 => FrameKind::Config,
            4 => FrameKind::Assign,
            5 => FrameKind::Shutdown,
            _ => return None,
        })
    }

    /// Control frames are accounted separately from model payloads.
    pub fn is_ctrl(self) -> bool {
        !matches!(self, FrameKind::Data)
    }
}

/// Typed decode/IO errors. Corruption maps to a specific variant; nothing
/// in this module panics on wire input.
#[derive(Debug)]
pub enum FrameError {
    WrongMagic(u32),
    BadVersion(u8),
    UnknownKind(u8),
    /// Declared payload length exceeds [`MAX_FRAME`].
    Oversized { len: usize },
    /// Ran out of bytes before the declared end of the frame.
    Truncated { wanted: usize, got: usize },
    /// A complete frame decoded but the buffer has bytes after it.
    TrailingBytes { extra: usize },
    CrcMismatch { expected: u32, got: u32 },
    Io(std::io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::WrongMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            FrameError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            FrameError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Oversized { len } => {
                write!(f, "frame payload length {len} exceeds MAX_FRAME {MAX_FRAME}")
            }
            FrameError::Truncated { wanted, got } => {
                write!(f, "frame truncated: got {got} of {wanted} bytes")
            }
            FrameError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after frame")
            }
            FrameError::CrcMismatch { expected, got } => {
                write!(f, "frame CRC mismatch: header says {expected:#010x}, payload hashes to {got:#010x}")
            }
            FrameError::Io(e) => write!(f, "frame I/O error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320)
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 as used by Ethernet/zlib — detects any single-byte corruption.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Frame
// ---------------------------------------------------------------------------

/// One decoded frame: kind + owned payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

impl Frame {
    /// A data frame wrapping serialized `comms::Message` bytes.
    pub fn data(payload: Vec<u8>) -> Frame {
        Frame { kind: FrameKind::Data, payload }
    }

    /// Total bytes this frame occupies on the wire.
    pub fn wire_len(&self) -> usize {
        HEADER_BYTES + self.payload.len()
    }

    /// Serialize header + payload.
    pub fn encode(&self) -> Result<Vec<u8>, FrameError> {
        if self.payload.len() > MAX_FRAME {
            return Err(FrameError::Oversized { len: self.payload.len() });
        }
        let mut out = Vec::with_capacity(self.wire_len());
        out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        out.push(FRAME_VERSION);
        out.push(self.kind as u8);
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&self.payload).to_le_bytes());
        out.extend_from_slice(&self.payload);
        Ok(out)
    }

    /// Decode exactly one frame from `buf` (must contain the whole frame
    /// and nothing else — the in-memory path used by `Loopback` and tests).
    pub fn decode(buf: &[u8]) -> Result<Frame, FrameError> {
        if buf.len() < HEADER_BYTES {
            return Err(FrameError::Truncated { wanted: HEADER_BYTES, got: buf.len() });
        }
        let (kind, len, crc) = parse_header(buf[..HEADER_BYTES].try_into().unwrap())?;
        let total = HEADER_BYTES + len;
        if buf.len() < total {
            return Err(FrameError::Truncated { wanted: total, got: buf.len() });
        }
        if buf.len() > total {
            return Err(FrameError::TrailingBytes { extra: buf.len() - total });
        }
        let payload = &buf[HEADER_BYTES..];
        let got = crc32(payload);
        if got != crc {
            return Err(FrameError::CrcMismatch { expected: crc, got });
        }
        Ok(Frame { kind, payload: payload.to_vec() })
    }

    /// Write the frame to a stream; returns the wire bytes written.
    pub fn write_to(&self, w: &mut impl Write) -> Result<usize, FrameError> {
        let bytes = self.encode()?;
        w.write_all(&bytes)?;
        Ok(bytes.len())
    }

    /// Read exactly one frame from a stream. The length bound is checked
    /// *before* the payload allocation, so a corrupt header cannot force a
    /// huge buffer.
    pub fn read_from(r: &mut impl Read) -> Result<Frame, FrameError> {
        let mut head = [0u8; HEADER_BYTES];
        read_exact_counted(r, &mut head)?;
        let (kind, len, crc) = parse_header(head)?;
        let mut payload = vec![0u8; len];
        read_exact_counted(r, &mut payload)?;
        let got = crc32(&payload);
        if got != crc {
            return Err(FrameError::CrcMismatch { expected: crc, got });
        }
        Ok(Frame { kind, payload })
    }
}

/// Validate a header; returns (kind, payload length, expected CRC).
fn parse_header(head: [u8; HEADER_BYTES]) -> Result<(FrameKind, usize, u32), FrameError> {
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(FrameError::WrongMagic(magic));
    }
    if head[4] != FRAME_VERSION {
        return Err(FrameError::BadVersion(head[4]));
    }
    let kind = FrameKind::from_u8(head[5]).ok_or(FrameError::UnknownKind(head[5]))?;
    let len = u32::from_le_bytes(head[6..10].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::Oversized { len });
    }
    let crc = u32::from_le_bytes(head[10..14].try_into().unwrap());
    Ok((kind, len, crc))
}

/// `read_exact` that reports how many bytes arrived before EOF.
fn read_exact_counted(r: &mut impl Read, buf: &mut [u8]) -> Result<(), FrameError> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => return Err(FrameError::Truncated { wanted: buf.len(), got: off }),
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn crc32_reference_vector() {
        // the classic IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_all_kinds() {
        for kind in [
            FrameKind::Data,
            FrameKind::Hello,
            FrameKind::Config,
            FrameKind::Assign,
            FrameKind::Shutdown,
        ] {
            let f = Frame { kind, payload: vec![1, 2, 3, 250] };
            let bytes = f.encode().unwrap();
            assert_eq!(bytes.len(), f.wire_len());
            assert_eq!(Frame::decode(&bytes).unwrap(), f);
            // and through a stream
            let mut cur = Cursor::new(bytes);
            assert_eq!(Frame::read_from(&mut cur).unwrap(), f);
        }
    }

    #[test]
    fn empty_payload_roundtrips() {
        let f = Frame::data(vec![]);
        let bytes = f.encode().unwrap();
        assert_eq!(bytes.len(), HEADER_BYTES);
        assert_eq!(Frame::decode(&bytes).unwrap(), f);
    }

    #[test]
    fn every_truncation_errors() {
        let bytes = Frame::data(vec![9; 40]).encode().unwrap();
        for cut in 0..bytes.len() {
            let err = Frame::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, FrameError::Truncated { .. }),
                "cut={cut}: {err}"
            );
            let mut cur = Cursor::new(&bytes[..cut]);
            assert!(Frame::read_from(&mut cur).is_err(), "stream cut={cut}");
        }
    }

    #[test]
    fn every_byte_flip_errors() {
        let bytes = Frame::data((0..64u8).collect()).encode().unwrap();
        for pos in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[pos] ^= 0xFF;
            assert!(Frame::decode(&bad).is_err(), "flip at {pos} went undetected");
        }
    }

    #[test]
    fn specific_error_variants() {
        let good = Frame::data(vec![7; 8]).encode().unwrap();

        let mut bad = good.clone();
        bad[0] ^= 1;
        assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::WrongMagic(_)));

        let mut bad = good.clone();
        bad[4] = 9;
        assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::BadVersion(9)));

        let mut bad = good.clone();
        bad[5] = 77;
        assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::UnknownKind(77)));

        // oversized declared length: rejected before any allocation
        let mut bad = good.clone();
        bad[6..10].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::Oversized { .. }));
        let mut cur = Cursor::new(bad);
        assert!(matches!(
            Frame::read_from(&mut cur).unwrap_err(),
            FrameError::Oversized { .. }
        ));

        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::CrcMismatch { .. }));

        let mut bad = good.clone();
        bad.push(0);
        assert!(matches!(
            Frame::decode(&bad).unwrap_err(),
            FrameError::TrailingBytes { extra: 1 }
        ));

        // encode refuses oversized payloads outright
        let too_big = Frame::data(vec![0; MAX_FRAME + 1]);
        assert!(matches!(too_big.encode().unwrap_err(), FrameError::Oversized { .. }));
    }

    #[test]
    fn back_to_back_frames_stream() {
        let a = Frame::data(vec![1; 10]);
        let b = Frame { kind: FrameKind::Shutdown, payload: vec![] };
        let mut wire = Vec::new();
        a.write_to(&mut wire).unwrap();
        b.write_to(&mut wire).unwrap();
        let mut cur = Cursor::new(wire);
        assert_eq!(Frame::read_from(&mut cur).unwrap(), a);
        assert_eq!(Frame::read_from(&mut cur).unwrap(), b);
        assert!(Frame::read_from(&mut cur).is_err()); // clean EOF -> Truncated
    }
}
