//! Bit-granular writer/reader shared by the sub-byte codecs (STC's
//! Golomb–Rice streams, k-bit quantization cells).
//!
//! Bits are packed LSB-first within each byte. The reader is
//! hostile-input safe: reading past the end is a typed
//! [`CodecError::Truncated`], unary runs are explicitly bounded, and
//! [`BitReader::expect_zero_padding`] rejects streams whose final-byte
//! padding bits are non-zero — a corrupt-but-length-valid tail can never
//! decode silently.

use crate::compress::CodecError;

/// Append-only bit sink; `finish()` yields the zero-padded byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    out: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let slot = self.nbits % 8;
        if slot == 0 {
            self.out.push(0);
        }
        if bit {
            *self.out.last_mut().unwrap() |= 1 << slot;
        }
        self.nbits += 1;
    }

    /// Push the low `n` bits of `v`, LSB first.
    pub fn push_bits(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 32);
        for i in 0..n {
            self.push_bit((v >> i) & 1 == 1);
        }
    }

    /// Unary code: `q` one-bits terminated by a zero-bit.
    pub fn push_unary(&mut self, q: u32) {
        for _ in 0..q {
            self.push_bit(true);
        }
        self.push_bit(false);
    }

    pub fn bit_len(&self) -> usize {
        self.nbits
    }

    pub fn finish(self) -> Vec<u8> {
        self.out
    }
}

/// Sequential bit reader over a byte slice.
pub struct BitReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(b: &'a [u8]) -> BitReader<'a> {
        BitReader { b, pos: 0 }
    }

    fn len_bits(&self) -> usize {
        self.b.len() * 8
    }

    pub fn read_bit(&mut self) -> Result<bool, CodecError> {
        if self.pos >= self.len_bits() {
            return Err(CodecError::Truncated { wanted: self.pos + 1, got: self.len_bits() });
        }
        let bit = (self.b[self.pos / 8] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Ok(bit)
    }

    /// Read `n` bits, LSB first.
    pub fn read_bits(&mut self, n: u32) -> Result<u32, CodecError> {
        debug_assert!(n <= 32);
        let mut v = 0u32;
        for i in 0..n {
            if self.read_bit()? {
                v |= 1 << i;
            }
        }
        Ok(v)
    }

    /// Read a unary run of ones terminated by a zero. A run longer than
    /// `max` is corrupt (the caller knows a content-derived bound).
    pub fn read_unary(&mut self, max: u32) -> Result<u32, CodecError> {
        let mut q = 0u32;
        while self.read_bit()? {
            q += 1;
            if q > max {
                return Err(CodecError::Corrupt("unary run exceeds content bound"));
            }
        }
        Ok(q)
    }

    /// After all content is read: fewer than 8 bits may remain and every
    /// one of them must be zero.
    pub fn expect_zero_padding(&mut self) -> Result<(), CodecError> {
        if self.len_bits() - self.pos >= 8 {
            return Err(CodecError::Corrupt("trailing bytes after bitstream"));
        }
        while self.pos < self.len_bits() {
            if self.read_bit()? {
                return Err(CodecError::Corrupt("non-zero padding bits in final byte"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn bits_roundtrip() {
        forall(64, |rng| {
            let n = 1 + rng.below(200) as usize;
            let widths: Vec<u32> = (0..n).map(|_| 1 + rng.below(24)).collect();
            let vals: Vec<u32> = widths
                .iter()
                .map(|&w| rng.next_u32() & ((1u32 << w) - 1))
                .collect();
            let mut w = BitWriter::new();
            for (&v, &n) in vals.iter().zip(&widths) {
                w.push_bits(v, n);
            }
            let total = w.bit_len();
            let bytes = w.finish();
            assert_eq!(bytes.len(), total.div_ceil(8));
            let mut r = BitReader::new(&bytes);
            for (&v, &n) in vals.iter().zip(&widths) {
                assert_eq!(r.read_bits(n).unwrap(), v);
            }
            r.expect_zero_padding().unwrap();
        });
    }

    #[test]
    fn unary_roundtrip() {
        let mut w = BitWriter::new();
        for q in [0u32, 1, 7, 13, 100] {
            w.push_unary(q);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for q in [0u32, 1, 7, 13, 100] {
            assert_eq!(r.read_unary(1000).unwrap(), q);
        }
    }

    #[test]
    fn read_past_end_is_truncated() {
        let mut r = BitReader::new(&[0xFF]);
        assert_eq!(r.read_bits(8).unwrap(), 0xFF);
        assert!(matches!(r.read_bit(), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn unbounded_unary_is_corrupt_or_truncated() {
        // all-ones never terminates: must hit the bound, not spin
        let mut r = BitReader::new(&[0xFF, 0xFF]);
        assert!(matches!(r.read_unary(8), Err(CodecError::Corrupt(_))));
        // without the bound being hit first, the end of input reports
        let mut r = BitReader::new(&[0xFF]);
        assert!(matches!(r.read_unary(100), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn dirty_padding_rejected() {
        let mut w = BitWriter::new();
        w.push_bits(0b101, 3);
        let mut bytes = w.finish();
        bytes[0] |= 1 << 6; // set a padding bit
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert!(matches!(r.expect_zero_padding(), Err(CodecError::Corrupt(_))));
    }

    #[test]
    fn whole_trailing_byte_rejected() {
        let mut r = BitReader::new(&[0, 0]);
        assert!(matches!(r.expect_zero_padding(), Err(CodecError::Corrupt(_))));
    }
}
