//! Bit-granular writer/reader shared by the sub-byte codecs (STC's
//! Golomb–Rice streams, k-bit quantization cells).
//!
//! Bits are packed LSB-first within each byte. The reader is
//! hostile-input safe: reading past the end is a typed
//! [`CodecError::Truncated`], unary runs are explicitly bounded, and
//! [`BitReader::expect_zero_padding`] rejects streams whose final-byte
//! padding bits are non-zero — a corrupt-but-length-valid tail can never
//! decode silently.

use crate::compress::CodecError;

/// Append-only bit sink; `finish()` yields the zero-padded byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    out: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let slot = self.nbits % 8;
        if slot == 0 {
            self.out.push(0);
        }
        if bit {
            *self.out.last_mut().unwrap() |= 1 << slot;
        }
        self.nbits += 1;
    }

    /// Push the low `n` bits of `v`, LSB first. Chunked: the head merges
    /// into the current partial byte, the body lands whole bytes, the
    /// tail opens a new partial byte — no per-bit loop.
    pub fn push_bits(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 32);
        if n == 0 {
            return;
        }
        let mask = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
        let mut acc = (v & mask) as u64;
        let mut left = n as usize;
        let slot = self.nbits % 8;
        if slot != 0 {
            let take = (8 - slot).min(left);
            // bits of `acc` beyond the byte boundary fall off the u8 shift
            *self.out.last_mut().unwrap() |= (acc as u8) << slot;
            acc >>= take;
            left -= take;
            self.nbits += take;
        }
        while left >= 8 {
            self.out.push(acc as u8);
            acc >>= 8;
            left -= 8;
            self.nbits += 8;
        }
        if left > 0 {
            self.out.push(acc as u8);
            self.nbits += left;
        }
    }

    /// Unary code: `q` one-bits terminated by a zero-bit, emitted in
    /// 32-bit all-ones chunks.
    pub fn push_unary(&mut self, q: u32) {
        let mut left = q;
        while left >= 32 {
            self.push_bits(u32::MAX, 32);
            left -= 32;
        }
        if left > 0 {
            self.push_bits((1u32 << left) - 1, left);
        }
        self.push_bit(false);
    }

    pub fn bit_len(&self) -> usize {
        self.nbits
    }

    pub fn finish(self) -> Vec<u8> {
        self.out
    }
}

/// Sequential bit reader over a byte slice.
pub struct BitReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(b: &'a [u8]) -> BitReader<'a> {
        BitReader { b, pos: 0 }
    }

    fn len_bits(&self) -> usize {
        self.b.len() * 8
    }

    pub fn read_bit(&mut self) -> Result<bool, CodecError> {
        if self.pos >= self.len_bits() {
            return Err(CodecError::Truncated { wanted: self.pos + 1, got: self.len_bits() });
        }
        let bit = (self.b[self.pos / 8] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Ok(bit)
    }

    /// Read `n` bits, LSB first. Chunked: one windowed multi-byte load
    /// per call instead of `n` bit probes. Error behavior matches the
    /// per-bit loop exactly: a short read consumes the remaining bits and
    /// reports the first missing position.
    pub fn read_bits(&mut self, n: u32) -> Result<u32, CodecError> {
        debug_assert!(n <= 32);
        if n == 0 {
            return Ok(0);
        }
        let len = self.len_bits();
        if self.pos + n as usize > len {
            self.pos = len;
            return Err(CodecError::Truncated { wanted: len + 1, got: len });
        }
        let start = self.pos / 8;
        let off = self.pos % 8;
        // n <= 32 and off <= 7 => at most 5 source bytes, fits a u64
        let nbytes = (off + n as usize).div_ceil(8);
        let mut win = 0u64;
        for (i, &byte) in self.b[start..start + nbytes].iter().enumerate() {
            win |= (byte as u64) << (8 * i);
        }
        win >>= off;
        let mask = if n == 32 { u32::MAX as u64 } else { (1u64 << n) - 1 };
        self.pos += n as usize;
        Ok((win & mask) as u32)
    }

    /// Read a unary run of ones terminated by a zero. A run longer than
    /// `max` is corrupt (the caller knows a content-derived bound).
    /// Chunked: scans the run a byte at a time via trailing-ones counts,
    /// with the same consumed-bit positions and errors as the bit loop.
    pub fn read_unary(&mut self, max: u32) -> Result<u32, CodecError> {
        let mut q = 0u32;
        loop {
            let len = self.len_bits();
            if self.pos >= len {
                return Err(CodecError::Truncated { wanted: self.pos + 1, got: len });
            }
            let avail = (8 - self.pos % 8) as u32;
            // remaining bits of the current byte, shifted to bit 0; the
            // vacated high bits are zero so trailing-ones caps at `avail`
            let window = self.b[self.pos / 8] >> (self.pos % 8);
            let run = (!window).trailing_zeros().min(avail);
            if run > max - q {
                // the bit loop stops after consuming the (max+1)-th one
                self.pos += (max - q) as usize + 1;
                return Err(CodecError::Corrupt("unary run exceeds content bound"));
            }
            q += run;
            self.pos += run as usize;
            if run < avail {
                self.pos += 1; // the terminating zero bit
                return Ok(q);
            }
        }
    }

    /// After all content is read: fewer than 8 bits may remain and every
    /// one of them must be zero.
    pub fn expect_zero_padding(&mut self) -> Result<(), CodecError> {
        if self.len_bits() - self.pos >= 8 {
            return Err(CodecError::Corrupt("trailing bytes after bitstream"));
        }
        while self.pos < self.len_bits() {
            if self.read_bit()? {
                return Err(CodecError::Corrupt("non-zero padding bits in final byte"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn bits_roundtrip() {
        forall(64, |rng| {
            let n = 1 + rng.below(200) as usize;
            let widths: Vec<u32> = (0..n).map(|_| 1 + rng.below(24)).collect();
            let vals: Vec<u32> = widths
                .iter()
                .map(|&w| rng.next_u32() & ((1u32 << w) - 1))
                .collect();
            let mut w = BitWriter::new();
            for (&v, &n) in vals.iter().zip(&widths) {
                w.push_bits(v, n);
            }
            let total = w.bit_len();
            let bytes = w.finish();
            assert_eq!(bytes.len(), total.div_ceil(8));
            let mut r = BitReader::new(&bytes);
            for (&v, &n) in vals.iter().zip(&widths) {
                assert_eq!(r.read_bits(n).unwrap(), v);
            }
            r.expect_zero_padding().unwrap();
        });
    }

    #[test]
    fn unary_roundtrip() {
        let mut w = BitWriter::new();
        for q in [0u32, 1, 7, 13, 100] {
            w.push_unary(q);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for q in [0u32, 1, 7, 13, 100] {
            assert_eq!(r.read_unary(1000).unwrap(), q);
        }
    }

    #[test]
    fn read_past_end_is_truncated() {
        let mut r = BitReader::new(&[0xFF]);
        assert_eq!(r.read_bits(8).unwrap(), 0xFF);
        assert!(matches!(r.read_bit(), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn unbounded_unary_is_corrupt_or_truncated() {
        // all-ones never terminates: must hit the bound, not spin
        let mut r = BitReader::new(&[0xFF, 0xFF]);
        assert!(matches!(r.read_unary(8), Err(CodecError::Corrupt(_))));
        // without the bound being hit first, the end of input reports
        let mut r = BitReader::new(&[0xFF]);
        assert!(matches!(r.read_unary(100), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn dirty_padding_rejected() {
        let mut w = BitWriter::new();
        w.push_bits(0b101, 3);
        let mut bytes = w.finish();
        bytes[0] |= 1 << 6; // set a padding bit
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert!(matches!(r.expect_zero_padding(), Err(CodecError::Corrupt(_))));
    }

    #[test]
    fn whole_trailing_byte_rejected() {
        let mut r = BitReader::new(&[0, 0]);
        assert!(matches!(r.expect_zero_padding(), Err(CodecError::Corrupt(_))));
    }
}
