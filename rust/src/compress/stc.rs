//! Sparse ternary compression (Sattler et al., "Robust and
//! Communication-Efficient Federated Learning from Non-IID Data", §III).
//!
//! Per tensor: keep the top `k` fraction of elements by magnitude, replace
//! every survivor with ± mu where mu is the mean magnitude over the
//! selection, and ship (count, mu, positions, signs). Positions are
//! strictly increasing, so they are stored as index *gaps* under a
//! Golomb–Rice code whose parameter is fitted to the mean gap (≈ 1/k) and
//! carried in the header — the decoder never re-derives it.
//!
//! Payload layout (little-endian):
//!
//! | field   | size | meaning                                   |
//! |---------|------|-------------------------------------------|
//! | count   | 4    | selected elements (<= numel)              |
//! | mu      | 4    | mean magnitude of the selection (>= 0)    |
//! | rice_b  | 1    | Golomb–Rice remainder width in bits       |
//! | stream  | n    | count gaps (unary q + b-bit r), then count sign bits |
//!
//! The bitstream's final-byte padding must be zero — the decoder rejects
//! dirty tails just like the ternary codec does.

use crate::compress::bitio::{BitReader, BitWriter};
use crate::compress::{CodecError, CodecSpec, Compressor};
use crate::util::rng::Pcg;

const HEADER_BYTES: usize = 9;
/// Upper bound on the remainder width; gaps fit in u32 so anything larger
/// is nonsense from the wire.
const MAX_RICE_B: u8 = 31;

pub struct StcCodec {
    /// fraction of elements kept, in (0, 1]
    k: f64,
}

impl StcCodec {
    pub fn new(k: f64) -> StcCodec {
        StcCodec { k }
    }

    /// Elements kept for a tensor of `n` values (at least one).
    fn kept(&self, n: usize) -> usize {
        ((self.k * n as f64).round() as usize).clamp(1, n)
    }
}

/// Deterministic magnitude order: larger |value| first, ties by index —
/// independent of the selection algorithm's internal ordering.
fn mag_cmp(data: &[f32], a: u32, b: u32) -> std::cmp::Ordering {
    let (ma, mb) = (data[a as usize].abs(), data[b as usize].abs());
    mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
}

impl Compressor for StcCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Stc { k: self.k }
    }

    fn encode_tensor(&self, data: &[f32], _rng: &mut Pcg) -> Result<Vec<u8>, CodecError> {
        if data.iter().any(|v| !v.is_finite()) {
            return Err(CodecError::Corrupt("non-finite input tensor"));
        }
        let n = data.len();
        let mut out = Vec::new();
        if n == 0 {
            out.extend_from_slice(&0u32.to_le_bytes());
            out.extend_from_slice(&0f32.to_le_bytes());
            out.push(0);
            return Ok(out);
        }
        let kept = self.kept(n);

        // top-k by magnitude: O(n) select, then index order for gap coding
        let mut idx: Vec<u32> = (0..n as u32).collect();
        if kept < n {
            idx.select_nth_unstable_by(kept - 1, |&a, &b| mag_cmp(data, a, b));
            idx.truncate(kept);
        }
        idx.sort_unstable();

        let mu = (idx.iter().map(|&i| data[i as usize].abs() as f64).sum::<f64>()
            / kept as f64) as f32;

        // Rice parameter from the mean gap (~ n/kept); mean_gap >= 1
        let mean_gap = (n / kept).max(1);
        let b = ((usize::BITS - 1 - mean_gap.leading_zeros()) as u8).min(MAX_RICE_B);

        let mut bw = BitWriter::new();
        let mut prev: i64 = -1;
        for &i in &idx {
            let gap = (i as i64 - prev - 1) as u64;
            bw.push_unary((gap >> b) as u32);
            bw.push_bits((gap & ((1u64 << b) - 1)) as u32, b as u32);
            prev = i as i64;
        }
        for &i in &idx {
            // sign bit: 1 => +mu (zeros only arise in an all-zero tensor,
            // where mu is 0 and the sign is irrelevant)
            bw.push_bit(data[i as usize] >= 0.0);
        }

        out.extend_from_slice(&(kept as u32).to_le_bytes());
        out.extend_from_slice(&mu.to_le_bytes());
        out.push(b);
        out.extend_from_slice(&bw.finish());
        Ok(out)
    }

    fn decode_tensor(&self, bytes: &[u8], numel: usize) -> Result<Vec<f32>, CodecError> {
        if bytes.len() < HEADER_BYTES {
            return Err(CodecError::Truncated { wanted: HEADER_BYTES, got: bytes.len() });
        }
        let count = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let mu = f32::from_le_bytes(bytes[4..8].try_into().unwrap());
        let b = bytes[8];
        if count > numel || (numel > 0 && count == 0) {
            return Err(CodecError::Corrupt("selection count out of range"));
        }
        if !mu.is_finite() || mu < 0.0 {
            return Err(CodecError::Corrupt("non-finite or negative magnitude"));
        }
        if b > MAX_RICE_B {
            return Err(CodecError::Corrupt("rice parameter out of range"));
        }
        let mut out = vec![0f32; numel];
        if numel == 0 {
            if bytes.len() != HEADER_BYTES {
                return Err(CodecError::LengthMismatch {
                    expected: HEADER_BYTES,
                    got: bytes.len(),
                });
            }
            return Ok(out);
        }

        let mut br = BitReader::new(&bytes[HEADER_BYTES..]);
        let mut indices = Vec::with_capacity(count);
        let mut prev: i64 = -1;
        for _ in 0..count {
            // a gap can never exceed the tensor length, so its unary
            // quotient is bounded by numel >> b
            let q = br.read_unary((numel >> b) as u32 + 1)? as u64;
            let r = br.read_bits(b as u32)? as u64;
            let gap = (q << b) | r;
            let i = prev + 1 + gap as i64;
            if i >= numel as i64 {
                return Err(CodecError::Corrupt("position index out of range"));
            }
            indices.push(i as usize);
            prev = i;
        }
        for &i in &indices {
            out[i] = if br.read_bit()? { mu } else { -mu };
        }
        br.expect_zero_padding()?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn codec(k: f64) -> StcCodec {
        StcCodec::new(k)
    }

    #[test]
    fn roundtrip_preserves_topk_support_and_signs() {
        forall(64, |rng| {
            let n = 1 + rng.below(4000) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let c = codec(0.05);
            let kept = c.kept(n);
            let enc = c.encode_tensor(&v, rng).unwrap();
            let dec = c.decode_tensor(&enc, n).unwrap();

            let nonzero: Vec<usize> =
                (0..n).filter(|&i| dec[i] != 0.0).collect();
            assert!(nonzero.len() <= kept);
            // every survivor is exactly +-mu with the original sign
            let mu = f32::from_le_bytes(enc[4..8].try_into().unwrap());
            for &i in &nonzero {
                assert_eq!(dec[i].abs(), mu);
                assert_eq!(dec[i] >= 0.0, v[i] >= 0.0, "sign flipped at {i}");
            }
            // top-k property: min selected magnitude >= max dropped
            if nonzero.len() == kept && kept < n {
                let min_sel = nonzero
                    .iter()
                    .map(|&i| v[i].abs())
                    .fold(f32::INFINITY, f32::min);
                let max_drop = (0..n)
                    .filter(|i| dec[*i] == 0.0)
                    .map(|i| v[i].abs())
                    .fold(0.0f32, f32::max);
                assert!(min_sel >= max_drop, "{min_sel} < {max_drop}");
            }
        });
    }

    #[test]
    fn compresses_well_below_dense() {
        let mut rng = Pcg::seeded(3);
        let n = 20_000;
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let enc = codec(0.01).encode_tensor(&v, &mut rng).unwrap();
        // 1% density: dense is 80 KB; STC should land far below 1/10th
        assert!(enc.len() * 10 < n * 4, "stc payload {} bytes", enc.len());
    }

    #[test]
    fn k_one_keeps_everything() {
        let mut rng = Pcg::seeded(4);
        let v = vec![1.0f32, -2.0, 3.0, -4.0];
        let c = codec(1.0);
        let dec = c
            .decode_tensor(&c.encode_tensor(&v, &mut rng).unwrap(), 4)
            .unwrap();
        let mu = 2.5;
        assert_eq!(dec, vec![mu, -mu, mu, -mu]);
    }

    #[test]
    fn empty_and_all_zero_tensors() {
        let mut rng = Pcg::seeded(5);
        let c = codec(0.1);
        let enc = c.encode_tensor(&[], &mut rng).unwrap();
        assert_eq!(c.decode_tensor(&enc, 0).unwrap(), Vec::<f32>::new());
        let enc = c.encode_tensor(&[0.0; 7], &mut rng).unwrap();
        assert_eq!(c.decode_tensor(&enc, 7).unwrap(), vec![0.0; 7]);
    }

    #[test]
    fn corrupt_payloads_rejected_with_typed_errors() {
        let mut rng = Pcg::seeded(6);
        let v: Vec<f32> = (0..500).map(|_| rng.normal()).collect();
        let c = codec(0.05);
        let enc = c.encode_tensor(&v, &mut rng).unwrap();

        // truncations never panic
        for cut in 0..enc.len() {
            assert!(c.decode_tensor(&enc[..cut], v.len()).is_err(), "cut={cut}");
        }
        // count beyond numel
        let mut bad = enc.clone();
        bad[0..4].copy_from_slice(&(v.len() as u32 + 1).to_le_bytes());
        assert!(matches!(
            c.decode_tensor(&bad, v.len()),
            Err(CodecError::Corrupt(_))
        ));
        // negative / non-finite mu
        let mut bad = enc.clone();
        bad[4..8].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(matches!(
            c.decode_tensor(&bad, v.len()),
            Err(CodecError::Corrupt(_))
        ));
        // absurd rice parameter
        let mut bad = enc.clone();
        bad[8] = 200;
        assert!(matches!(
            c.decode_tensor(&bad, v.len()),
            Err(CodecError::Corrupt(_))
        ));
        // encoding a non-finite tensor is refused outright
        assert!(c.encode_tensor(&[1.0, f32::INFINITY], &mut rng).is_err());
    }

    #[test]
    fn bitflips_never_panic() {
        forall(32, |rng| {
            let n = 1 + rng.below(600) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let c = codec(0.05);
            let mut enc = c.encode_tensor(&v, rng).unwrap();
            let pos = rng.below(enc.len() as u32) as usize;
            enc[pos] ^= 1 << rng.below(8);
            // either a typed error or a well-formed tensor — never a panic
            if let Ok(dec) = c.decode_tensor(&enc, n) {
                assert_eq!(dec.len(), n);
            }
        });
    }
}
