//! Pluggable update-compression subsystem: a codec registry behind the
//! [`Compressor`] trait.
//!
//! The seed repo hardwired one compressed path — 2-bit ternary packing —
//! into the message layer. This subsystem turns payload compression into a
//! first-class axis of the experiment grid, so the paper's T-FedAvg
//! protocol can run head-to-head against the strongest competing codec
//! families under one measurement harness (ROADMAP: scenario diversity):
//!
//! * `ternary`  — the paper's 2-bit packing (§III-B), ported here from
//!   `comms/codec.rs`; also usable as a generic post-training codec.
//! * `stc`      — sparse ternary compression (Sattler et al. §III):
//!   magnitude top-k to a single ± mean-magnitude value, index gaps
//!   Golomb–Rice coded.
//! * `quant<k>` — stochastic uniform k-bit quantization (k in 1..=8),
//!   unbiased in expectation, driven by the server-seeded per-client
//!   `Pcg` so runs stay bit-reproducible at any worker count.
//! * `fp16` / `dense` — calibration baselines (half precision, raw f32).
//!
//! Every codec encodes one flat f32 tensor to an opaque payload and back;
//! [`compress`]/[`decompress`] lift that to whole `ParamSet`s. Codec
//! identity travels on the wire as a fixed 10-byte [`CodecSpec`] header
//! (see `comms::messages`) and is negotiated per round in the
//! `transport::RoundAssign`. Decoding is hostile-input safe: every failure
//! is a typed [`CodecError`], never a panic or unbounded allocation.

pub mod baseline;
pub mod bitio;
pub mod quantize;
pub mod stc;
pub mod ternary;

pub use baseline::{DenseCodec, Fp16Codec};
pub use quantize::QuantCodec;
pub use stc::StcCodec;
pub use ternary::{
    pack_ternary, unpack_dequantize, unpack_ternary, PackedTernary, TernaryCodec,
};

use std::fmt;

use anyhow::{anyhow, bail};

use crate::model::{ParamSet, Tensor};
use crate::util::rng::Pcg;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// Typed decode/encode errors. Corrupt wire input maps to a specific
/// variant; nothing in this subsystem panics on payload bytes.
#[derive(Clone, Debug, PartialEq)]
pub enum CodecError {
    /// Wire codec id does not name a registered codec.
    UnknownCodec(u8),
    /// Codec parameters out of range (bad `k`, bad bit width, ...).
    BadParams(String),
    /// Payload ended before the declared content did.
    Truncated { wanted: usize, got: usize },
    /// Payload length disagrees with the expected element count.
    LengthMismatch { expected: usize, got: usize },
    /// Payload is internally inconsistent (invalid encoding, index out of
    /// range, non-zero padding, non-finite scale, ...).
    Corrupt(&'static str),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::UnknownCodec(id) => write!(f, "unknown codec id {id}"),
            CodecError::BadParams(msg) => write!(f, "bad codec parameters: {msg}"),
            CodecError::Truncated { wanted, got } => {
                write!(f, "payload truncated: wanted {wanted}, got {got}")
            }
            CodecError::LengthMismatch { expected, got } => {
                write!(f, "payload length mismatch: expected {expected}, got {got}")
            }
            CodecError::Corrupt(msg) => write!(f, "corrupt payload: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---------------------------------------------------------------------------
// codec identity
// ---------------------------------------------------------------------------

/// A fully-parameterized codec choice — the unit of wire negotiation.
///
/// Parsed from strings like `ternary`, `fp16`, `quant8`, `stc:k=0.01`
/// (the CLI `--codec` flag) and serialized as a fixed [`Self::WIRE_BYTES`]
/// header inside messages, the `Config` handshake, and each round's
/// `Assign` frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecSpec {
    /// The paper's 2-bit ternary packing (T-FedAvg's native format).
    Ternary,
    /// Raw little-endian f32 — the FedAvg baseline, zero loss.
    Dense,
    /// IEEE half precision, round-to-nearest-even.
    Fp16,
    /// Stochastic uniform quantization to `bits`-bit cells (1..=8).
    Quant { bits: u8 },
    /// Sparse ternary compression: top `k` fraction by magnitude.
    Stc { k: f64 },
}

impl CodecSpec {
    /// Fixed wire size: id byte + bits byte + 8-byte f64 parameter.
    pub const WIRE_BYTES: usize = 10;

    /// Stable wire id (never reuse a retired value).
    pub fn id(&self) -> u8 {
        match self {
            CodecSpec::Ternary => 1,
            CodecSpec::Dense => 2,
            CodecSpec::Fp16 => 3,
            CodecSpec::Quant { .. } => 4,
            CodecSpec::Stc { .. } => 5,
        }
    }

    /// Canonical name, parseable by [`CodecSpec::parse`].
    pub fn name(&self) -> String {
        match self {
            CodecSpec::Ternary => "ternary".into(),
            CodecSpec::Dense => "dense".into(),
            CodecSpec::Fp16 => "fp16".into(),
            CodecSpec::Quant { bits } => format!("quant{bits}"),
            CodecSpec::Stc { k } => format!("stc:k={k}"),
        }
    }

    /// Parameter validation shared by the CLI parser, the config
    /// validator, and the wire decoder.
    pub fn check(&self) -> Result<(), CodecError> {
        match *self {
            CodecSpec::Quant { bits } => {
                if !(1..=8).contains(&bits) {
                    return Err(CodecError::BadParams(format!(
                        "quant bit width must be in 1..=8, got {bits}"
                    )));
                }
            }
            CodecSpec::Stc { k } => {
                if !(k.is_finite() && k > 0.0 && k <= 1.0) {
                    return Err(CodecError::BadParams(format!(
                        "stc sparsity k must be in (0, 1], got {k}"
                    )));
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Parse a `--codec` string: `ternary`, `dense`, `fp16`, `quant<bits>`
    /// (or `quant:bits=<b>`), `stc:k=<fraction>` (default k=0.01).
    pub fn parse(spec: &str) -> anyhow::Result<CodecSpec> {
        let (name, params) = match spec.split_once(':') {
            Some((n, p)) => (n.to_ascii_lowercase(), p),
            None => (spec.to_ascii_lowercase(), ""),
        };
        let mut kv = std::collections::BTreeMap::new();
        for part in params.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("codec param {part:?} is not key=value"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let out = match name.as_str() {
            "ternary" => CodecSpec::Ternary,
            "dense" | "fp32" => CodecSpec::Dense,
            "fp16" | "half" => CodecSpec::Fp16,
            "stc" | "topk" => CodecSpec::Stc { k: take_f64(&mut kv, "k", 0.01)? },
            _ => {
                if let Some(rest) = name.strip_prefix("quant") {
                    // bit width is an integer: reject "4.9" / "-3" at
                    // parse time instead of silently truncating
                    let raw = if rest.is_empty() {
                        kv.remove("bits").unwrap_or_else(|| "8".into())
                    } else {
                        rest.to_string()
                    };
                    let bits = raw
                        .parse()
                        .map_err(|e| anyhow!("codec bit width {raw:?}: {e}"))?;
                    CodecSpec::Quant { bits }
                } else {
                    bail!(
                        "unknown codec {name:?} \
                         (ternary | dense | fp16 | quant<bits> | stc:k=<frac>)"
                    );
                }
            }
        };
        if let Some(k) = kv.keys().next() {
            bail!("codec {name:?} does not take parameter {k:?}");
        }
        out.check()?;
        Ok(out)
    }

    /// Fixed-size wire form (id, bits, f64 param; unused fields zero).
    pub fn to_wire(&self) -> [u8; Self::WIRE_BYTES] {
        let mut b = [0u8; Self::WIRE_BYTES];
        b[0] = self.id();
        match self {
            CodecSpec::Quant { bits } => b[1] = *bits,
            CodecSpec::Stc { k } => b[2..10].copy_from_slice(&k.to_le_bytes()),
            _ => {}
        }
        b
    }

    pub fn from_wire(b: [u8; Self::WIRE_BYTES]) -> Result<CodecSpec, CodecError> {
        let spec = match b[0] {
            1 => CodecSpec::Ternary,
            2 => CodecSpec::Dense,
            3 => CodecSpec::Fp16,
            4 => CodecSpec::Quant { bits: b[1] },
            5 => CodecSpec::Stc { k: f64::from_le_bytes(b[2..10].try_into().unwrap()) },
            id => return Err(CodecError::UnknownCodec(id)),
        };
        spec.check()?;
        Ok(spec)
    }
}

fn take_f64(
    kv: &mut std::collections::BTreeMap<String, String>,
    key: &str,
    default: f64,
) -> anyhow::Result<f64> {
    match kv.remove(key) {
        Some(v) => v.parse().map_err(|e| anyhow!("codec param {key}={v}: {e}")),
        None => Ok(default),
    }
}

// ---------------------------------------------------------------------------
// the trait + registry
// ---------------------------------------------------------------------------

/// One payload codec. Implementations are stateless per call (`&self`) and
/// shared across round-driver worker threads.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::compress::{self, CodecSpec};
/// use tfed::util::rng::Pcg;
///
/// let codec = compress::build(CodecSpec::parse("fp16").unwrap()).unwrap();
/// let data = vec![0.5f32, -1.25, 3.0];
/// let mut rng = Pcg::seeded(1); // ignored by deterministic codecs
/// let wire = codec.encode_tensor(&data, &mut rng).unwrap();
/// let back = codec.decode_tensor(&wire, data.len()).unwrap();
/// assert_eq!(back, data); // these values are exact in half precision
/// ```
pub trait Compressor: Send + Sync {
    /// The spec this instance was built from (carries the wire identity).
    fn spec(&self) -> CodecSpec;

    /// Canonical display name.
    fn name(&self) -> String {
        self.spec().name()
    }

    /// Encode one flat f32 tensor into an opaque payload. `rng` drives
    /// stochastic codecs (unbiased rounding); deterministic codecs ignore
    /// it and must not draw from it.
    fn encode_tensor(&self, data: &[f32], rng: &mut Pcg) -> Result<Vec<u8>, CodecError>;

    /// Decode a payload back to exactly `numel` values. Must reject any
    /// inconsistent payload with a typed error.
    fn decode_tensor(&self, bytes: &[u8], numel: usize) -> Result<Vec<f32>, CodecError>;
}

/// Build the codec implementation for a validated spec.
pub fn build(spec: CodecSpec) -> Result<Box<dyn Compressor>, CodecError> {
    spec.check()?;
    Ok(match spec {
        CodecSpec::Ternary => Box::new(TernaryCodec::default()),
        CodecSpec::Dense => Box::new(DenseCodec),
        CodecSpec::Fp16 => Box::new(Fp16Codec),
        CodecSpec::Quant { bits } => Box::new(QuantCodec::new(bits)),
        CodecSpec::Stc { k } => Box::new(StcCodec::new(k)),
    })
}

/// String-keyed registry entry point: parse a codec name and build it.
pub fn build_named(name: &str) -> anyhow::Result<Box<dyn Compressor>> {
    Ok(build(CodecSpec::parse(name)?)?)
}

/// The registered codec family, one canonical name per entry — what
/// `--codec` accepts and the conformance suite iterates over.
pub fn codec_names() -> &'static [&'static str] {
    &["ternary", "dense", "fp16", "quant1", "quant4", "quant8", "stc:k=0.01"]
}

// ---------------------------------------------------------------------------
// ParamSet-level helpers
// ---------------------------------------------------------------------------

/// A whole model's compressed payload: codec identity + one opaque blob
/// per tensor, positionally matching the model schema.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedUpdate {
    pub codec: CodecSpec,
    pub tensors: Vec<Vec<u8>>,
}

impl CompressedUpdate {
    /// Payload bytes this update contributes to its message (codec header
    /// included; message/frame framing excluded).
    pub fn wire_bytes(&self) -> usize {
        CodecSpec::WIRE_BYTES + self.tensors.iter().map(|t| t.len()).sum::<usize>()
    }
}

/// Compress every tensor of a ParamSet. The registry choke point for
/// observability: every registered codec is traced (`codec.encode` span)
/// and counted (`tfed_codec_encode_*` series) here, with zero per-codec
/// instrumentation and zero cost when obs is off.
pub fn compress(
    codec: &dyn Compressor,
    params: &ParamSet,
    rng: &mut Pcg,
) -> Result<CompressedUpdate, CodecError> {
    crate::obs_span!("codec.encode");
    let tensors = params
        .tensors
        .iter()
        .map(|t| codec.encode_tensor(&t.data, rng))
        .collect::<Result<_, _>>()?;
    let upd = CompressedUpdate { codec: codec.spec(), tensors };
    if crate::obs::enabled() {
        obs_codec("encode", &codec.name(), upd.wire_bytes());
    }
    Ok(upd)
}

/// Rebuild a dense ParamSet from a compressed update against the model's
/// tensor shapes.
pub fn decompress(
    codec: &dyn Compressor,
    upd: &CompressedUpdate,
    shapes: &[Vec<usize>],
) -> Result<ParamSet, CodecError> {
    crate::obs_span!("codec.decode");
    if upd.codec != codec.spec() {
        return Err(CodecError::BadParams(format!(
            "update was encoded with {}, decoder is {}",
            upd.codec.name(),
            codec.name()
        )));
    }
    if upd.tensors.len() != shapes.len() {
        return Err(CodecError::LengthMismatch {
            expected: shapes.len(),
            got: upd.tensors.len(),
        });
    }
    let mut tensors = Vec::with_capacity(shapes.len());
    for (bytes, shape) in upd.tensors.iter().zip(shapes) {
        let numel: usize = shape.iter().product();
        let data = codec.decode_tensor(bytes, numel)?;
        if data.len() != numel {
            return Err(CodecError::LengthMismatch { expected: numel, got: data.len() });
        }
        tensors.push(Tensor { shape: shape.clone(), data });
    }
    if crate::obs::enabled() {
        obs_codec("decode", &codec.name(), upd.wire_bytes());
    }
    Ok(ParamSet { tensors })
}

/// Per-codec call + payload-byte counters, e.g.
/// `tfed_codec_encode_total{codec="ternary"}`. Only reached when obs is
/// enabled; the registry returns the same handle for a repeated name, so
/// the lookup is a short lock, not a new series.
fn obs_codec(dir: &str, name: &str, wire_bytes: usize) {
    use crate::obs::metrics::counter;
    counter(&format!("tfed_codec_{dir}_total{{codec=\"{name}\"}}")).inc();
    counter(&format!("tfed_codec_{dir}_bytes_total{{codec=\"{name}\"}}")).add(wire_bytes as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_canonical_names() {
        assert_eq!(CodecSpec::parse("ternary").unwrap(), CodecSpec::Ternary);
        assert_eq!(CodecSpec::parse("DENSE").unwrap(), CodecSpec::Dense);
        assert_eq!(CodecSpec::parse("fp16").unwrap(), CodecSpec::Fp16);
        assert_eq!(CodecSpec::parse("quant8").unwrap(), CodecSpec::Quant { bits: 8 });
        assert_eq!(
            CodecSpec::parse("quant:bits=4").unwrap(),
            CodecSpec::Quant { bits: 4 }
        );
        assert_eq!(CodecSpec::parse("stc").unwrap(), CodecSpec::Stc { k: 0.01 });
        assert_eq!(
            CodecSpec::parse("stc:k=0.05").unwrap(),
            CodecSpec::Stc { k: 0.05 }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(CodecSpec::parse("gzip").is_err());
        assert!(CodecSpec::parse("quant0").is_err());
        assert!(CodecSpec::parse("quant9").is_err());
        assert!(CodecSpec::parse("quant:bits=4.9").is_err());
        assert!(CodecSpec::parse("quant:bits=-3").is_err());
        assert!(CodecSpec::parse("stc:k=0").is_err());
        assert!(CodecSpec::parse("stc:k=1.5").is_err());
        assert!(CodecSpec::parse("stc:q=0.1").is_err());
        assert!(CodecSpec::parse("dense:k=1").is_err());
        assert!(CodecSpec::parse("stc:k").is_err());
    }

    #[test]
    fn wire_roundtrip_every_registered_codec() {
        for name in codec_names() {
            let spec = CodecSpec::parse(name).unwrap();
            assert_eq!(CodecSpec::from_wire(spec.to_wire()).unwrap(), spec);
            // name is canonical: parses back to itself
            assert_eq!(CodecSpec::parse(&spec.name()).unwrap(), spec);
        }
    }

    #[test]
    fn wire_rejects_unknown_and_invalid() {
        let mut b = [0u8; CodecSpec::WIRE_BYTES];
        b[0] = 99;
        assert_eq!(CodecSpec::from_wire(b), Err(CodecError::UnknownCodec(99)));
        // quant with a zero bit width
        let mut b = [0u8; CodecSpec::WIRE_BYTES];
        b[0] = 4;
        assert!(matches!(CodecSpec::from_wire(b), Err(CodecError::BadParams(_))));
        // stc with k out of range
        let mut b = [0u8; CodecSpec::WIRE_BYTES];
        b[0] = 5;
        b[2..10].copy_from_slice(&2.0f64.to_le_bytes());
        assert!(matches!(CodecSpec::from_wire(b), Err(CodecError::BadParams(_))));
    }

    #[test]
    fn registry_builds_every_name() {
        for name in codec_names() {
            let c = build_named(name).unwrap();
            assert_eq!(CodecSpec::parse(&c.name()).unwrap(), c.spec());
        }
    }

    #[test]
    fn decompress_rejects_codec_mismatch_and_count() {
        let dense = build(CodecSpec::Dense).unwrap();
        let upd = CompressedUpdate { codec: CodecSpec::Fp16, tensors: vec![] };
        assert!(matches!(
            decompress(dense.as_ref(), &upd, &[]),
            Err(CodecError::BadParams(_))
        ));
        let upd = CompressedUpdate { codec: CodecSpec::Dense, tensors: vec![] };
        assert!(matches!(
            decompress(dense.as_ref(), &upd, &[vec![2]]),
            Err(CodecError::LengthMismatch { .. })
        ));
    }
}
