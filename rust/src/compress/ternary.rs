//! 2-bit ternary packing: 4 trits per byte — the paper's codec (§III-B),
//! ported here from `comms/codec.rs` as the compression subsystem's first
//! implementation.
//!
//! Encoding per 2-bit cell: 00 -> 0, 01 -> +1, 10 -> -1 (11 unused). The
//! upstream/downstream payload for one layer of n weights is
//! ceil(n/4) bytes — 1/16 of the 4n bytes FedAvg ships, matching the
//! paper's §III-B arithmetic.
//!
//! Both unpack paths enforce the same strictness: invalid 0b11 cells AND
//! non-zero padding bits in the final byte are rejected, so a
//! corrupt-but-CRC-valid frame decodes identically (to an error) no matter
//! which path the client takes.

use crate::compress::{CodecError, CodecSpec, Compressor};
use crate::quant;
use crate::util::rng::Pcg;

/// A packed ternary tensor (one layer's sign pattern).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTernary {
    pub len: usize,
    pub bytes: Vec<u8>,
}

impl PackedTernary {
    pub fn payload_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[inline]
fn encode_trit(s: i8) -> u8 {
    match s {
        0 => 0b00,
        1 => 0b01,
        -1 => 0b10,
        _ => unreachable!("non-ternary value {s}"),
    }
}

/// The 4-entry cell expansion table — **the** single decode table for the
/// 2-bit encoding, shared by the codec paths and the packed kernels
/// (`native::kernels`): index by cell code, get `[0, pos, neg, 0]`
/// (the invalid 0b11 lane maps to 0 and is guarded by the callers'
/// validity scans).
#[inline]
pub fn cell_table(pos: f32, neg: f32) -> [f32; 4] {
    [0.0, pos, neg, 0.0]
}

/// Blow [`cell_table`] up to a 256-entry x 4-lane per-byte LUT: one row
/// load expands a whole packed byte, replacing four shift/mask/branch
/// steps with a fixed-width copy. Shared by [`unpack_dequantize`] and the
/// packed-kernel inner loops.
pub fn byte_expand_lut(pos: f32, neg: f32) -> [[f32; 4]; 256] {
    let cell = cell_table(pos, neg);
    let mut lut = [[0.0f32; 4]; 256];
    for (b, row) in lut.iter_mut().enumerate() {
        for (lane, v) in row.iter_mut().enumerate() {
            *v = cell[(b >> (2 * lane)) & 3];
        }
    }
    lut
}

/// The i8 twin of [`byte_expand_lut`] for sign-pattern decode, built once
/// at compile time (it has no value parameters).
const TRIT_LUT: [[i8; 4]; 256] = {
    let cell = [0i8, 1, -1, 0];
    let mut lut = [[0i8; 4]; 256];
    let mut b = 0;
    while b < 256 {
        let mut lane = 0;
        while lane < 4 {
            lut[b][lane] = cell[(b >> (2 * lane)) & 3];
            lane += 1;
        }
        b += 1;
    }
    lut
};

/// Pack one row of trits ({-1, 0, +1} as i8), appending
/// `row.len().div_ceil(4)` zero-padded bytes to `out`. Chunked four
/// elements per byte (no per-element read-modify-write on the output
/// byte), this is the codec's — and the packed kernels' — one trit
/// encoder.
pub fn pack_row(row: &[i8], out: &mut Vec<u8>) {
    let mut chunks = row.chunks_exact(4);
    for c in &mut chunks {
        out.push(
            encode_trit(c[0])
                | encode_trit(c[1]) << 2
                | encode_trit(c[2]) << 4
                | encode_trit(c[3]) << 6,
        );
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut b = 0u8;
        for (lane, &s) in rem.iter().enumerate() {
            b |= encode_trit(s) << (2 * lane);
        }
        out.push(b);
    }
}

/// Pack a sign pattern ({-1, 0, +1} as i8) into 2-bit cells.
pub fn pack_ternary(it: &[i8]) -> PackedTernary {
    let mut bytes = Vec::with_capacity(it.len().div_ceil(4));
    pack_row(it, &mut bytes);
    PackedTernary { len: it.len(), bytes }
}

/// Byte count / element count consistency, shared by both unpack paths.
#[inline]
fn check_len(p: &PackedTernary) -> Result<(), CodecError> {
    if p.bytes.len() != p.len.div_ceil(4) {
        return Err(CodecError::LengthMismatch {
            expected: p.len.div_ceil(4),
            got: p.bytes.len(),
        });
    }
    Ok(())
}

/// Trailing cells of the last byte must be zero-padded, shared by both
/// unpack paths (a dirty tail is corruption the CRC happened to miss).
#[inline]
fn check_padding(p: &PackedTernary) -> Result<(), CodecError> {
    if p.len % 4 != 0 {
        let last = p.bytes[p.bytes.len() - 1];
        let used = (p.len % 4) * 2;
        if last >> used != 0 {
            return Err(CodecError::Corrupt("non-zero padding bits in final byte"));
        }
    }
    Ok(())
}

/// Unpack back to the sign pattern; validates cell encoding and padding.
/// Same structure as [`unpack_dequantize`]: validity is checked up front
/// per byte, then the body is a branch-free 4-lane LUT expansion.
pub fn unpack_ternary(p: &PackedTernary) -> Result<Vec<i8>, CodecError> {
    check_len(p)?;
    check_padding(p)?;
    if p.bytes.iter().any(|&b| has_invalid_cell(b)) {
        return Err(CodecError::Corrupt("invalid trit encoding 0b11"));
    }
    let full_bytes = p.len / 4;
    let rem = p.len % 4;
    let mut out = Vec::with_capacity(p.len);
    for &b in &p.bytes[..full_bytes] {
        out.extend_from_slice(&TRIT_LUT[b as usize]);
    }
    if rem != 0 {
        out.extend_from_slice(&TRIT_LUT[p.bytes[full_bytes] as usize][..rem]);
    }
    Ok(out)
}

/// A 2-bit cell is the invalid encoding 0b11 iff both of its bits are set;
/// `b & (b >> 1)` lines those up on the low bit of each cell.
#[inline]
fn has_invalid_cell(b: u8) -> bool {
    b & (b >> 1) & 0b0101_0101 != 0
}

/// Unpack directly to dense f32 weights (wq * it) without the i8 hop —
/// the hot-path variant used when materializing a downloaded model.
/// Exactly as strict as [`unpack_ternary`]: invalid cells and dirty
/// padding are both rejected.
///
/// Validity is checked up front with a per-byte bit trick (no post-hoc NaN
/// scan), then the body is a straight 256-entry x 4-lane table copy: one
/// LUT row per byte value replaces the per-element shift/mask loop.
pub fn unpack_dequantize(p: &PackedTernary, wq: f32) -> Result<Vec<f32>, CodecError> {
    check_len(p)?;
    check_padding(p)?;
    // up-front 0b11-cell check; after the padding check the tail byte's
    // unused cells are known-zero, so whole bytes can be tested
    let full_bytes = p.len / 4;
    if p.bytes.iter().any(|&b| has_invalid_cell(b)) {
        return Err(CodecError::Corrupt("invalid trit encoding 0b11"));
    }
    let rem = p.len % 4;

    let cell = cell_table(wq, -wq);
    let mut out = Vec::with_capacity(p.len);

    // below this size the 1024-entry LUT fill would cost more than the
    // unpack itself (e.g. the MLP's bias-sized layers): use the 4-entry
    // cell table directly
    if p.len < 4096 {
        for &b in &p.bytes[..full_bytes] {
            out.push(cell[(b & 3) as usize]);
            out.push(cell[((b >> 2) & 3) as usize]);
            out.push(cell[((b >> 4) & 3) as usize]);
            out.push(cell[((b >> 6) & 3) as usize]);
        }
        if rem != 0 {
            let b = p.bytes[full_bytes];
            for lane in 0..rem {
                out.push(cell[((b >> (2 * lane)) & 3) as usize]);
            }
        }
        return Ok(out);
    }

    // the shared 256-entry x 4-lane per-byte LUT (the 0b11 lane is
    // unreachable after the validity check; 0.0 keeps the table total)
    let lut = byte_expand_lut(wq, -wq);
    for &b in &p.bytes[..full_bytes] {
        out.extend_from_slice(&lut[b as usize]);
    }
    if rem != 0 {
        out.extend_from_slice(&lut[p.bytes[full_bytes] as usize][..rem]);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// the generic Compressor wrapper
// ---------------------------------------------------------------------------

/// Ternary quantization as a registry codec: FTTQ-style ternarization of a
/// trained tensor (scale -> eq.8 threshold -> sign pattern) with the eq.-20
/// optimal factor, packed 4 trits/byte behind a single f32 scale.
///
/// The T-FedAvg protocol path keeps its dedicated `TernaryUpdate` /
/// `TernaryGlobal` messages (which also carry per-layer w^q and Delta);
/// this wrapper is the same wire format applied as a generic post-training
/// codec, so `ternary` participates in the codec-conformance suite and the
/// FedAvg-side comparisons on equal footing.
pub struct TernaryCodec {
    /// eq. 8 threshold hyperparameter T.
    t: f32,
}

impl Default for TernaryCodec {
    fn default() -> Self {
        // the manifest's T_k default, shared with NativeBackend
        TernaryCodec { t: 0.05 }
    }
}

impl Compressor for TernaryCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Ternary
    }

    fn encode_tensor(&self, data: &[f32], _rng: &mut Pcg) -> Result<Vec<u8>, CodecError> {
        let s = quant::scale(data);
        let delta = quant::threshold_mean(&s, self.t);
        let it = quant::ternarize(&s, delta);
        let wq = quant::optimal_wq_symmetric(data, &it);
        let packed = pack_ternary(&it);
        let mut out = Vec::with_capacity(4 + packed.bytes.len());
        out.extend_from_slice(&wq.to_le_bytes());
        out.extend_from_slice(&packed.bytes);
        Ok(out)
    }

    fn decode_tensor(&self, bytes: &[u8], numel: usize) -> Result<Vec<f32>, CodecError> {
        if bytes.len() < 4 {
            return Err(CodecError::Truncated { wanted: 4, got: bytes.len() });
        }
        let wq = f32::from_le_bytes(bytes[..4].try_into().unwrap());
        if !wq.is_finite() {
            return Err(CodecError::Corrupt("non-finite ternary scale"));
        }
        let packed = PackedTernary { len: numel, bytes: bytes[4..].to_vec() };
        unpack_dequantize(&packed, wq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn roundtrip_small() {
        for pattern in [
            vec![],
            vec![0i8],
            vec![1, -1, 0],
            vec![1, 1, 1, 1],
            vec![-1, 0, 1, -1, 0],
        ] {
            let p = pack_ternary(&pattern);
            assert_eq!(unpack_ternary(&p).unwrap(), pattern);
        }
    }

    #[test]
    fn roundtrip_property() {
        forall(128, |rng| {
            let n = rng.below(4096) as usize;
            let it: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
            let p = pack_ternary(&it);
            assert_eq!(p.payload_bytes(), n.div_ceil(4));
            assert_eq!(unpack_ternary(&p).unwrap(), it);
        });
    }

    #[test]
    fn sixteen_x_compression() {
        // paper §III-B: 2-bit vs 32-bit => 16x on the weight payload
        let n = 24_380; // MLP parameter count
        let it = vec![1i8; n];
        let p = pack_ternary(&it);
        let fp32 = n * 4;
        let ratio = fp32 as f64 / p.payload_bytes() as f64;
        assert!((ratio - 16.0).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn dequantize_matches_unpack() {
        forall(64, |rng| {
            let n = rng.below(1000) as usize;
            let it: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
            let wq = rng.next_f32() + 0.01;
            let p = pack_ternary(&it);
            let dense = unpack_dequantize(&p, wq).unwrap();
            let via_i8: Vec<f32> =
                unpack_ternary(&p).unwrap().iter().map(|&s| wq * s as f32).collect();
            assert_eq!(dense, via_i8);
        });
    }

    #[test]
    fn byte_lut_expands_cell_table() {
        let lut = byte_expand_lut(0.3, -0.7);
        let cell = cell_table(0.3, -0.7);
        for b in 0..256usize {
            for lane in 0..4 {
                assert_eq!(lut[b][lane], cell[(b >> (2 * lane)) & 3], "b={b} lane={lane}");
                assert_eq!(TRIT_LUT[b][lane], [0i8, 1, -1, 0][(b >> (2 * lane)) & 3]);
            }
        }
    }

    #[test]
    fn pack_row_appends_byte_aligned_rows() {
        let mut out = Vec::new();
        pack_row(&[1, -1, 0, 1, 1], &mut out); // 2 bytes, 3 padding cells
        pack_row(&[-1, -1], &mut out); // 1 byte, 2 padding cells
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], 0b01_00_10_01);
        assert_eq!(out[1], 0b00_00_00_01);
        assert_eq!(out[2], 0b00_00_10_10);
    }

    #[test]
    fn rejects_corrupt_encoding() {
        let mut p = pack_ternary(&[1, 1, 1, 1]);
        p.bytes[0] = 0xFF; // 0b11 cells
        assert!(matches!(unpack_ternary(&p), Err(CodecError::Corrupt(_))));
        assert!(matches!(unpack_dequantize(&p, 1.0), Err(CodecError::Corrupt(_))));
    }

    #[test]
    fn rejects_bad_length() {
        let p = PackedTernary { len: 10, bytes: vec![0; 1] };
        assert!(matches!(unpack_ternary(&p), Err(CodecError::LengthMismatch { .. })));
        assert!(matches!(
            unpack_dequantize(&p, 1.0),
            Err(CodecError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn rejects_dirty_padding_on_both_paths() {
        // the seed's hot path accepted non-zero padding that the strict
        // path rejected — both must now agree (ISSUE 2 satellite)
        let mut p = pack_ternary(&[1, 1, 1]);
        p.bytes[0] |= 0b01 << 6; // set the unused 4th cell
        assert!(matches!(unpack_ternary(&p), Err(CodecError::Corrupt(_))));
        assert!(matches!(unpack_dequantize(&p, 1.0), Err(CodecError::Corrupt(_))));
        // an invalid 0b11 pattern hidden in the padding is also rejected
        let mut p = pack_ternary(&[1, 1, 1]);
        p.bytes[0] |= 0b11 << 6;
        assert!(unpack_ternary(&p).is_err());
        assert!(unpack_dequantize(&p, 1.0).is_err());
    }

    #[test]
    fn codec_decodes_to_pattern_times_scale() {
        forall(32, |rng| {
            let n = 1 + rng.below(3000) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let codec = TernaryCodec::default();
            let enc = codec.encode_tensor(&v, rng).unwrap();
            assert_eq!(enc.len(), 4 + n.div_ceil(4));
            let dec = codec.decode_tensor(&enc, n).unwrap();
            let wq = f32::from_le_bytes(enc[..4].try_into().unwrap());
            assert!(dec.iter().all(|&x| x == 0.0 || x == wq || x == -wq));
        });
    }

    #[test]
    fn codec_rejects_truncation_and_nonfinite_scale() {
        let codec = TernaryCodec::default();
        let mut rng = Pcg::seeded(1);
        let enc = codec.encode_tensor(&[0.5, -0.4, 0.1, 0.9], &mut rng).unwrap();
        assert!(codec.decode_tensor(&enc[..2], 4).is_err());
        assert!(codec.decode_tensor(&enc[..enc.len() - 1], 4).is_err());
        let mut bad = enc.clone();
        bad[..4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(matches!(codec.decode_tensor(&bad, 4), Err(CodecError::Corrupt(_))));
    }
}
