//! Stochastic uniform k-bit quantization (the FL-quantization survey's
//! canonical axis): per tensor, the value range [lo, hi] is split into
//! 2^k - 1 equal steps and every element is rounded to a neighboring level
//! *probabilistically*, so the codec is unbiased in expectation:
//! E[decode(encode(v))] = v.
//!
//! The randomness comes from the caller's `Pcg` — in a federated round
//! that generator is server-seeded per client and travels in the round
//! assignment, so runs are bit-reproducible at any worker count and over
//! any transport.
//!
//! Payload layout: [lo f32][hi f32][numel k-bit cells, LSB-first packed].

use crate::compress::bitio::{BitReader, BitWriter};
use crate::compress::{CodecError, CodecSpec, Compressor};
use crate::util::rng::Pcg;

const HEADER_BYTES: usize = 8;

pub struct QuantCodec {
    bits: u8,
}

impl QuantCodec {
    pub fn new(bits: u8) -> QuantCodec {
        QuantCodec { bits }
    }

    fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }
}

impl Compressor for QuantCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Quant { bits: self.bits }
    }

    fn encode_tensor(&self, data: &[f32], rng: &mut Pcg) -> Result<Vec<u8>, CodecError> {
        if data.iter().any(|v| !v.is_finite()) {
            return Err(CodecError::Corrupt("non-finite input tensor"));
        }
        let payload = (data.len() * self.bits as usize).div_ceil(8);
        let mut out = Vec::with_capacity(HEADER_BYTES + payload);
        if data.is_empty() {
            out.extend_from_slice(&0f32.to_le_bytes());
            out.extend_from_slice(&0f32.to_le_bytes());
            return Ok(out);
        }
        let lo = data.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let hi = data.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if !(hi - lo).is_finite() {
            // a span wider than f32::MAX cannot be stepped; refuse rather
            // than emit a payload our own decoder must reject
            return Err(CodecError::Corrupt("value range overflows f32"));
        }
        let levels = self.levels();
        let step = (hi - lo) / levels as f32;
        out.extend_from_slice(&lo.to_le_bytes());
        out.extend_from_slice(&hi.to_le_bytes());

        let mut bw = BitWriter::new();
        for &v in data {
            let idx = if step <= 0.0 {
                0 // constant tensor: every element is lo
            } else {
                let t = ((v - lo) / step).clamp(0.0, levels as f32);
                let base = t.floor();
                let frac = t - base;
                let base = base as u32;
                if base >= levels {
                    levels
                } else {
                    // unbiased rounding: up with probability frac
                    base + (rng.next_f32() < frac) as u32
                }
            };
            bw.push_bits(idx, self.bits as u32);
        }
        out.extend_from_slice(&bw.finish());
        Ok(out)
    }

    fn decode_tensor(&self, bytes: &[u8], numel: usize) -> Result<Vec<f32>, CodecError> {
        if bytes.len() < HEADER_BYTES {
            return Err(CodecError::Truncated { wanted: HEADER_BYTES, got: bytes.len() });
        }
        let expected = HEADER_BYTES + (numel * self.bits as usize).div_ceil(8);
        if bytes.len() != expected {
            return Err(CodecError::LengthMismatch { expected, got: bytes.len() });
        }
        let lo = f32::from_le_bytes(bytes[0..4].try_into().unwrap());
        let hi = f32::from_le_bytes(bytes[4..8].try_into().unwrap());
        // (hi - lo) must be finite too: lo=-3e38/hi=3e38 passes the
        // individual checks but overflows the span to +inf, which would
        // decode to NaN/inf and poison the aggregate
        if !lo.is_finite() || !hi.is_finite() || hi < lo || !(hi - lo).is_finite() {
            return Err(CodecError::Corrupt("invalid quantization range"));
        }
        let step = (hi - lo) / self.levels() as f32;
        let mut br = BitReader::new(&bytes[HEADER_BYTES..]);
        let mut out = Vec::with_capacity(numel);
        for _ in 0..numel {
            // a k-bit cell can never exceed levels = 2^k - 1, so every
            // bit pattern maps to a valid level
            let idx = br.read_bits(self.bits as u32)?;
            out.push(lo + idx as f32 * step);
        }
        br.expect_zero_padding()?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn error_bounded_by_one_step() {
        forall(64, |rng| {
            for bits in [1u8, 4, 8] {
                let c = QuantCodec::new(bits);
                let n = 1 + rng.below(1000) as usize;
                let v: Vec<f32> = (0..n).map(|_| rng.normal() * 3.0).collect();
                let enc = c.encode_tensor(&v, rng).unwrap();
                let dec = c.decode_tensor(&enc, n).unwrap();
                let lo = v.iter().fold(f32::INFINITY, |a, &b| a.min(b));
                let hi = v.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let step = (hi - lo) / c.levels() as f32;
                for (d, x) in dec.iter().zip(&v) {
                    assert!(
                        (d - x).abs() <= step * 1.0001 + 1e-6,
                        "bits={bits} |{d} - {x}| > step {step}"
                    );
                }
            }
        });
    }

    #[test]
    fn unbiased_in_expectation() {
        // fixed values, many independent stochastic encodes: the mean
        // decode must converge on the input (the codec's defining
        // property for convergence proofs)
        let v = [0.13f32, -0.57, 0.91, 0.02, -0.33, 0.74, -0.99, 0.48];
        for bits in [1u8, 4] {
            let c = QuantCodec::new(bits);
            let trials = 3000;
            let mut acc = [0f64; 8];
            for t in 0..trials {
                let mut rng = Pcg::seeded(1000 + t);
                let dec = c
                    .decode_tensor(&c.encode_tensor(&v, &mut rng).unwrap(), v.len())
                    .unwrap();
                for (a, d) in acc.iter_mut().zip(&dec) {
                    *a += *d as f64;
                }
            }
            let lo = -0.99f32;
            let hi = 0.91f32;
            let step = ((hi - lo) / c.levels() as f32) as f64;
            // mean of `trials` draws: tolerance ~ step / sqrt(trials) * 3
            let tol = (step / (trials as f64).sqrt()) * 4.0 + 1e-4;
            for (a, x) in acc.iter().zip(&v) {
                let mean = a / trials as f64;
                assert!(
                    (mean - *x as f64).abs() < tol,
                    "bits={bits}: E[{x}] drifted to {mean} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn deterministic_given_same_rng() {
        let v: Vec<f32> = (0..257).map(|i| (i as f32).sin()).collect();
        let c = QuantCodec::new(4);
        let a = c.encode_tensor(&v, &mut Pcg::new(9, 7)).unwrap();
        let b = c.encode_tensor(&v, &mut Pcg::new(9, 7)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn payload_size_matches_bit_width() {
        let mut rng = Pcg::seeded(2);
        let v = vec![0.5f32; 1000];
        for (bits, payload) in [(1u8, 125), (4, 500), (8, 1000)] {
            let enc = QuantCodec::new(bits).encode_tensor(&v, &mut rng).unwrap();
            assert_eq!(enc.len(), HEADER_BYTES + payload);
        }
    }

    #[test]
    fn constant_and_empty_tensors() {
        let mut rng = Pcg::seeded(3);
        let c = QuantCodec::new(4);
        let enc = c.encode_tensor(&[2.5; 9], &mut rng).unwrap();
        assert_eq!(c.decode_tensor(&enc, 9).unwrap(), vec![2.5; 9]);
        let enc = c.encode_tensor(&[], &mut rng).unwrap();
        assert_eq!(c.decode_tensor(&enc, 0).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let mut rng = Pcg::seeded(4);
        let c = QuantCodec::new(8);
        let v: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let enc = c.encode_tensor(&v, &mut rng).unwrap();
        for cut in 0..enc.len() {
            assert!(c.decode_tensor(&enc[..cut], v.len()).is_err(), "cut={cut}");
        }
        // range inverted
        let mut bad = enc.clone();
        bad[0..4].copy_from_slice(&10f32.to_le_bytes());
        bad[4..8].copy_from_slice(&(-10f32).to_le_bytes());
        assert!(matches!(
            c.decode_tensor(&bad, v.len()),
            Err(CodecError::Corrupt(_))
        ));
        // non-finite range
        let mut bad = enc.clone();
        bad[0..4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(matches!(
            c.decode_tensor(&bad, v.len()),
            Err(CodecError::Corrupt(_))
        ));
        // finite lo/hi whose span overflows to +inf
        let mut bad = enc;
        bad[0..4].copy_from_slice(&(-3.0e38f32).to_le_bytes());
        bad[4..8].copy_from_slice(&3.0e38f32.to_le_bytes());
        assert!(matches!(
            c.decode_tensor(&bad, v.len()),
            Err(CodecError::Corrupt(_))
        ));
        // encoding a legal-but-unsteppable span is refused symmetrically
        assert!(c.encode_tensor(&[-3.0e38, 3.0e38], &mut rng).is_err());
        // encoding refuses non-finite inputs
        assert!(c.encode_tensor(&[f32::NAN], &mut rng).is_err());
    }
}
