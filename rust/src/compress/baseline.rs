//! Calibration baselines: raw f32 passthrough (the FedAvg reference every
//! compression ratio is measured against) and IEEE-754 half precision
//! (the weakest "real" codec — exactly 2x, negligible error).

use crate::compress::{CodecError, CodecSpec, Compressor};
use crate::util::rng::Pcg;

// ---------------------------------------------------------------------------
// dense f32
// ---------------------------------------------------------------------------

/// Lossless little-endian f32 passthrough.
pub struct DenseCodec;

impl Compressor for DenseCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Dense
    }

    fn encode_tensor(&self, data: &[f32], _rng: &mut Pcg) -> Result<Vec<u8>, CodecError> {
        let mut out = Vec::with_capacity(data.len() * 4);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        Ok(out)
    }

    fn decode_tensor(&self, bytes: &[u8], numel: usize) -> Result<Vec<f32>, CodecError> {
        if bytes.len() != numel * 4 {
            return Err(CodecError::LengthMismatch { expected: numel * 4, got: bytes.len() });
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

// ---------------------------------------------------------------------------
// fp16
// ---------------------------------------------------------------------------

/// IEEE-754 binary16, round-to-nearest-even on encode.
pub struct Fp16Codec;

impl Compressor for Fp16Codec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Fp16
    }

    fn encode_tensor(&self, data: &[f32], _rng: &mut Pcg) -> Result<Vec<u8>, CodecError> {
        let mut out = Vec::with_capacity(data.len() * 2);
        for &v in data {
            out.extend_from_slice(&f32_to_f16(v).to_le_bytes());
        }
        Ok(out)
    }

    fn decode_tensor(&self, bytes: &[u8], numel: usize) -> Result<Vec<f32>, CodecError> {
        if bytes.len() != numel * 2 {
            return Err(CodecError::LengthMismatch { expected: numel * 2, got: bytes.len() });
        }
        Ok(bytes
            .chunks_exact(2)
            .map(|c| f16_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

/// f32 -> binary16 bits, round-to-nearest-even; overflow saturates to
/// infinity, NaN payload is preserved in the top mantissa bit.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 255 {
        // inf / NaN (force a quiet-NaN bit so the payload never
        // collapses to an infinity)
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127 + 15;
    if unbiased >= 31 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased <= 0 {
        // subnormal half (or zero): shift the 24-bit significand down
        if unbiased < -10 {
            return sign; // underflow -> signed zero
        }
        let full = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - unbiased) as u32;
        return sign | round_shift(full, shift) as u16;
    }
    // normal: 23 -> 10 mantissa bits; the rounding carry may overflow
    // into the exponent (and at the top, into infinity) — both correct
    let v = ((unbiased as u32) << 10) | (man >> 13);
    let v = v + round_increment(man, 13, v);
    sign | v as u16
}

/// Drop `shift` low bits of `v` with round-to-nearest-even.
fn round_shift(v: u32, shift: u32) -> u32 {
    let out = v >> shift;
    out + round_increment(v, shift, out)
}

/// 1 if dropping the low `shift` bits of `v` should round `out` up.
fn round_increment(v: u32, shift: u32, out: u32) -> u32 {
    let half = 1u32 << (shift - 1);
    let rem = v & ((1u32 << shift) - 1);
    (rem > half || (rem == half && out & 1 == 1)) as u32
}

/// binary16 bits -> f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 31 {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    if exp == 0 {
        // subnormal: man * 2^-24 is exactly representable in f32
        let v = man as f32 * (1.0 / 16_777_216.0);
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn dense_is_bit_exact() {
        forall(32, |rng| {
            let n = rng.below(2000) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal() * 100.0).collect();
            let c = DenseCodec;
            let enc = c.encode_tensor(&v, rng).unwrap();
            assert_eq!(enc.len(), n * 4);
            let dec = c.decode_tensor(&enc, n).unwrap();
            for (a, b) in dec.iter().zip(&v) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(c.decode_tensor(&enc, n + 1).is_err());
        });
    }

    #[test]
    fn f16_special_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // largest normal half
        assert_eq!(f32_to_f16(1e6), 0x7C00); // overflow -> inf
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // smallest positive subnormal half = 2^-24
        assert_eq!(f16_to_f32(0x0001), 1.0 / 16_777_216.0);
        assert_eq!(f32_to_f16(1.0 / 16_777_216.0), 0x0001);
        // underflow to zero
        assert_eq!(f32_to_f16(1e-10), 0x0000);
    }

    #[test]
    fn f16_roundtrip_is_exact_for_half_values() {
        // every finite half value converts to f32 and back unchanged
        for h in 0u16..=0xFFFF {
            if (h >> 10) & 0x1F == 31 {
                continue; // inf/NaN lane
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "h={h:#06x}");
        }
    }

    #[test]
    fn f16_error_within_half_ulp() {
        forall(64, |rng| {
            let v = rng.normal() * 8.0;
            let d = f16_to_f32(f32_to_f16(v));
            // relative error <= 2^-11 in the normal range
            assert!(
                (d - v).abs() <= v.abs() * (1.0 / 2048.0) + 1e-7,
                "{v} -> {d}"
            );
        });
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 and the next half value
        // (1 + 2^-10): ties go to the even mantissa (1.0)
        let tie = 1.0 + 1.0 / 2048.0;
        assert_eq!(f32_to_f16(tie), 0x3C00);
        // just above the tie rounds up
        let above = 1.0 + 1.5 / 2048.0;
        assert_eq!(f32_to_f16(above), 0x3C01);
    }

    #[test]
    fn fp16_codec_roundtrip_and_size() {
        forall(32, |rng| {
            let n = rng.below(1000) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let c = Fp16Codec;
            let enc = c.encode_tensor(&v, rng).unwrap();
            assert_eq!(enc.len(), n * 2);
            let dec = c.decode_tensor(&enc, n).unwrap();
            assert_eq!(dec.len(), n);
            if n > 0 {
                assert!(c.decode_tensor(&enc[..enc.len() - 1], n).is_err());
            }
        });
    }
}
