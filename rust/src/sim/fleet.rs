//! The virtual fleet: lazily-materialized per-client device and network
//! profiles for a registered population far larger than any round's
//! cohort.
//!
//! No per-client state is ever stored. A client's profile — device speed
//! tier, link bandwidth tier, last-mile latency — is a pure function of
//! `(fleet seed, registered client id)`: looking it up builds a fresh
//! server-seeded [`Pcg`](crate::util::rng::Pcg) and makes a fixed number
//! of draws. That gives O(cohort) memory at one million registered
//! clients *and* bit-reproducible profiles regardless of which worker
//! thread asks first (the repo's RNG discipline, applied to the fleet).
//!
//! Timing model (all integer microseconds at the event boundary):
//!
//! ```text
//! exchange(c) = 2·latency(c)                       round trip
//!             + down_bytes · 8 / bandwidth(c)      broadcast transfer
//!             + samples · epochs · us_per_sample(c) local training
//!             + up_bytes · 8 / bandwidth(c)        upload transfer
//!             + straggle(c, round)                 availability delay
//! ```
//!
//! Clients are independent (no shared server pipe is modeled), so a
//! round's completion time is the max arrival — exactly what the
//! `(time, seq)` event queue drains last.

use crate::sim::SimError;
use crate::util::rng::Pcg;

/// Stream selectors for the per-client derivations (distinct from every
/// stream the coordinator uses).
const PROFILE_STREAM: u64 = 0x51F0;
const STRAGGLE_STREAM: u64 = 0x57A6;
/// SplitMix64 golden-ratio constant, the repo's standard id-mixing salt.
const MIX: u64 = 0x9E3779B97F4A7C15;

/// A discrete distribution over tier values (device speeds, bandwidths):
/// `values[i]` is drawn with probability `weights[i] / sum(weights)`.
#[derive(Clone, Debug, PartialEq)]
pub struct TierSet {
    values: Vec<f64>,
    weights: Vec<f64>,
}

impl TierSet {
    /// Weighted tiers. Rejects empty sets, non-positive / non-finite
    /// values or weights, and length mismatches.
    pub fn new(values: Vec<f64>, weights: Vec<f64>) -> Result<TierSet, SimError> {
        if values.is_empty() {
            return Err(SimError::BadTier { what: "tier values", why: "must not be empty" });
        }
        if values.len() != weights.len() {
            return Err(SimError::BadTier {
                what: "tier weights",
                why: "must have one weight per value",
            });
        }
        if !values.iter().all(|v| v.is_finite() && *v > 0.0) {
            return Err(SimError::BadTier {
                what: "tier values",
                why: "must be positive and finite",
            });
        }
        if !weights.iter().all(|w| w.is_finite() && *w > 0.0) {
            return Err(SimError::BadTier {
                what: "tier weights",
                why: "must be positive and finite",
            });
        }
        Ok(TierSet { values, weights })
    }

    /// Equal-probability tiers.
    pub fn uniform(values: Vec<f64>) -> Result<TierSet, SimError> {
        let w = vec![1.0; values.len()];
        TierSet::new(values, w)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// One weighted draw (consumes exactly one `next_f64`).
    fn sample(&self, rng: &mut Pcg) -> f64 {
        let total: f64 = self.weights.iter().sum();
        let mut x = rng.next_f64() * total;
        for (v, w) in self.values.iter().zip(&self.weights) {
            if x < *w {
                return *v;
            }
            x -= w;
        }
        *self.values.last().unwrap() // x == total (fp edge): last tier
    }
}

/// One registered client's materialized characteristics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientProfile {
    /// local training cost, microseconds per (sample × epoch)
    pub us_per_sample: f64,
    /// link bandwidth, megabits per second (both directions)
    pub bandwidth_mbps: f64,
    /// one-way last-mile latency, microseconds
    pub latency_us: f64,
}

/// The lazily-profiled registered population.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::sim::{FleetModel, SimSpec};
///
/// let fleet = FleetModel::from_spec(&SimSpec::new(1_000_000, 100, 7));
/// let p = fleet.profile(123_456);
/// assert_eq!(p, fleet.profile(123_456)); // pure function of the id
/// assert!(p.bandwidth_mbps > 0.0);
/// ```
#[derive(Clone, Debug)]
pub struct FleetModel {
    seed: u64,
    device_us_per_sample: TierSet,
    bandwidth_mbps: TierSet,
    latency_ms: (f64, f64),
}

impl FleetModel {
    /// Build from a validated [`SimSpec`](crate::sim::SimSpec).
    pub fn from_spec(spec: &crate::sim::SimSpec) -> FleetModel {
        FleetModel {
            seed: spec.seed,
            device_us_per_sample: spec.device_us_per_sample.clone(),
            bandwidth_mbps: spec.bandwidth_mbps.clone(),
            latency_ms: spec.latency_ms,
        }
    }

    /// Materialize registered client `rid`'s profile — a pure function of
    /// `(fleet seed, rid)`, O(1) time, no stored state.
    pub fn profile(&self, rid: u32) -> ClientProfile {
        let mut rng =
            Pcg::new(self.seed ^ (rid as u64).wrapping_mul(MIX), PROFILE_STREAM);
        let us_per_sample = self.device_us_per_sample.sample(&mut rng);
        let bandwidth_mbps = self.bandwidth_mbps.sample(&mut rng);
        let (lo, hi) = self.latency_ms;
        let latency_us = (lo + (hi - lo) * rng.next_f64()) * 1_000.0;
        ClientProfile { us_per_sample, bandwidth_mbps, latency_us }
    }

    /// Virtual duration of one full exchange with client `rid`, in
    /// microseconds (excluding any straggler delay).
    pub fn exchange_us(
        &self,
        profile: &ClientProfile,
        down_bytes: usize,
        up_bytes: usize,
        samples: u64,
        epochs: usize,
    ) -> u64 {
        let transfer =
            |bytes: usize| bytes as f64 * 8.0 / profile.bandwidth_mbps; // µs at mbps
        let compute = samples as f64 * epochs as f64 * profile.us_per_sample;
        let total =
            2.0 * profile.latency_us + transfer(down_bytes) + compute + transfer(up_bytes);
        total.round() as u64
    }

    /// The availability model's straggler knob, made virtual: with
    /// probability `prob`, client `rid` replies `delay_ms` late in
    /// `round`. The draw is keyed by `(fleet seed, rid, round)` — never
    /// by wall time or worker schedule — so straggler hits are part of
    /// the reproducible trace.
    pub fn straggle_us(&self, rid: u32, round: u32, prob: f64, delay_ms: u64) -> u64 {
        if prob <= 0.0 || delay_ms == 0 {
            return 0;
        }
        let mut rng = Pcg::new(
            self.seed ^ (rid as u64).wrapping_mul(MIX) ^ (round as u64).rotate_left(32),
            STRAGGLE_STREAM,
        );
        if rng.next_f64() < prob {
            delay_ms * 1_000
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimSpec;

    fn fleet() -> FleetModel {
        FleetModel::from_spec(&SimSpec::new(1_000_000, 100, 42))
    }

    #[test]
    fn tierset_validates() {
        assert!(TierSet::new(vec![], vec![]).is_err());
        assert!(TierSet::new(vec![1.0], vec![1.0, 2.0]).is_err());
        assert!(TierSet::new(vec![0.0], vec![1.0]).is_err());
        assert!(TierSet::new(vec![-1.0], vec![1.0]).is_err());
        assert!(TierSet::new(vec![f64::NAN], vec![1.0]).is_err());
        assert!(TierSet::new(vec![1.0], vec![0.0]).is_err());
        assert!(TierSet::new(vec![1.0], vec![f64::INFINITY]).is_err());
        TierSet::new(vec![5.0, 50.0], vec![0.3, 0.7]).unwrap();
        TierSet::uniform(vec![1.0, 2.0, 3.0]).unwrap();
    }

    #[test]
    fn tier_sampling_tracks_weights() {
        let tiers = TierSet::new(vec![1.0, 10.0], vec![0.9, 0.1]).unwrap();
        let mut rng = Pcg::seeded(3);
        let n = 20_000;
        let slow = (0..n).filter(|_| tiers.sample(&mut rng) == 1.0).count();
        let frac = slow as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn profiles_are_pure_functions_of_id() {
        let f = fleet();
        for rid in [0u32, 1, 999_999, 123_456] {
            assert_eq!(f.profile(rid), f.profile(rid));
        }
        // distinct ids overwhelmingly get distinct profiles
        let distinct = (0..256)
            .map(|rid| f.profile(rid).latency_us.to_bits())
            .collect::<std::collections::HashSet<_>>();
        assert!(distinct.len() > 200);
        // and profiles stay inside the declared distributions
        let spec = SimSpec::new(10, 1, 42);
        for rid in 0..64 {
            let p = f.profile(rid);
            assert!(spec.device_us_per_sample.values().contains(&p.us_per_sample));
            assert!(spec.bandwidth_mbps.values().contains(&p.bandwidth_mbps));
            let (lo, hi) = spec.latency_ms;
            assert!(p.latency_us >= lo * 1000.0 && p.latency_us <= hi * 1000.0);
        }
    }

    #[test]
    fn exchange_time_is_monotone() {
        let f = fleet();
        let p = f.profile(7);
        let base = f.exchange_us(&p, 1000, 1000, 100, 1);
        assert!(base > 0);
        assert!(f.exchange_us(&p, 2000, 1000, 100, 1) > base);
        assert!(f.exchange_us(&p, 1000, 2000, 100, 1) > base);
        assert!(f.exchange_us(&p, 1000, 1000, 200, 1) > base);
        assert!(f.exchange_us(&p, 1000, 1000, 100, 2) > base);
    }

    #[test]
    fn straggler_draws_are_keyed_by_id_and_round() {
        let f = fleet();
        // deterministic per (rid, round)
        assert_eq!(f.straggle_us(5, 1, 0.5, 100), f.straggle_us(5, 1, 0.5, 100));
        // inert without a delay or probability
        assert_eq!(f.straggle_us(5, 1, 0.0, 100), 0);
        assert_eq!(f.straggle_us(5, 1, 0.5, 0), 0);
        // hit rate tracks the probability across the population
        let hits = (0..4_000u32).filter(|&rid| f.straggle_us(rid, 3, 0.25, 10) > 0).count();
        let frac = hits as f64 / 4_000.0;
        assert!((frac - 0.25).abs() < 0.03, "frac={frac}");
        // a hit is the full delay in microseconds
        let hit = (0..1_000u32)
            .map(|rid| f.straggle_us(rid, 3, 0.25, 10))
            .find(|&d| d > 0)
            .unwrap();
        assert_eq!(hit, 10_000);
    }
}
