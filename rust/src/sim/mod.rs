//! Virtual-time fleet simulator: deterministic discrete-event execution
//! of million-client federations.
//!
//! The paper's headline claims are about communication cost *at fleet
//! scale* — but real transports execute fleets in real time, so fleet
//! size is bounded by the worker pool and "time to accuracy" is bounded
//! by the wall clock. This subsystem replaces wall time with a virtual
//! clock so a 1M-registered-client T-FedAvg run finishes in seconds and
//! codec comparisons can be made on *modeled* client bandwidth and
//! device heterogeneity (the condition Sattler et al. and the
//! communication-perspective FL surveys put on meaningful codec
//! comparisons; see PAPERS.md).
//!
//! It plugs into the existing stack at exactly two seams:
//!
//! * [`SimTransport`] implements the [`Transport`](crate::transport::Transport)
//!   trait by *wrapping* the in-process `Loopback` — every payload byte,
//!   frame header, and `LinkStats` counter is byte-identical to a
//!   loopback run of the same cohort. On top, each exchange's wire bytes
//!   are converted into a virtual transfer time by the per-client
//!   bandwidth/latency model, local training becomes
//!   `samples × epochs × us_per_sample`, and availability stragglers
//!   become virtual delays (no `thread::sleep` anywhere).
//! * a virtual clock plus a `(time, seq)`-ordered event queue
//!   ([`EventQueue`]): worker threads push completion events in whatever
//!   order the OS schedules them; the drained trace and the round
//!   completion time depend only on the event keys, so results are
//!   bit-reproducible at any worker count.
//!
//! The registered population ([`FleetModel`]) is never materialized:
//! client profiles are pure functions of `(fleet seed, client id)`, so
//! memory stays O(cohort) + O(data shards) at any population size.
//! Registered client `r` trains on data shard `r % n_clients` — the
//! statistical substrate is shared; the *timing* identity is per client.
//!
//! Declared in a scenario manifest as a `[sim]` table (see
//! `examples/scenarios/sim_fleet.toml`), or driven directly through
//! [`Orchestrator::with_sim`](crate::coordinator::server::Orchestrator::with_sim).
//! DESIGN.md §9 derives the event model and the clock invariants.

pub mod event;
pub mod fleet;
pub mod transport;

use std::fmt;

pub use event::{EventQueue, SimEvent};
pub use fleet::{ClientProfile, FleetModel, TierSet};
pub use transport::SimTransport;

/// Typed validation error for simulator parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum SimError {
    /// Registered population is zero or exceeds the u32 client-id space.
    BadPopulation { registered: usize },
    /// Fewer registered clients than data shards (`n_clients`).
    PopulationSmallerThanShards { registered: usize, shards: usize },
    /// Cohort is zero or larger than the registered population.
    BadCohort { cohort: usize, registered: usize },
    /// A tier distribution is malformed.
    BadTier { what: &'static str, why: &'static str },
    /// Latency bounds are not `0 <= lo <= hi < inf`.
    BadLatency { lo: f64, hi: f64 },
    /// Target accuracy outside `(0, 1]`.
    BadTarget { target: f64 },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::BadPopulation { registered } => write!(
                f,
                "registered population must be in [1, {}], got {registered}",
                u32::MAX
            ),
            SimError::PopulationSmallerThanShards { registered, shards } => write!(
                f,
                "registered population {registered} is smaller than the {shards} data \
                 shards (clients); the sim maps registered ids onto shards, not the reverse"
            ),
            SimError::BadCohort { cohort, registered } => write!(
                f,
                "cohort must be in [1, registered={registered}], got {cohort}"
            ),
            SimError::BadTier { what, why } => write!(f, "{what} {why}"),
            SimError::BadLatency { lo, hi } => {
                write!(f, "latency bounds must satisfy 0 <= lo <= hi (finite), got [{lo}, {hi}]")
            }
            SimError::BadTarget { target } => {
                write!(f, "target accuracy must be in (0, 1], got {target}")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// A validated simulator configuration — the `[sim]` manifest table.
///
/// `registered` is the virtual fleet size; each round the coordinator
/// samples a `cohort` of registered ids (server RNG, O(cohort) memory)
/// and maps each onto one of the experiment's data shards. Device and
/// bandwidth heterogeneity are discrete tier distributions; last-mile
/// latency is uniform in `latency_ms`.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::sim::SimSpec;
///
/// let spec = SimSpec::new(100_000, 32, 7);
/// spec.validate_for(10).unwrap(); // 10 data shards
/// assert_eq!(spec.registered, 100_000);
/// ```
#[derive(Clone, Debug)]
pub struct SimSpec {
    /// virtual fleet size (ids `0..registered`)
    pub registered: usize,
    /// registered clients sampled per round
    pub cohort: usize,
    /// fleet seed: all per-client profile/straggler draws derive from it
    pub seed: u64,
    /// device-speed tiers, µs per (sample × epoch)
    pub device_us_per_sample: TierSet,
    /// link-bandwidth tiers, Mbit/s (both directions)
    pub bandwidth_mbps: TierSet,
    /// one-way latency drawn uniformly from `[lo, hi]` milliseconds
    pub latency_ms: (f64, f64),
    /// test-accuracy target for time-to-accuracy reporting (optional)
    pub target_acc: Option<f64>,
}

impl SimSpec {
    /// A spec with the default heterogeneity model: three device tiers
    /// (phone / laptop / workstation-ish), three bandwidth tiers
    /// (cellular / home / fiber-ish), 10–200 ms latency.
    pub fn new(registered: usize, cohort: usize, seed: u64) -> SimSpec {
        SimSpec {
            registered,
            cohort,
            seed,
            device_us_per_sample: TierSet::new(
                vec![400.0, 120.0, 30.0],
                vec![0.3, 0.5, 0.2],
            )
            .expect("default device tiers"),
            bandwidth_mbps: TierSet::new(vec![2.0, 20.0, 150.0], vec![0.5, 0.3, 0.2])
                .expect("default bandwidth tiers"),
            latency_ms: (10.0, 200.0),
            target_acc: None,
        }
    }

    /// Validate against the experiment's shard count (`n_clients`).
    /// Tier sets are validated at construction ([`TierSet::new`]); this
    /// checks the population/cohort geometry and the scalar bounds.
    pub fn validate_for(&self, shards: usize) -> Result<(), SimError> {
        if self.registered == 0 || self.registered > u32::MAX as usize {
            return Err(SimError::BadPopulation { registered: self.registered });
        }
        if self.registered < shards {
            return Err(SimError::PopulationSmallerThanShards {
                registered: self.registered,
                shards,
            });
        }
        if self.cohort == 0 || self.cohort > self.registered {
            return Err(SimError::BadCohort {
                cohort: self.cohort,
                registered: self.registered,
            });
        }
        let (lo, hi) = self.latency_ms;
        if !(lo.is_finite() && hi.is_finite() && 0.0 <= lo && lo <= hi) {
            return Err(SimError::BadLatency { lo, hi });
        }
        if let Some(t) = self.target_acc {
            if !(t > 0.0 && t <= 1.0) {
                return Err(SimError::BadTarget { target: t });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_validates() {
        SimSpec::new(1_000_000, 100, 1).validate_for(10).unwrap();
        SimSpec::new(10, 10, 1).validate_for(10).unwrap();
    }

    #[test]
    fn geometry_is_checked() {
        let err = SimSpec::new(0, 1, 1).validate_for(1).unwrap_err();
        assert!(matches!(err, SimError::BadPopulation { .. }));
        let err = SimSpec::new(5, 1, 1).validate_for(10).unwrap_err();
        assert!(matches!(err, SimError::PopulationSmallerThanShards { .. }));
        let err = SimSpec::new(100, 0, 1).validate_for(10).unwrap_err();
        assert!(matches!(err, SimError::BadCohort { .. }));
        let err = SimSpec::new(100, 101, 1).validate_for(10).unwrap_err();
        assert!(matches!(err, SimError::BadCohort { .. }));
        let mut huge = SimSpec::new(100, 1, 1);
        huge.registered = u32::MAX as usize + 1;
        assert!(matches!(
            huge.validate_for(1).unwrap_err(),
            SimError::BadPopulation { .. }
        ));
    }

    #[test]
    fn scalar_bounds_are_checked() {
        let mut s = SimSpec::new(100, 10, 1);
        s.latency_ms = (5.0, 1.0);
        assert!(matches!(s.validate_for(10).unwrap_err(), SimError::BadLatency { .. }));
        let mut s = SimSpec::new(100, 10, 1);
        s.latency_ms = (-1.0, 1.0);
        assert!(s.validate_for(10).is_err());
        let mut s = SimSpec::new(100, 10, 1);
        s.latency_ms = (0.0, f64::INFINITY);
        assert!(s.validate_for(10).is_err());
        for bad in [0.0, -0.5, 1.5, f64::NAN] {
            let mut s = SimSpec::new(100, 10, 1);
            s.target_acc = Some(bad);
            assert!(s.validate_for(10).is_err(), "target={bad}");
        }
        let mut s = SimSpec::new(100, 10, 1);
        s.target_acc = Some(1.0);
        s.validate_for(10).unwrap();
    }

    #[test]
    fn errors_display() {
        let e = SimError::BadCohort { cohort: 0, registered: 5 };
        assert!(format!("{e}").contains("cohort"));
        let e = SimError::BadTier { what: "tier values", why: "must not be empty" };
        assert!(format!("{e}").contains("tier values"));
    }
}
