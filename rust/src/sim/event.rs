//! Discrete-event core: a virtual-time event queue ordered by
//! `(time, seq)`.
//!
//! Virtual time is integer microseconds (`u64`) — never floats — so event
//! ordering has no platform- or optimization-dependent tie behavior. The
//! `seq` component breaks simultaneous-arrival ties deterministically
//! (the round driver uses the registered client id, which is unique
//! within a round's cohort), which is what makes the drained event trace
//! byte-reproducible at any worker-thread count: workers may *push*
//! events in any interleaving, but the pop order depends only on the
//! `(time, seq)` keys.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One simulated occurrence: client `client`'s upload arrived at the
/// server at virtual time `time_us`, during `round`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimEvent {
    pub round: u32,
    pub time_us: u64,
    pub client: u32,
}

/// Min-heap of pending events keyed by `(time_us, seq)`.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::sim::EventQueue;
///
/// let mut q = EventQueue::new();
/// q.push(20, 1);
/// q.push(10, 9);
/// q.push(10, 2); // same time: seq breaks the tie
/// assert_eq!(q.pop(), Some((10, 2)));
/// assert_eq!(q.pop(), Some((10, 9)));
/// assert_eq!(q.pop(), Some((20, 1)));
/// assert_eq!(q.pop(), None);
/// ```
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u32)>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new() }
    }

    /// Schedule an event at virtual time `time_us`; `seq` is the
    /// deterministic tie-breaker for simultaneous events.
    pub fn push(&mut self, time_us: u64, seq: u32) {
        self.heap.push(Reverse((time_us, seq)));
    }

    /// Earliest pending `(time_us, seq)`, removing it from the queue.
    pub fn pop(&mut self) -> Option<(u64, u32)> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = EventQueue::new();
        // pushed deliberately out of order
        for (t, s) in [(30, 0), (10, 5), (20, 7), (10, 1), (20, 2)] {
            q.push(t, s);
        }
        let drained: Vec<(u64, u32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![(10, 1), (10, 5), (20, 2), (20, 7), (30, 0)]);
    }

    #[test]
    fn push_order_never_changes_pop_order() {
        let mut events = vec![(5u64, 3u32), (5, 1), (1, 9), (9, 0), (5, 2)];
        let mut traces = Vec::new();
        for _ in 0..4 {
            let mut q = EventQueue::new();
            for &(t, s) in &events {
                q.push(t, s);
            }
            traces.push(std::iter::from_fn(|| q.pop()).collect::<Vec<_>>());
            events.rotate_left(1); // a different insertion interleaving
        }
        assert!(traces.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1, 1);
        q.push(2, 2);
        assert_eq!(q.len(), 2);
        q.pop();
        q.pop();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }
}
