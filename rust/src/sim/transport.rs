//! `SimTransport`: the `Transport` impl that swaps wall time for the
//! virtual clock.
//!
//! Payload handling is *delegated to the real `Loopback`* — the frame
//! codec runs, CRCs are checked, `LinkStats` count the same wire bytes —
//! so everything the coordinator and the benches measure about traffic is
//! byte-identical to a non-simulated run of the same cohort. The sim
//! layer only adds timing: after each exchange it computes the client's
//! virtual duration from the wire byte counts, the client's lazily-drawn
//! profile, and the (virtualized) availability straggler draw, then
//! schedules an arrival event. [`Transport::end_round`] drains the
//! events in `(time, seq)` order, advances the clock to the last
//! arrival, and hands the round's virtual duration to the round driver
//! for `RoundRecord::sim_secs`.
//!
//! Invariants (asserted by `tests/sim_e2e.rs`):
//! * the event trace and the clock are identical at any worker count —
//!   durations are pure functions of `(fleet seed, client id, round,
//!   payload bytes)`, and the queue orders by `(time, seq)`;
//! * `clock` is non-decreasing: round N+1 starts at round N's last
//!   arrival (server-side aggregation is modeled as instantaneous).

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::comms::Message;
use crate::sim::event::{EventQueue, SimEvent};
use crate::sim::fleet::FleetModel;
use crate::transport::{Loopback, RoundAssign, Transport, VirtualRoundTime};

struct SimState {
    /// virtual now, microseconds; round N+1 starts where round N ended
    clock_us: u64,
    /// arrivals scheduled for the round in flight
    pending: EventQueue,
    /// straggler delay injected this round (accounting), milliseconds
    round_straggle_ms: u64,
    /// drained arrival trace, every round (determinism fixture)
    log: Vec<SimEvent>,
}

/// Virtual-time transport over an inner in-process fleet.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::sim::{FleetModel, SimSpec, SimTransport};
/// use tfed::transport::{Loopback, Transport};
///
/// let spec = SimSpec::new(100_000, 16, 7);
/// let sim = SimTransport::new(
///     Loopback::new(Vec::new()), // attach ClientRuntimes for a live fleet
///     FleetModel::from_spec(&spec),
///     1,    // local epochs (compute-time model)
///     0.0,  // straggler probability
///     0,    // straggler delay, ms
/// );
/// assert_eq!(sim.n_clients(), 0);
/// assert_eq!(sim.clock_us(), 0);
/// ```
pub struct SimTransport<'a> {
    inner: Loopback<'a>,
    fleet: FleetModel,
    local_epochs: usize,
    straggler_prob: f64,
    straggler_delay_ms: u64,
    state: Mutex<SimState>,
}

impl<'a> SimTransport<'a> {
    /// Wrap an in-process fleet. `local_epochs` feeds the compute-time
    /// model (`samples × epochs × us_per_sample`); the straggler pair is
    /// the availability model's knob, made virtual.
    pub fn new(
        inner: Loopback<'a>,
        fleet: FleetModel,
        local_epochs: usize,
        straggler_prob: f64,
        straggler_delay_ms: u64,
    ) -> SimTransport<'a> {
        SimTransport {
            inner,
            fleet,
            local_epochs,
            straggler_prob,
            straggler_delay_ms,
            state: Mutex::new(SimState {
                clock_us: 0,
                pending: EventQueue::new(),
                round_straggle_ms: 0,
                log: Vec::new(),
            }),
        }
    }

    /// The virtual clock, microseconds since the start of the run.
    pub fn clock_us(&self) -> u64 {
        self.state.lock().unwrap().clock_us
    }

    /// The drained arrival trace so far (one entry per completed
    /// exchange, in `(time, seq)` order within each round).
    pub fn event_log(&self) -> Vec<SimEvent> {
        self.state.lock().unwrap().log.clone()
    }

    /// The fleet's heterogeneity model (profile lookups for reporting).
    pub fn fleet(&self) -> &FleetModel {
        &self.fleet
    }
}

/// Samples carried by an upstream update (drives the compute-time model).
fn update_samples(msg: &Message) -> Result<u64> {
    Ok(match msg {
        Message::TernaryUpdate(u) => u.num_samples,
        Message::DenseUpdate(u) => u.num_samples,
        Message::CodedUpdate(u) => u.num_samples,
        other => bail!("upstream message kind {} carries no sample count", other.kind()),
    })
}

impl Transport for SimTransport<'_> {
    fn n_clients(&self) -> usize {
        self.inner.n_clients()
    }

    fn round_trip(&self, cid: usize, assign: &RoundAssign, down_wire: &[u8]) -> Result<Message> {
        // the payload path IS the loopback path — byte-identical framing,
        // decoding, training, and LinkStats accounting; the measured
        // variant also hands back the upstream frame's wire length so the
        // reply is never re-serialized just to be weighed
        let (up, up_bytes) = self.inner.round_trip_measured(cid, assign, down_wire)?;

        // timing: pure function of (fleet seed, registered id, round,
        // wire bytes, samples) — independent of worker scheduling
        let rid = assign.client_id;
        let samples = update_samples(&up)?;
        let profile = self.fleet.profile(rid);
        let exchange_us = self.fleet.exchange_us(
            &profile,
            down_wire.len(),
            up_bytes,
            samples,
            self.local_epochs,
        );
        let straggle_us = self.fleet.straggle_us(
            rid,
            assign.round,
            self.straggler_prob,
            self.straggler_delay_ms,
        );

        let mut st = self.state.lock().unwrap();
        st.round_straggle_ms += straggle_us / 1_000;
        let arrival = st.clock_us + exchange_us + straggle_us;
        st.pending.push(arrival, rid);
        Ok(up)
    }

    fn link_stats(&self) -> Vec<crate::transport::LinkStats> {
        self.inner.link_stats()
    }

    fn shutdown(&self) -> Result<()> {
        self.inner.shutdown()
    }

    fn end_round(&self, round: u32) -> Option<VirtualRoundTime> {
        crate::obs_span!("sim.end_round");
        let mut st = self.state.lock().unwrap();
        let start = st.clock_us;
        let mut completion = start;
        let mut drained = 0u64;
        while let Some((time_us, client)) = st.pending.pop() {
            completion = completion.max(time_us);
            st.log.push(SimEvent { round, time_us, client });
            drained += 1;
        }
        st.clock_us = completion;
        let straggler_ms = std::mem::take(&mut st.round_straggle_ms);
        if crate::obs::enabled() {
            use crate::obs::metrics::{counter, gauge};
            counter("tfed_sim_events_total").add(drained);
            gauge("tfed_sim_clock_secs").set(completion as f64 / 1e6);
        }
        Some(VirtualRoundTime {
            round_secs: (completion - start) as f64 / 1e6,
            clock_secs: completion as f64 / 1e6,
            straggler_ms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::DenseGlobal;
    use crate::compress::CodecSpec;
    use crate::coordinator::backend::NativeBackend;
    use crate::coordinator::client::{ClientRuntime, ShardData};
    use crate::model::{init_params, mlp_schema};
    use crate::sim::SimSpec;
    use crate::transport::encode_data_frame;
    use crate::util::rng::Pcg;

    fn tiny_shard(seed: u64, n: usize) -> ShardData {
        let mut rng = Pcg::seeded(seed);
        ShardData {
            dim: 784,
            num_classes: 10,
            x: (0..n * 784).map(|_| rng.normal() * 0.3).collect(),
            y: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    fn dense_broadcast(seed: u64) -> Message {
        let schema = mlp_schema();
        let mut rng = Pcg::seeded(seed);
        let params = init_params(&schema, &mut rng);
        Message::DenseGlobal(DenseGlobal {
            round: 1,
            tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
        })
    }

    fn assign(rid: u32, round: u32) -> RoundAssign {
        RoundAssign {
            round,
            client_id: rid,
            rng_seed: 99,
            rng_stream: rid as u64,
            codec: CodecSpec::Dense,
        }
    }

    fn sim<'a>(backend: &'a NativeBackend, stragglers: (f64, u64)) -> SimTransport<'a> {
        let runtimes = (0..2u32)
            .map(|cid| ClientRuntime {
                client_id: cid,
                backend,
                shard: tiny_shard(cid as u64 + 1, 12),
                local_epochs: 1,
                lr: 0.05,
                codec: CodecSpec::Dense,
                adversary: Default::default(),
            })
            .collect();
        SimTransport::new(
            Loopback::new(runtimes),
            FleetModel::from_spec(&SimSpec::new(100_000, 4, 7)),
            1,
            stragglers.0,
            stragglers.1,
        )
    }

    #[test]
    fn rounds_advance_the_virtual_clock() {
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
        let t = sim(&backend, (0.0, 0));
        let wire = encode_data_frame(&dense_broadcast(2)).unwrap();
        // registered ids 1001/2002 map to shards 1001%2=1 and 2002%2=0
        t.round_trip(1001 % 2, &assign(1001, 1), &wire).unwrap();
        t.round_trip(2002 % 2, &assign(2002, 1), &wire).unwrap();
        let vt = t.end_round(1).unwrap();
        assert!(vt.round_secs > 0.0);
        assert_eq!(vt.clock_secs, vt.round_secs);
        assert_eq!(vt.straggler_ms, 0);
        let log = t.event_log();
        assert_eq!(log.len(), 2);
        assert!(log[0].time_us <= log[1].time_us);
        assert_eq!(t.clock_us(), (vt.clock_secs * 1e6).round() as u64);

        // a second round starts at the first round's completion
        t.round_trip(0, &assign(7, 2), &wire).unwrap();
        let vt2 = t.end_round(2).unwrap();
        assert!(vt2.clock_secs > vt.clock_secs);
        assert_eq!(t.event_log().len(), 3);
    }

    #[test]
    fn payloads_and_stats_match_plain_loopback() {
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
        let t = sim(&backend, (0.0, 0));
        let runtimes = (0..2u32)
            .map(|cid| ClientRuntime {
                client_id: cid,
                backend: &backend,
                shard: tiny_shard(cid as u64 + 1, 12),
                local_epochs: 1,
                lr: 0.05,
                codec: CodecSpec::Dense,
                adversary: Default::default(),
            })
            .collect();
        let lb = Loopback::new(runtimes);
        let wire = encode_data_frame(&dense_broadcast(2)).unwrap();
        for cid in 0..2 {
            let a = assign(cid as u32, 1);
            let from_sim = t.round_trip(cid, &a, &wire).unwrap();
            let from_lb = lb.round_trip(cid, &a, &wire).unwrap();
            assert_eq!(from_sim.encode(), from_lb.encode());
        }
        assert_eq!(t.stats(), lb.stats());
    }

    #[test]
    fn virtual_stragglers_delay_without_sleeping() {
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
        // probability 1: every exchange pays the full virtual delay
        let t = sim(&backend, (1.0, 30_000));
        let wire = encode_data_frame(&dense_broadcast(2)).unwrap();
        let started = std::time::Instant::now();
        t.round_trip(0, &assign(0, 1), &wire).unwrap();
        let vt = t.end_round(1).unwrap();
        assert!(vt.round_secs >= 30.0, "virtual delay missing: {}", vt.round_secs);
        assert_eq!(vt.straggler_ms, 30_000);
        // ... while wall time stayed at CPU speed (no 30 s sleep)
        assert!(started.elapsed() < std::time::Duration::from_secs(10));
    }

    #[test]
    fn empty_round_is_zero_time() {
        let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
        let t = sim(&backend, (0.0, 0));
        let vt = t.end_round(1).unwrap();
        assert_eq!(vt.round_secs, 0.0);
        assert!(t.event_log().is_empty());
    }
}
