//! Evaluation records: per-round results + JSON/CSV sinks.
//!
//! Every experiment produces a `RunMetrics`; the bench harness turns these
//! into the paper's tables/figures and EXPERIMENTS.md quotes them.
//!
//! Formerly `crate::metrics` — renamed so "metrics" unambiguously means
//! the observability registry ([`crate::obs::metrics`]). The old
//! re-export shim is gone; import from `crate::eval`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// One communication round (or centralized epoch-group).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// mean local training loss across selected clients
    pub train_loss: f32,
    /// test accuracy of the reported model (quantized for T-FedAvg/TTQ)
    pub test_acc: f32,
    pub test_loss: f32,
    /// upstream wire bytes this round, measured at the transport frame
    /// layer (all selected clients, frame headers included)
    pub up_bytes: u64,
    /// downstream wire bytes this round
    pub down_bytes: u64,
    /// upstream data frames this round (one per client upload)
    pub up_frames: u64,
    /// downstream data frames this round (one per client broadcast)
    pub down_frames: u64,
    pub wall_secs: f64,
    /// simulated round completion time in virtual seconds (last cohort
    /// arrival − round start, from `sim::SimTransport`); 0 when the run
    /// is not simulated
    pub sim_secs: f64,
    /// total straggler delay injected this round, in milliseconds —
    /// virtual under the simulator, configured-but-wall-capped on real
    /// transports (availability delay accounting)
    pub straggler_delay_ms: u64,
    pub selected: Vec<usize>,
    /// per-layer quantization factors, if the protocol has them:
    /// T-FedAvg: mean w^q per layer; TTQ: [wp..., wn...]
    pub factors: Vec<f32>,
    /// evaluated this round?
    pub evaluated: bool,
    /// clients whose updates the server rejected this round (typed
    /// per-client faults: malformed, mislabeled, sample-count mismatch,
    /// non-finite, failed exchange). Empty on honest rounds — and only
    /// emitted to JSON when non-empty, so honest bundles keep their
    /// historical bytes.
    pub rejected: Vec<u32>,
    /// clients whose updates the norm-clipping aggregator scaled down
    /// (empty for every other aggregation rule; same conditional JSON
    /// emission as `rejected`)
    pub clipped: Vec<u32>,
}

/// Whole-run metrics.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub config_summary: String,
    pub records: Vec<RoundRecord>,
}

impl RunMetrics {
    pub fn new(config_summary: String) -> Self {
        RunMetrics { config_summary, records: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_acc(&self) -> f32 {
        self.records
            .iter()
            .rev()
            .find(|r| r.evaluated)
            .map(|r| r.test_acc)
            .unwrap_or(0.0)
    }

    pub fn best_acc(&self) -> f32 {
        self.records
            .iter()
            .filter(|r| r.evaluated)
            .map(|r| r.test_acc)
            .fold(0.0, f32::max)
    }

    pub fn total_up_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.up_bytes).sum()
    }

    pub fn total_down_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.down_bytes).sum()
    }

    pub fn total_up_frames(&self) -> u64 {
        self.records.iter().map(|r| r.up_frames).sum()
    }

    pub fn total_down_frames(&self) -> u64 {
        self.records.iter().map(|r| r.down_frames).sum()
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.records.iter().map(|r| r.wall_secs).sum()
    }

    /// Total simulated time across all rounds (virtual seconds; 0 for
    /// non-simulated runs).
    pub fn total_sim_secs(&self) -> f64 {
        self.records.iter().map(|r| r.sim_secs).sum()
    }

    /// Round throughput on the virtual clock (None for non-simulated
    /// runs) — the bench's cross-codec "rounds per virtual hour" axis.
    pub fn rounds_per_virtual_hour(&self) -> Option<f64> {
        let secs = self.total_sim_secs();
        if secs > 0.0 {
            Some(self.records.len() as f64 * 3_600.0 / secs)
        } else {
            None
        }
    }

    /// Rounds needed to first reach `acc` (None if never).
    pub fn rounds_to_acc(&self, acc: f32) -> Option<usize> {
        self.records.iter().find(|r| r.evaluated && r.test_acc >= acc).map(|r| r.round)
    }

    /// Simulated time to first reach test accuracy `acc`: the virtual
    /// clock at the end of the first evaluated round whose accuracy
    /// meets the target (None if never reached, or not simulated).
    pub fn sim_secs_to_acc(&self, acc: f32) -> Option<f64> {
        if self.total_sim_secs() <= 0.0 {
            return None;
        }
        let mut clock = 0.0;
        for r in &self.records {
            clock += r.sim_secs;
            if r.evaluated && r.test_acc >= acc {
                return Some(clock);
            }
        }
        None
    }

    /// Accuracy series (round, acc) at evaluated rounds — Fig. 6/10 data.
    pub fn acc_series(&self) -> Vec<(usize, f32)> {
        self.records
            .iter()
            .filter(|r| r.evaluated)
            .map(|r| (r.round, r.test_acc))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("config", s(&self.config_summary)),
            ("final_acc", num(self.final_acc() as f64)),
            ("best_acc", num(self.best_acc() as f64)),
            ("total_up_bytes", num(self.total_up_bytes() as f64)),
            ("total_down_bytes", num(self.total_down_bytes() as f64)),
            ("total_wall_secs", num(self.total_wall_secs())),
            ("total_sim_secs", num(self.total_sim_secs())),
            (
                "rounds",
                arr(self
                    .records
                    .iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("round", num(r.round as f64)),
                            ("train_loss", num(r.train_loss as f64)),
                            ("test_acc", num(r.test_acc as f64)),
                            ("test_loss", num(r.test_loss as f64)),
                            ("up_bytes", num(r.up_bytes as f64)),
                            ("down_bytes", num(r.down_bytes as f64)),
                            ("up_frames", num(r.up_frames as f64)),
                            ("down_frames", num(r.down_frames as f64)),
                            ("wall_secs", num(r.wall_secs)),
                            ("sim_secs", num(r.sim_secs)),
                            ("straggler_delay_ms", num(r.straggler_delay_ms as f64)),
                            ("evaluated", Json::Bool(r.evaluated)),
                            (
                                "factors",
                                arr(r.factors.iter().map(|&f| num(f as f64)).collect()),
                            ),
                        ];
                        // emitted only when non-empty: honest-run JSON
                        // stays byte-identical to pre-adversary bundles
                        if !r.rejected.is_empty() {
                            fields.push((
                                "rejected",
                                arr(r.rejected.iter().map(|&c| num(c as f64)).collect()),
                            ));
                        }
                        if !r.clipped.is_empty() {
                            fields.push((
                                "clipped",
                                arr(r.clipped.iter().map(|&c| num(c as f64)).collect()),
                            ));
                        }
                        obj(fields)
                    })
                    .collect()),
            ),
        ])
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,test_acc,test_loss,up_bytes,down_bytes,up_frames,down_frames,wall_secs,sim_secs,straggler_delay_ms,evaluated\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{:.4},{:.6},{},{}\n",
                r.round,
                r.train_loss,
                r.test_acc,
                r.test_loss,
                r.up_bytes,
                r.down_bytes,
                r.up_frames,
                r.down_frames,
                r.wall_secs,
                r.sim_secs,
                r.straggler_delay_ms,
                r.evaluated as u8
            ));
        }
        out
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string_pretty())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_csv())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }
}

pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f32, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            test_acc: acc,
            test_loss: 0.5,
            up_bytes: up,
            down_bytes: up,
            up_frames: 2,
            down_frames: 2,
            wall_secs: 0.1,
            sim_secs: 0.0,
            straggler_delay_ms: 0,
            selected: vec![0, 1],
            factors: vec![0.1, 0.2],
            evaluated: true,
            rejected: vec![],
            clipped: vec![],
        }
    }

    #[test]
    fn aggregates() {
        let mut m = RunMetrics::new("test".into());
        m.push(rec(1, 0.5, 100));
        m.push(rec(2, 0.8, 100));
        m.push(rec(3, 0.7, 100));
        assert_eq!(m.final_acc(), 0.7);
        assert_eq!(m.best_acc(), 0.8);
        assert_eq!(m.total_up_bytes(), 300);
        assert_eq!(m.total_up_frames(), 6);
        assert_eq!(m.total_down_frames(), 6);
        assert_eq!(m.rounds_to_acc(0.75), Some(2));
        assert_eq!(m.rounds_to_acc(0.95), None);
        assert_eq!(m.acc_series().len(), 3);
    }

    #[test]
    fn json_and_csv_emit() {
        let mut m = RunMetrics::new("cfg".into());
        m.push(rec(1, 0.5, 42));
        let j = m.to_json().to_string();
        assert!(j.contains("\"final_acc\""));
        assert!(j.contains("\"up_bytes\":42"));
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_arr().unwrap().len(), 1);
        let csv = m.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn rejection_fields_appear_only_when_nonempty() {
        // honest round: no "rejected"/"clipped" keys at all, so bundles
        // from pre-adversary builds keep their exact bytes
        let mut honest = RunMetrics::new("cfg".into());
        honest.push(rec(1, 0.5, 10));
        let j = honest.to_json().to_string();
        assert!(!j.contains("\"rejected\""));
        assert!(!j.contains("\"clipped\""));

        let mut attacked = RunMetrics::new("cfg".into());
        let mut r = rec(1, 0.5, 10);
        r.rejected = vec![3, 7];
        r.clipped = vec![1];
        attacked.push(r);
        let j = attacked.to_json().to_string();
        assert!(j.contains("\"rejected\":[3,7]"));
        assert!(j.contains("\"clipped\":[1]"));
        Json::parse(&j).unwrap();
    }

    #[test]
    fn mb_conversion() {
        assert!((mb(1024 * 1024) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn virtual_time_aggregates() {
        // non-simulated runs: no virtual clock, no time-to-accuracy
        let mut plain = RunMetrics::new("plain".into());
        plain.push(rec(1, 0.9, 1));
        assert_eq!(plain.total_sim_secs(), 0.0);
        assert_eq!(plain.rounds_per_virtual_hour(), None);
        assert_eq!(plain.sim_secs_to_acc(0.5), None);

        let mut m = RunMetrics::new("sim".into());
        for (round, acc, secs) in [(1, 0.3, 40.0), (2, 0.6, 50.0), (3, 0.8, 30.0)] {
            let mut r = rec(round, acc, 10);
            r.sim_secs = secs;
            r.straggler_delay_ms = 500;
            m.push(r);
        }
        assert_eq!(m.total_sim_secs(), 120.0);
        // 3 rounds in 120 virtual seconds = 90 rounds/hour
        assert!((m.rounds_per_virtual_hour().unwrap() - 90.0).abs() < 1e-9);
        // 0.6 is first reached at the end of round 2 (40 + 50 virtual s)
        assert_eq!(m.sim_secs_to_acc(0.5), Some(90.0));
        assert_eq!(m.sim_secs_to_acc(0.99), None);
        // the new columns reach both sinks
        let j = m.to_json().to_string();
        assert!(j.contains("\"total_sim_secs\":120"));
        assert!(j.contains("\"sim_secs\":40"));
        assert!(j.contains("\"straggler_delay_ms\":500"));
        let csv = m.to_csv();
        assert!(csv.lines().next().unwrap().contains("sim_secs,straggler_delay_ms"));
    }
}
