//! Experiment configuration: the knobs of the paper's evaluation section.
//!
//! Presets mirror Table I + §V-A ("Basic configuration"); every bench and
//! example builds an `ExperimentConfig`, validates it, and hands it to
//! `coordinator::run_experiment`.

use anyhow::{bail, Result};

use crate::compress::CodecSpec;
use crate::coordinator::adversary::AdversarySpec;
use crate::coordinator::aggregation::AggregatorSpec;

/// Which algorithm of Table II to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Protocol {
    /// centralized full-precision SGD/Adam (paper "Baseline")
    Baseline,
    /// centralized two-factor trained ternary quantization (paper "TTQ")
    Ttq,
    /// canonical FedAvg (McMahan et al.)
    FedAvg,
    /// the paper's contribution
    TFedAvg,
}

impl Protocol {
    pub fn parse(s: &str) -> Result<Protocol> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "baseline" => Protocol::Baseline,
            "ttq" => Protocol::Ttq,
            "fedavg" => Protocol::FedAvg,
            "tfedavg" | "t-fedavg" => Protocol::TFedAvg,
            other => bail!("unknown protocol {other:?} (baseline|ttq|fedavg|tfedavg)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Protocol::Baseline => "Baseline",
            Protocol::Ttq => "TTQ",
            Protocol::FedAvg => "FedAvg",
            Protocol::TFedAvg => "T-FedAvg",
        }
    }

    pub fn is_centralized(&self) -> bool {
        matches!(self, Protocol::Baseline | Protocol::Ttq)
    }

    /// Weight width reported in Table II.
    pub fn weight_bits(&self) -> usize {
        match self {
            Protocol::Baseline | Protocol::FedAvg => 32,
            Protocol::Ttq | Protocol::TFedAvg => 2,
        }
    }

    /// The payload codec this protocol speaks unless overridden:
    /// T-FedAvg's wire format *is* the ternary codec; everything else
    /// ships dense f32.
    pub fn default_codec(&self) -> CodecSpec {
        match self {
            Protocol::TFedAvg => CodecSpec::Ternary,
            _ => CodecSpec::Dense,
        }
    }

    /// Inverse of [`Self::default_codec`]: the protocol a bare codec
    /// choice implies (`--codec ternary` means the T-FedAvg protocol,
    /// every other codec rides FedAvg's round path). The single source of
    /// truth for the CLI, benches, and examples.
    pub fn for_codec(codec: CodecSpec) -> Protocol {
        if codec == CodecSpec::Ternary {
            Protocol::TFedAvg
        } else {
            Protocol::FedAvg
        }
    }
}

/// Which synthetic task (DESIGN.md §3 substitution for MNIST/CIFAR10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// 28x28x1 -> MLP (paper: MNIST)
    MnistLike,
    /// 16x16x3 -> ResNetLite (paper: CIFAR10)
    CifarLike,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "mnist" | "mnist-like" | "mnistlike" => Task::MnistLike,
            "cifar" | "cifar10" | "cifar-like" | "cifarlike" => Task::CifarLike,
            other => bail!("unknown task {other:?} (mnist|cifar)"),
        })
    }

    pub fn model_name(&self) -> &'static str {
        match self {
            Task::MnistLike => "mlp",
            Task::CifarLike => "resnetlite",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::MnistLike => "mnist-like",
            Task::CifarLike => "cifar-like",
        }
    }

    /// Per-sample feature count of the task's synthetic dataset.
    pub fn dim(&self) -> usize {
        let (h, w, c) = self.image_shape();
        h * w * c
    }

    /// (side, side, channels) of the task's image-shaped samples
    /// (delegates to the dataset generator's constants — one source of
    /// truth for task geometry).
    pub fn image_shape(&self) -> (usize, usize, usize) {
        match self {
            Task::MnistLike => crate::data::synth::MNIST_LIKE_SHAPE,
            Task::CifarLike => crate::data::synth::CIFAR_LIKE_SHAPE,
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub protocol: Protocol,
    pub task: Task,
    /// total clients N (paper default 100; Table II uses 10 full-part.)
    pub n_clients: usize,
    /// participation ratio lambda (selected = max(1, round(lambda*N)))
    pub participation: f64,
    /// classes per client Nc (>= 10 means IID)
    pub nc: usize,
    /// unbalancedness beta (eq. 29); 1.0 = balanced
    pub beta: f64,
    /// Dirichlet(alpha) label-skew partition (Hsu et al. 2019); 0.0 =
    /// disabled (nc/beta drive the split). When > 0, nc and beta must be
    /// left at their IID/balanced defaults.
    pub dirichlet_alpha: f64,
    /// local batch size B (must have a matching train artifact)
    pub batch: usize,
    /// local epochs E per round
    pub local_epochs: usize,
    pub rounds: usize,
    pub lr: f32,
    pub seed: u64,
    /// evaluate every k rounds (1 = every round)
    pub eval_every: usize,
    pub train_samples: usize,
    pub test_samples: usize,
    /// run on the pure-Rust layer-graph backend instead of PJRT
    pub native_backend: bool,
    /// model override from the registry (`--model` / `[experiment] model`);
    /// empty = the task's default family (`mlp` / `resnetlite`). Native
    /// runs resolve this against [`crate::model::registry`].
    pub model: String,
    /// payload codec for model updates (both directions). T-FedAvg
    /// requires `ternary`; FedAvg accepts any registered codec
    /// (`--codec stc:k=0.01`, `quant8`, `fp16`, ...), `dense` being its
    /// uncompressed native format.
    pub codec: CodecSpec,
    /// server aggregation rule (`--aggregator` / `[experiment] aggregator`);
    /// `mean` is the streaming sample-weighted default, byte-identical to
    /// the pre-registry orchestrator.
    pub aggregator: AggregatorSpec,
    /// Byzantine-client behavior assignment (`[adversary]` manifest
    /// table); the honest default marks nobody.
    pub adversary: AdversarySpec,
}

impl ExperimentConfig {
    /// §V "Basic configuration" scaled to the synthetic datasets:
    /// N=10 full participation, B=64, E=5 (Table II setting).
    pub fn table2(protocol: Protocol, task: Task, seed: u64) -> Self {
        let cfg = ExperimentConfig {
            protocol,
            task,
            n_clients: 10,
            participation: 1.0,
            nc: 10,
            beta: 1.0,
            dirichlet_alpha: 0.0,
            batch: 64,
            local_epochs: 5,
            rounds: 30,
            lr: match task {
                Task::MnistLike => 0.05,
                Task::CifarLike => 0.002,
            },
            seed,
            eval_every: 1,
            train_samples: match task {
                Task::MnistLike => 8_000,
                Task::CifarLike => 4_000,
            },
            test_samples: 2_000,
            native_backend: false,
            model: String::new(),
            codec: protocol.default_codec(),
            aggregator: AggregatorSpec::Mean,
            adversary: AdversarySpec::honest(),
        };
        if protocol.is_centralized() {
            cfg.centralized()
        } else {
            cfg
        }
    }

    /// Paper §V-D setting: N=100, lambda=0.1, E=5 (Table IV / Fig. 10).
    pub fn large_federation(protocol: Protocol, task: Task, seed: u64) -> Self {
        let mut c = Self::table2(protocol, task, seed);
        c.n_clients = 100;
        c.participation = 0.1;
        c
    }

    /// The model this experiment trains: the explicit override, or the
    /// task's default family when `model` is empty.
    pub fn model_name(&self) -> &str {
        if self.model.is_empty() {
            self.task.model_name()
        } else {
            &self.model
        }
    }

    pub fn selected_per_round(&self) -> usize {
        ((self.participation * self.n_clients as f64).round() as usize)
            .max(1)
            .min(self.n_clients)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_clients == 0 {
            bail!("n_clients must be > 0");
        }
        // single (0, 1] check — NaN fails both comparisons and is rejected
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            bail!("participation must be in (0, 1]");
        }
        if self.nc == 0 {
            bail!("nc must be >= 1");
        }
        if !(self.beta > 0.0 && self.beta <= 1.0) {
            bail!("beta must be in (0, 1]");
        }
        if self.dirichlet_alpha != 0.0 {
            if !(self.dirichlet_alpha > 0.0 && self.dirichlet_alpha.is_finite()) {
                bail!(
                    "dirichlet alpha must be positive and finite (got {})",
                    self.dirichlet_alpha
                );
            }
            if self.nc < 10 || self.beta != 1.0 {
                bail!("dirichlet partition replaces nc/beta; leave nc >= 10 and beta = 1");
            }
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("lr must be positive and finite (got {})", self.lr);
        }
        if self.batch == 0 || self.local_epochs == 0 || self.rounds == 0 {
            bail!("batch, local_epochs, rounds must be > 0");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be > 0");
        }
        if self.train_samples < self.n_clients {
            bail!("need at least one sample per client");
        }
        if self.protocol.is_centralized() && self.n_clients != 1 {
            // centralized runs are modeled as a single client holding all data
            bail!("centralized protocols require n_clients == 1 (got {})", self.n_clients);
        }
        if self.native_backend {
            // the model must exist in the native registry and its input
            // geometry must match the task's dataset
            let def = crate::model::registry::model_def(self.model_name()).map_err(|e| {
                anyhow::anyhow!("native backend: {e}; pick one with --model / [experiment] model")
            })?;
            if def.schema.input_dim != self.task.dim() {
                bail!(
                    "model {:?} wants input dim {}, task {} provides {}",
                    self.model_name(),
                    def.schema.input_dim,
                    self.task.name(),
                    self.task.dim()
                );
            }
        }
        self.codec.check()?;
        self.aggregator.check()?;
        self.adversary.check()?;
        if self.protocol.is_centralized() {
            if self.aggregator != AggregatorSpec::Mean {
                bail!(
                    "centralized protocol {} aggregates nothing; --aggregator {} has no effect",
                    self.protocol.name(),
                    self.aggregator.name()
                );
            }
            if self.adversary.is_active() {
                bail!(
                    "centralized protocol {} has no client fleet to corrupt",
                    self.protocol.name()
                );
            }
        }
        match (self.protocol, self.codec) {
            (Protocol::TFedAvg, CodecSpec::Ternary) => {}
            (Protocol::TFedAvg, c) => bail!(
                "T-FedAvg's wire format is the ternary codec; --codec {} needs \
                 --protocol fedavg",
                c.name()
            ),
            (p, c) if p.is_centralized() && c != CodecSpec::Dense => bail!(
                "centralized protocol {} moves no payloads; --codec {} has no effect",
                p.name(),
                c.name()
            ),
            _ => {}
        }
        Ok(())
    }

    /// Normalize a centralized protocol config (1 client, full part.).
    pub fn centralized(mut self) -> Self {
        self.n_clients = 1;
        self.participation = 1.0;
        self.nc = usize::MAX;
        self.beta = 1.0;
        self.dirichlet_alpha = 0.0;
        self
    }

    /// One-line summary for logs/metrics. The codec is appended only when
    /// it differs from the protocol's native format, the model only when
    /// explicitly overridden, and the Nc field shows `Dir(alpha)` only
    /// under a Dirichlet partition, so default runs (T-FedAvg/ternary,
    /// FedAvg/dense, nc/beta splits, task-default models) keep their
    /// pre-scenario-engine summaries byte-for-byte.
    pub fn summary(&self) -> String {
        let mut codec = if self.codec != self.protocol.default_codec() {
            format!(" codec={}", self.codec.name())
        } else {
            String::new()
        };
        if !self.model.is_empty() {
            codec.push_str(&format!(" model={}", self.model));
        }
        if self.aggregator != AggregatorSpec::Mean {
            codec.push_str(&format!(" aggregator={}", self.aggregator.name()));
        }
        if self.adversary.is_active() {
            codec.push_str(&format!(" adversary={}", self.adversary.label()));
        }
        let nc = if self.dirichlet_alpha != 0.0 {
            format!("Dir({})", self.dirichlet_alpha)
        } else if self.nc >= 10 {
            "IID".to_string()
        } else {
            self.nc.to_string()
        };
        format!(
            "{} on {} | N={} lambda={} Nc={} beta={} B={} E={} rounds={} lr={} seed={}{codec}",
            self.protocol.name(),
            self.task.name(),
            self.n_clients,
            self.participation,
            nc,
            self.beta,
            self.batch,
            self.local_epochs,
            self.rounds,
            self.lr,
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1)
            .validate()
            .unwrap();
        ExperimentConfig::large_federation(Protocol::FedAvg, Task::CifarLike, 2)
            .validate()
            .unwrap();
        ExperimentConfig::table2(Protocol::Baseline, Task::MnistLike, 3)
            .validate()
            .unwrap();
    }

    #[test]
    fn selected_count() {
        let mut c = ExperimentConfig::large_federation(Protocol::TFedAvg, Task::MnistLike, 1);
        assert_eq!(c.selected_per_round(), 10);
        c.participation = 0.34;
        assert_eq!(c.selected_per_round(), 34);
        c.participation = 0.001;
        assert_eq!(c.selected_per_round(), 1);
    }

    #[test]
    fn validation_catches_errors() {
        let ok = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        let cases: Vec<fn(&mut ExperimentConfig)> = vec![
            |c| c.n_clients = 0,
            |c| c.participation = 0.0,
            |c| c.participation = 1.5,
            |c| c.participation = f64::NAN,
            |c| c.beta = 0.0,
            |c| c.beta = f64::NAN,
            |c| c.lr = 0.0,
            |c| c.lr = -0.1,
            |c| c.lr = f32::NAN,
            |c| c.lr = f32::INFINITY,
            |c| c.batch = 0,
            |c| c.rounds = 0,
            |c| c.eval_every = 0,
            |c| c.train_samples = 2,
        ];
        for f in cases {
            let mut c = ok.clone();
            f(&mut c);
            assert!(c.validate().is_err());
        }
        // centralized with many clients rejected
        let mut c = ok.clone();
        c.protocol = Protocol::Baseline;
        assert!(c.validate().is_err());
    }

    #[test]
    fn dirichlet_alpha_validation() {
        let ok = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        let mut c = ok.clone();
        c.dirichlet_alpha = 0.5;
        c.validate().unwrap();
        // bad alpha values
        for alpha in [-0.5, f64::NAN, f64::INFINITY] {
            let mut c = ok.clone();
            c.dirichlet_alpha = alpha;
            assert!(c.validate().is_err(), "alpha={alpha}");
        }
        // dirichlet + nc/beta partitions are mutually exclusive
        let mut c = ok.clone();
        c.dirichlet_alpha = 0.5;
        c.nc = 2;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.dirichlet_alpha = 0.5;
        c.beta = 0.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn summary_mentions_dirichlet_only_when_set() {
        let c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        assert!(c.summary().contains("Nc=IID"));
        assert!(!c.summary().contains("Dir("));
        let mut c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        c.dirichlet_alpha = 0.5;
        assert!(c.summary().contains("Nc=Dir(0.5)"), "{}", c.summary());
    }

    #[test]
    fn codec_protocol_pairing() {
        use crate::compress::CodecSpec;
        // FedAvg accepts any registered codec
        for codec in [
            CodecSpec::Dense,
            CodecSpec::Fp16,
            CodecSpec::Quant { bits: 8 },
            CodecSpec::Stc { k: 0.01 },
            CodecSpec::Ternary,
        ] {
            let mut c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
            c.codec = codec;
            c.validate().unwrap();
        }
        // T-FedAvg speaks ternary only
        let mut c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        assert_eq!(c.codec, CodecSpec::Ternary);
        c.codec = CodecSpec::Fp16;
        assert!(c.validate().is_err());
        // centralized protocols take no codec override
        let mut c = ExperimentConfig::table2(Protocol::Baseline, Task::MnistLike, 1);
        c.codec = CodecSpec::Fp16;
        assert!(c.validate().is_err());
        // invalid codec parameters are caught here too
        let mut c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        c.codec = CodecSpec::Quant { bits: 0 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn summary_mentions_codec_only_when_non_default() {
        use crate::compress::CodecSpec;
        let c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        assert!(!c.summary().contains("codec="));
        let c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        assert!(!c.summary().contains("codec="));
        let mut c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        c.codec = CodecSpec::Stc { k: 0.01 };
        assert!(c.summary().contains("codec=stc:k=0.01"), "{}", c.summary());
    }

    #[test]
    fn aggregator_and_adversary_validation() {
        use crate::coordinator::adversary::AdversarySpec;
        use crate::coordinator::aggregation::AggregatorSpec;
        let ok = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        // every registered rule validates on a federated protocol
        for s in ["mean", "trimmed_mean", "median", "norm_clip", "krum:2"] {
            let mut c = ok.clone();
            c.aggregator = AggregatorSpec::parse(s).unwrap();
            c.validate().unwrap();
        }
        // invalid rule parameters are caught here too
        let mut c = ok.clone();
        c.aggregator = AggregatorSpec::TrimmedMean { beta: 0.7 };
        assert!(c.validate().is_err());
        // adversary specs validate (and bad fractions are rejected)
        let mut c = ok.clone();
        c.adversary = AdversarySpec::parse("sign_flip", 0.3, 7).unwrap();
        c.validate().unwrap();
        let mut c = ok.clone();
        c.adversary.fraction = 2.0;
        assert!(c.validate().is_err());
        // centralized protocols accept neither knob
        let mut c = ExperimentConfig::table2(Protocol::Baseline, Task::MnistLike, 1);
        c.aggregator = AggregatorSpec::Median;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::table2(Protocol::Baseline, Task::MnistLike, 1);
        c.adversary = AdversarySpec::parse("sign_flip", 0.5, 0).unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn summary_mentions_aggregator_and_adversary_only_when_set() {
        use crate::coordinator::adversary::AdversarySpec;
        use crate::coordinator::aggregation::AggregatorSpec;
        let c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        assert!(!c.summary().contains("aggregator="));
        assert!(!c.summary().contains("adversary="));
        let mut c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        c.aggregator = AggregatorSpec::Median;
        c.adversary = AdversarySpec::parse("scale:10", 0.2, 3).unwrap();
        let s = c.summary();
        assert!(s.contains("aggregator=median"), "{s}");
        assert!(s.contains("adversary=scale:10@0.2"), "{s}");
    }

    #[test]
    fn model_resolution_and_validation() {
        // default: the task family, no summary noise
        let mut c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        assert_eq!(c.model_name(), "mlp");
        assert!(!c.summary().contains("model="));
        // explicit override shows up in the summary and resolves
        c.model = "mlp-large".into();
        c.native_backend = true;
        assert_eq!(c.model_name(), "mlp-large");
        assert!(c.summary().contains("model=mlp-large"), "{}", c.summary());
        c.validate().unwrap();
        // unknown native model rejected with the registry in the message
        c.model = "vgg".into();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("vgg") && err.contains("--model"), "{err}");
        // native + cifar without an explicit model: resnetlite is not native
        let mut c = ExperimentConfig::table2(Protocol::TFedAvg, Task::CifarLike, 1);
        c.native_backend = true;
        assert!(c.validate().is_err());
        // native cnn on the cifar task validates; on mnist the dims clash
        c.model = "cnn".into();
        c.validate().unwrap();
        let mut c = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        c.native_backend = true;
        c.model = "cnn".into();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("input dim"), "{err}");
    }

    #[test]
    fn task_dims_match_synth_shapes() {
        assert_eq!(Task::MnistLike.dim(), 784);
        assert_eq!(Task::CifarLike.dim(), 768);
        assert_eq!(Task::CifarLike.image_shape(), (16, 16, 3));
    }

    #[test]
    fn protocol_parse_and_bits() {
        assert_eq!(Protocol::parse("t-fedavg").unwrap(), Protocol::TFedAvg);
        assert_eq!(Protocol::parse("BASELINE").unwrap(), Protocol::Baseline);
        assert!(Protocol::parse("x").is_err());
        assert_eq!(Protocol::TFedAvg.weight_bits(), 2);
        assert_eq!(Protocol::FedAvg.weight_bits(), 32);
    }
}
