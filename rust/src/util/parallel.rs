//! Ordered parallel map over an index range — the shared worker-pool
//! idiom behind the round driver's client fan-out and the scenario
//! runner's `--jobs` grid execution.
//!
//! Work items are claimed from an atomic counter and results land in a
//! slot per index, so the output order is always `0..n` regardless of
//! which worker ran what — the property that keeps float-summation and
//! results-bundle ordering schedule-independent. A single worker runs
//! inline with no threads or locks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(0)`, `f(1)`, …, `f(n-1)` over up to `workers` threads and
/// return the results indexed by input position. Every index runs (no
/// short-circuiting — wrap errors in the result type); a panicking `f`
/// propagates out of the enclosing thread scope.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::util::parallel::parallel_map_indexed;
///
/// let squares = parallel_map_indexed(4, 2, |i| i * i);
/// assert_eq!(squares, vec![0, 1, 4, 9]);
/// ```
pub fn parallel_map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every claimed slot is written"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn preserves_index_order_at_any_worker_count() {
        for workers in [1, 2, 7, 64] {
            let out = parallel_map_indexed(23, workers, |i| i * 10);
            assert_eq!(out, (0..23).map(|i| i * 10).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn runs_every_index_exactly_once() {
        let calls = AtomicUsize::new(0);
        let out = parallel_map_indexed(100, 8, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn empty_and_oversubscribed_edges() {
        let out: Vec<usize> = parallel_map_indexed(0, 4, |i| i);
        assert!(out.is_empty());
        // more workers than items is clamped, not a spawn storm
        let out = parallel_map_indexed(2, 1000, |i| i);
        assert_eq!(out, vec![0, 1]);
        // workers = 0 behaves as sequential
        let out = parallel_map_indexed(3, 0, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn results_can_carry_errors_per_item() {
        let out: Vec<Result<usize, String>> =
            parallel_map_indexed(4, 2, |i| if i == 2 { Err(format!("item {i}")) } else { Ok(i) });
        assert!(out[0].is_ok() && out[1].is_ok() && out[3].is_ok());
        assert_eq!(out[2].as_ref().unwrap_err(), "item 2");
    }
}
