//! Minimal property-testing harness (offline stand-in for proptest).
//!
//! `forall(cases, |rng| ...)` runs a closure over many seeded RNGs; on
//! failure it reports the seed so the case is replayable:
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath)
//! use tfed::util::proptest::forall;
//! forall(64, |rng| {
//!     let n = 1 + rng.below(100) as usize;
//!     let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
//!     assert!(v.len() == n);
//! });
//! ```

use crate::util::rng::Pcg;

/// Base seed; override with TFED_PROP_SEED to reproduce a failure run.
fn base_seed() -> u64 {
    std::env::var("TFED_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF3D5_0001)
}

/// Run `f` for `cases` seeded RNGs; panics with the failing seed attached.
pub fn forall(cases: u64, f: impl Fn(&mut Pcg) + std::panic::RefUnwindSafe) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg::seeded(seed);
            f(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (TFED_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Shrink-ish helper: random vec of length in [1, max_len].
pub fn arb_vec_f32(rng: &mut Pcg, max_len: usize, scale: f32) -> Vec<f32> {
    let n = 1 + rng.below(max_len as u32) as usize;
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Random dims (r, c) with r*c <= cap.
pub fn arb_dims(rng: &mut Pcg, cap: usize) -> (usize, usize) {
    let r = 1 + rng.below(64) as usize;
    let c_max = (cap / r).max(1).min(512);
    let c = 1 + rng.below(c_max as u32) as usize;
    (r, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(32, |rng| {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            forall(8, |rng| {
                assert!(rng.next_f32() < 2.0); // passes
                panic!("intentional");
            });
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("TFED_PROP_SEED"), "{msg}");
    }

    #[test]
    fn arb_helpers_in_bounds() {
        forall(16, |rng| {
            let v = arb_vec_f32(rng, 100, 1.0);
            assert!((1..=100).contains(&v.len()));
            let (r, c) = arb_dims(rng, 4096);
            assert!(r * c <= 4096 * 2); // r<=64, c<=cap/r
        });
    }
}
