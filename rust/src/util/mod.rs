//! Infrastructure substrates built in-repo.
//!
//! This environment has no crates.io access beyond the vendored set
//! (`xla`, `anyhow`, `thiserror`, ...), so the usual ecosystem pieces —
//! `rand`, `serde`, `clap`, `criterion`, `proptest` — are implemented here
//! at the scale this system needs (DESIGN.md §3 Substitutions).

pub mod cli;
pub mod json;
pub mod logging;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
