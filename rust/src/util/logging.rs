//! Tiny leveled logger (offline stand-in for env_logger).
//!
//! Level comes from `TFED_LOG` (error|warn|info|debug|trace), default info;
//! unrecognized values warn once and fall back to info. `TFED_LOG=trace`
//! additionally opens the obs span-logging gate (`obs::trace::span`
//! completions are logged even when no `--trace-out` collection is on).
//! Output goes to stderr so stdout stays clean for bench CSV/tables.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Parse a `TFED_LOG` value; `None` for unrecognized input.
fn parse_level(s: &str) -> Option<u8> {
    match s {
        "error" => Some(0),
        "warn" => Some(1),
        "info" => Some(2),
        "debug" => Some(3),
        "trace" => Some(4),
        _ => None,
    }
}

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("TFED_LOG").as_deref() {
        Ok(value) => parse_level(value).unwrap_or_else(|| {
            // warn exactly once, even if two threads race the first parse
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "[tfed] unknown TFED_LOG value {value:?} \
                     (expected error|warn|info|debug|trace); using info"
                );
            });
            2
        }),
        Err(_) => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (benches silence info chatter).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{secs:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
            module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `LEVEL` is process-global; tests that mutate it hold this lock and
    /// restore the prior raw value (possibly the 255 "unset" sentinel) on
    /// exit, so they can't race other tests' `enabled()` checks.
    static LEVEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    struct RestoreLevel(u8);

    impl Drop for RestoreLevel {
        fn drop(&mut self) {
            LEVEL.store(self.0, Ordering::Relaxed);
        }
    }

    #[test]
    fn parse_level_accepts_every_documented_value() {
        assert_eq!(parse_level("error"), Some(0));
        assert_eq!(parse_level("warn"), Some(1));
        assert_eq!(parse_level("info"), Some(2));
        assert_eq!(parse_level("debug"), Some(3));
        assert_eq!(parse_level("trace"), Some(4));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level("INFO"), None); // values are case-sensitive
    }

    #[test]
    fn level_ordering() {
        let _serial = LEVEL_LOCK.lock().unwrap();
        let _restore = RestoreLevel(LEVEL.load(Ordering::Relaxed));
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        // stop short of Trace: that level opens the obs span-logging gate
        // and would race concurrently running obs tests
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
    }
}
