//! Minimal JSON parser + emitter (offline stand-in for serde_json).
//!
//! Parses `artifacts/manifest.json` (written by python aot.py) and emits
//! metrics/bench output. Supports the full JSON value grammar; numbers are
//! f64 (ints round-trip exactly up to 2^53, far beyond anything here).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Shape helper: `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- emission ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, Some(2), 0);
        s
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => emit_num(out, *n),
            Json::Str(s) => emit_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.emit(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    emit_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.emit(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn emit_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// -- convenience constructors ----------------------------------------------

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

// -- parser ------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            s.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode utf8 starting at c
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let chunk = std::str::from_utf8(&self.b[start..start + width])?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn round_trip() {
        let src = r#"{"models":{"mlp":{"params":[{"name":"w1","shape":[784,30]}],"lr":0.05}},"n":100}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn parse_real_manifest_shapes() {
        let v = Json::parse(r#"{"shape": [16, 64, 784]}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().as_shape().unwrap(), vec![16, 64, 784]);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""éA café 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA café 😀");
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn int_emission_is_exact() {
        assert_eq!(Json::Num(123456789.0).to_string(), "123456789");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
