//! Summary statistics for metrics and the bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (average of the middle two for even n; 0 for empty).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        (v[n / 2 - 1] + v[n / 2]) / 2.0
    }
}

/// Linear-interpolated quantile, q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// The paper's unbalancedness ratio beta = median(S_N) / max(S_N) (eq. 29).
pub fn unbalancedness(sizes: &[usize]) -> f64 {
    if sizes.is_empty() {
        return 1.0;
    }
    let v: Vec<f64> = sizes.iter().map(|&s| s as f64).collect();
    let mx = max(&v);
    if mx == 0.0 {
        1.0
    } else {
        median(&v) / mx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 0.5), 50.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert!((quantile(&xs, 0.95) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn beta_eq29() {
        // balanced: all clients equal -> beta = 1
        assert_eq!(unbalancedness(&[100, 100, 100]), 1.0);
        // extreme: one giant client
        let b = unbalancedness(&[1000, 10, 10, 10, 10]);
        assert!(b < 0.05, "{b}");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(unbalancedness(&[]), 1.0);
    }
}
