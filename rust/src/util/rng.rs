//! Deterministic PRNG: PCG-XSH-RR 64/32 + Box-Muller normals.
//!
//! Every stochastic decision in the system (dataset synthesis, sharding,
//! client selection, batch shuffling, parameter init) flows through this
//! generator with an explicit seed, so whole federated runs are replayable
//! bit-for-bit — a requirement for the paper-table benches.

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with an arbitrary value; `stream` picks an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// The (seed, stream) pair `fork(tag)` builds its child from. The
    /// round driver ships these over the transport so a remote client
    /// constructs the exact generator a local `fork` would have returned;
    /// sharing the mixing here keeps the two paths equivalent by
    /// construction.
    pub fn fork_params(&mut self, tag: u64) -> (u64, u64) {
        let s = self.next_u64();
        (s ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    /// Derive an independent generator (used per-client / per-round).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let (seed, stream) = self.fork_params(tag);
        Pcg::new(seed, stream)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_scaled(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Standard normal with f64 resolution (Box-Muller). The f32
    /// [`Self::normal`] is enough for weight init; the Gamma sampler's
    /// acceptance test wants the extra mantissa.
    fn normal_f64(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= 0.0 {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); `shape` > 0, finite.
    /// Shapes < 1 use the boost `Gamma(a) = Gamma(a+1) · U^(1/a)`.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0 && shape.is_finite(), "gamma shape must be positive, got {shape}");
        if shape < 1.0 {
            let u = self.next_f64();
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal_f64();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * (x * x) * (x * x) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha · 1_n) proportions: length `n`, sums
    /// to 1. Drives the label-skew partitioner (Hsu et al. 2019 style).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        assert!(n > 0, "dirichlet needs n > 0");
        let mut w: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = w.iter().sum();
        if !(s.is_finite() && s > 0.0) {
            // every gamma draw underflowed to zero (extreme alpha → 0).
            // The Dirichlet(alpha → 0) limit is a one-hot on a uniformly
            // random coordinate — NOT a uniform split, which would invert
            // the requested concentration.
            let mut w = vec![0.0; n];
            w[self.below(n as u32) as usize] = 1.0;
            return w;
        }
        for x in w.iter_mut() {
            *x /= s;
        }
        w
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose({k}) from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg::seeded(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(5);
        let n = 200_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(6);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Pcg::seeded(7);
        let picked = r.choose(100, 10);
        assert_eq!(picked.len(), 10);
        let mut s = picked.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn choose_all() {
        let mut r = Pcg::seeded(8);
        let mut picked = r.choose(5, 5);
        picked.sort_unstable();
        assert_eq!(picked, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn gamma_moments_match() {
        // Gamma(a, 1): mean = a, var = a
        let mut r = Pcg::seeded(10);
        for a in [0.3, 1.0, 2.5, 10.0] {
            let n = 50_000;
            let (mut s, mut s2) = (0f64, 0f64);
            for _ in 0..n {
                let x = r.gamma(a);
                assert!(x >= 0.0 && x.is_finite(), "a={a} x={x}");
                s += x;
                s2 += x * x;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - a).abs() < 0.1 * a.max(0.5), "a={a} mean={mean}");
            assert!((var - a).abs() < 0.2 * a.max(0.5), "a={a} var={var}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentrates() {
        let mut r = Pcg::seeded(11);
        for alpha in [0.1, 1.0, 100.0] {
            let w = r.dirichlet(alpha, 16);
            assert_eq!(w.len(), 16);
            let s: f64 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "alpha={alpha} sum={s}");
            assert!(w.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
        // large alpha -> near-uniform proportions
        let w = r.dirichlet(1e5, 10);
        for &x in &w {
            assert!((x - 0.1).abs() < 0.01, "w={w:?}");
        }
    }

    #[test]
    fn dirichlet_deterministic_given_seed() {
        let a = Pcg::seeded(12).dirichlet(0.5, 8);
        let b = Pcg::seeded(12).dirichlet(0.5, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn dirichlet_tiny_alpha_stays_concentrated() {
        // alpha -> 0 must approach a one-hot, never flatten to uniform —
        // even when every gamma draw underflows to exactly zero
        let mut r = Pcg::seeded(13);
        for alpha in [1e-4, 1e-6] {
            for _ in 0..20 {
                let w = r.dirichlet(alpha, 10);
                let s: f64 = w.iter().sum();
                assert!((s - 1.0).abs() < 1e-9, "alpha={alpha} sum={s}");
                // the mass must stay concentrated (dominant coordinate),
                // never flatten toward the 0.1-per-client uniform split
                let mx = w.iter().cloned().fold(0.0, f64::max);
                assert!(mx > 0.5, "alpha={alpha} not concentrated: {w:?}");
            }
        }
    }

    #[test]
    fn fork_diverges_from_parent() {
        let mut parent = Pcg::seeded(9);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
