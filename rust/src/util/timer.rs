//! Timing + micro-bench loop (offline stand-in for criterion).
//!
//! `bench(name, iters, f)` warms up, measures per-iteration wall time, and
//! returns summary stats; the bench binaries format these as the tables in
//! bench_output.txt.

use std::time::Instant;

use crate::util::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter  (median {:>8.3}, p95 {:>8.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.median_ns / 1e6,
            self.p95_ns / 1e6,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench_with_warmup(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        median_ns: stats::median(&samples),
        p95_ns: stats::quantile(&samples, 0.95),
        min_ns: stats::min(&samples),
        std_ns: stats::std_dev(&samples),
    }
}

pub fn bench(name: &str, iters: usize, f: impl FnMut()) -> BenchResult {
    bench_with_warmup(name, (iters / 10).max(1), iters, f)
}

/// Scoped wall-clock timer for coarse phases.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 10, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.p95_ns + 1.0);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.ms() >= 1.0);
    }
}
