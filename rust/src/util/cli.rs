//! Hand-rolled CLI argument parser (offline stand-in for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! accessors with defaults, and auto-generated `--help` text from the
//! declared options.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declared option for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed arguments plus the declared spec.
#[derive(Debug)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    spec: Vec<OptSpec>,
    prog: String,
    about: &'static str,
}

pub struct Cli {
    spec: Vec<OptSpec>,
    about: &'static str,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Cli { spec: Vec::new(), about }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.spec.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.spec.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.spec.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn parse_env(self) -> Result<Args> {
        let argv: Vec<String> = std::env::args().collect();
        self.parse(&argv)
    }

    pub fn parse(self, argv: &[String]) -> Result<Args> {
        let prog = argv.first().cloned().unwrap_or_default();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.help_text(&prog));
            }
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    self.check_known(k)?;
                    opts.insert(k.to_string(), v.to_string());
                } else {
                    self.check_known(body)?;
                    let is_flag = self
                        .spec
                        .iter()
                        .find(|s| s.name == body)
                        .map(|s| s.is_flag)
                        .unwrap_or(false);
                    if is_flag {
                        flags.push(body.to_string());
                    } else {
                        i += 1;
                        let v = argv
                            .get(i)
                            .ok_or_else(|| anyhow!("--{body} expects a value"))?;
                        opts.insert(body.to_string(), v.clone());
                    }
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { opts, flags, positional, spec: self.spec, prog, about: self.about })
    }

    fn check_known(&self, name: &str) -> Result<()> {
        if self.spec.iter().any(|s| s.name == name) {
            Ok(())
        } else {
            bail!("unknown option --{name} (see --help)")
        }
    }

    fn help_text(&self, prog: &str) -> String {
        let mut out = format!("{}\n\nUsage: {prog} [options]\n\nOptions:\n", self.about);
        for s in &self.spec {
            let kind = if s.is_flag { "" } else { " <value>" };
            let def = match &s.default {
                Some(d) if !s.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            out.push_str(&format!("  --{}{kind:<10} {}{def}\n", s.name, s.help));
        }
        out
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Result<String> {
        if let Some(v) = self.opts.get(name) {
            return Ok(v.clone());
        }
        if let Some(spec) = self.spec.iter().find(|s| s.name == name) {
            if let Some(d) = &spec.default {
                return Ok(d.clone());
            }
            bail!("missing required option --{name}");
        }
        bail!("option --{name} was never declared");
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name)?;
        v.parse().map_err(|e| anyhow!("--{name}={v}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        let v = self.get(name)?;
        v.parse().map_err(|e| anyhow!("--{name}={v}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name)?;
        v.parse().map_err(|e| anyhow!("--{name}={v}: {e}"))
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        Ok(self.get_f64(name)? as f32)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Was this option explicitly given on the command line (as opposed to
    /// falling back to its declared default)?
    pub fn is_set(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list: `--betas 0.1,0.5,1.0`.
    pub fn get_list_f64(&self, name: &str) -> Result<Vec<f64>> {
        self.get(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| anyhow!("--{name}: {e}")))
            .collect()
    }

    pub fn get_list_usize(&self, name: &str) -> Result<Vec<usize>> {
        self.get(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| anyhow!("--{name}: {e}")))
            .collect()
    }

    pub fn prog(&self) -> &str {
        &self.prog
    }

    pub fn about(&self) -> &str {
        self.about
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.split_whitespace().map(String::from))
            .collect()
    }

    fn cli() -> Cli {
        Cli::new("test tool")
            .opt("rounds", "100", "number of rounds")
            .opt("lr", "0.05", "learning rate")
            .req("model", "model name")
            .flag("quiet", "suppress logs")
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = cli().parse(&argv("--model mlp --rounds 7 --quiet run")).unwrap();
        assert_eq!(a.get("model").unwrap(), "mlp");
        assert_eq!(a.get_usize("rounds").unwrap(), 7);
        assert_eq!(a.get_f64("lr").unwrap(), 0.05); // default
        assert!(a.flag("quiet"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn is_set_distinguishes_defaults() {
        let a = cli().parse(&argv("--model mlp --rounds 7")).unwrap();
        assert!(a.is_set("rounds"));
        assert!(!a.is_set("lr")); // defaulted
        assert!(!a.is_set("nonexistent"));
    }

    #[test]
    fn equals_syntax() {
        let a = cli().parse(&argv("--model=mlp --lr=0.1")).unwrap();
        assert_eq!(a.get("model").unwrap(), "mlp");
        assert_eq!(a.get_f64("lr").unwrap(), 0.1);
    }

    #[test]
    fn missing_required_errors() {
        let a = cli().parse(&argv("--rounds 5")).unwrap();
        assert!(a.get("model").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse(&argv("--bogus 1")).is_err());
    }

    #[test]
    fn lists() {
        let c = Cli::new("t").opt("betas", "1.0", "beta list");
        let a = c.parse(&argv("--betas 0.1,0.5,1.0")).unwrap();
        assert_eq!(a.get_list_f64("betas").unwrap(), vec![0.1, 0.5, 1.0]);
    }

    #[test]
    fn help_is_error_with_text() {
        let err = cli().parse(&argv("--help")).unwrap_err();
        let text = format!("{err}");
        assert!(text.contains("--rounds"));
        assert!(text.contains("test tool"));
    }
}
