//! Deprecated alias of [`crate::eval`] (kept one release).
//!
//! The evaluation-record module (`RunMetrics`, `RoundRecord`, `mb`) moved
//! to [`crate::eval`] so that "metrics" unambiguously refers to the
//! observability registry ([`crate::obs::metrics`]). Update imports from
//! `tfed::metrics::…` to `tfed::eval::…`; this shim will be removed in
//! the next release.

pub use crate::eval::*;
