//! Minimal TOML parser (offline stand-in for the `toml` crate), in the
//! spirit of `util::json`.
//!
//! Covers exactly what scenario manifests need: `[table]` / `[a.b]`
//! headers, `key = value` pairs, `#` comments (string-aware), basic
//! strings with escapes, integers (with `_` separators), floats,
//! booleans, and single-line arrays. Unsupported TOML — multi-line
//! strings, datetimes, inline tables, array-of-tables — is rejected with
//! a line-numbered error rather than silently misparsed.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A TOML value. Ints and floats stay distinct so manifests can't
/// accidentally feed `2.5` into a round count.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn type_name(&self) -> &'static str {
        match self {
            TomlValue::Str(_) => "string",
            TomlValue::Int(_) => "integer",
            TomlValue::Float(_) => "float",
            TomlValue::Bool(_) => "boolean",
            TomlValue::Arr(_) => "array",
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.type_name()),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => bail!("expected integer, got {}", other.type_name()),
        }
    }

    /// Non-negative integer (sizes, counts, rounds).
    pub fn as_unsigned(&self) -> Result<u64> {
        let i = self.as_int()?;
        u64::try_from(i).map_err(|_| anyhow!("expected non-negative integer, got {i}"))
    }

    /// Floats; integers promote (TOML `1` is a valid probability).
    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {}", other.type_name()),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected boolean, got {}", other.type_name()),
        }
    }

    pub fn as_arr(&self) -> Result<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Ok(a),
            other => bail!("expected array, got {}", other.type_name()),
        }
    }
}

/// A parsed document: dotted table path → key → value. Keys above the
/// first table header live under the root table `""`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut tables: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
        let mut current = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with("[[") {
                bail!("line {lineno}: array-of-tables is not supported");
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {lineno}: unterminated table header {line:?}");
                };
                let name = name.trim();
                if name.is_empty() || !name.split('.').all(is_bare_key) {
                    bail!("line {lineno}: bad table name {name:?}");
                }
                if tables.contains_key(name) {
                    bail!("line {lineno}: duplicate table [{name}]");
                }
                tables.insert(name.to_string(), BTreeMap::new());
                current = name.to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {lineno}: expected `key = value` or `[table]`, got {line:?}");
            };
            let key = k.trim();
            if !is_bare_key(key) {
                bail!("line {lineno}: bad key {key:?} (bare keys only)");
            }
            let value = parse_value(v.trim())
                .map_err(|e| anyhow!("line {lineno}, key {key:?}: {e}"))?;
            let table = tables.entry(current.clone()).or_default();
            if table.insert(key.to_string(), value).is_some() {
                bail!("line {lineno}: duplicate key {key:?}");
            }
        }
        Ok(TomlDoc { tables })
    }

    /// The keys of one table (None if the table never appeared).
    pub fn table(&self, name: &str) -> Option<&BTreeMap<String, TomlValue>> {
        self.tables.get(name)
    }

    /// All table names that appeared (the root table only if it has keys).
    pub fn table_names(&self) -> Vec<&str> {
        self.tables.keys().map(String::as_str).collect()
    }

    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables.get(table).and_then(|t| t.get(key))
    }
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Cut a `#` comment, ignoring `#` inside basic strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    let chars: Vec<char> = s.chars().collect();
    let mut p = ValueParser { chars, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.chars.len() {
        bail!("trailing characters after value");
    }
    Ok(v)
}

struct ValueParser {
    chars: Vec<char>,
    i: usize,
}

impl ValueParser {
    fn skip_ws(&mut self) {
        while matches!(self.chars.get(self.i).copied(), Some(' ' | '\t')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<char> {
        self.chars.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of value"))
    }

    fn value(&mut self) -> Result<TomlValue> {
        self.skip_ws();
        match self.peek()? {
            '"' => self.string(),
            '[' => self.array(),
            '\'' => bail!("literal (single-quoted) strings are not supported"),
            _ => self.scalar(),
        }
    }

    fn string(&mut self) -> Result<TomlValue> {
        self.i += 1; // opening quote
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                '"' => return Ok(TomlValue::Str(out)),
                '\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        'n' => out.push('\n'),
                        'r' => out.push('\r'),
                        't' => out.push('\t'),
                        'u' => {
                            if self.i + 4 > self.chars.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex: String = self.chars[self.i..self.i + 4].iter().collect();
                            self.i += 4;
                            let cp = u32::from_str_radix(&hex, 16)
                                .map_err(|e| anyhow!("bad \\u escape {hex:?}: {e}"))?;
                            out.push(
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        other => bail!("unsupported escape \\{other}"),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn array(&mut self) -> Result<TomlValue> {
        self.i += 1; // opening bracket
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            if self.peek()? == ']' {
                self.i += 1;
                return Ok(TomlValue::Arr(items));
            }
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                ',' => self.i += 1, // trailing comma before ']' is fine
                ']' => {
                    self.i += 1;
                    return Ok(TomlValue::Arr(items));
                }
                c => bail!("expected ',' or ']' in array, got {c:?}"),
            }
        }
    }

    fn scalar(&mut self) -> Result<TomlValue> {
        let start = self.i;
        while let Some(&c) = self.chars.get(self.i) {
            if c == ',' || c == ']' {
                break;
            }
            self.i += 1;
        }
        let word: String = self.chars[start..self.i].iter().collect();
        let word = word.trim();
        match word {
            "" => bail!("empty value"),
            "true" => return Ok(TomlValue::Bool(true)),
            "false" => return Ok(TomlValue::Bool(false)),
            _ => {}
        }
        let num = word.replace('_', "");
        if !num.contains(['.', 'e', 'E']) {
            if let Ok(i) = num.parse::<i64>() {
                return Ok(TomlValue::Int(i));
            }
        }
        // floats: reject TOML-invalid forms the f64 parser would accept
        // ("inf", "nan" are valid TOML but useless in a manifest)
        if num.parse::<f64>().is_ok()
            && num.chars().all(|c| c.is_ascii_digit() || matches!(c, '.' | 'e' | 'E' | '+' | '-'))
        {
            return Ok(TomlValue::Float(num.parse::<f64>().unwrap()));
        }
        bail!("cannot parse value {word:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_manifest_shape() {
        let doc = TomlDoc::parse(
            r#"
# top comment
[scenario]
name = "paper_noniid"   # trailing comment

[experiment]
clients = 10
participation = 1.0
lr = 0.05
native = true
rounds = 1_000

[sweep]
seeds = [1, 2, 3]
partitions = ["iid", "nc:2"]
mixed = [1, 2.5, "x", true]
empty = []
"#,
        )
        .unwrap();
        assert_eq!(doc.get("scenario", "name").unwrap().as_str().unwrap(), "paper_noniid");
        assert_eq!(doc.get("experiment", "clients").unwrap().as_int().unwrap(), 10);
        assert_eq!(doc.get("experiment", "rounds").unwrap().as_int().unwrap(), 1000);
        assert_eq!(doc.get("experiment", "participation").unwrap().as_float().unwrap(), 1.0);
        assert_eq!(doc.get("experiment", "lr").unwrap().as_float().unwrap(), 0.05);
        assert!(doc.get("experiment", "native").unwrap().as_bool().unwrap());
        let seeds = doc.get("sweep", "seeds").unwrap().as_arr().unwrap();
        assert_eq!(seeds.len(), 3);
        assert_eq!(seeds[2].as_int().unwrap(), 3);
        let parts = doc.get("sweep", "partitions").unwrap().as_arr().unwrap();
        assert_eq!(parts[1].as_str().unwrap(), "nc:2");
        assert_eq!(doc.get("sweep", "mixed").unwrap().as_arr().unwrap().len(), 4);
        assert!(doc.get("sweep", "empty").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(doc.table_names(), vec!["experiment", "scenario", "sweep"]);
    }

    #[test]
    fn root_keys_and_dotted_tables() {
        let doc = TomlDoc::parse("top = 1\n[a.b]\nx = 2\n").unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("a.b", "x").unwrap().as_int().unwrap(), 2);
        assert!(doc.table("a").is_none());
    }

    #[test]
    fn strings_with_escapes_and_hashes() {
        let doc = TomlDoc::parse(r##"s = "a # not a comment \"q\" \n" # real"##).unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str().unwrap(), "a # not a comment \"q\" \n");
    }

    #[test]
    fn int_float_distinction() {
        let doc = TomlDoc::parse("i = 3\nf = 3.0\nneg = -2\nexp = 1e3\n").unwrap();
        assert_eq!(doc.get("", "i").unwrap().as_int().unwrap(), 3);
        assert!(doc.get("", "f").unwrap().as_int().is_err());
        assert_eq!(doc.get("", "f").unwrap().as_float().unwrap(), 3.0);
        assert_eq!(doc.get("", "i").unwrap().as_float().unwrap(), 3.0); // promotes
        assert_eq!(doc.get("", "neg").unwrap().as_int().unwrap(), -2);
        assert!(doc.get("", "neg").unwrap().as_unsigned().is_err());
        assert_eq!(doc.get("", "exp").unwrap().as_float().unwrap(), 1000.0);
    }

    #[test]
    fn rejects_malformed_lines() {
        for (src, why) in [
            ("not a kv", "bare text"),
            ("[unclosed", "unterminated header"),
            ("[]", "empty table name"),
            ("[a]\n[a]", "duplicate table"),
            ("x = 1\nx = 2", "duplicate key"),
            ("[[fleet]]", "array-of-tables"),
            ("x = ", "empty value"),
            ("x = [1, 2", "unterminated array"),
            ("x = \"unterminated", "unterminated string"),
            ("x = 'literal'", "literal strings"),
            ("x = nan", "nan scalar"),
            ("x = 1 2", "trailing characters"),
            ("a key = 1", "key with space"),
        ] {
            let r = TomlDoc::parse(src);
            assert!(r.is_err(), "accepted {why}: {src:?}");
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert!(format!("{err}").contains("line 2"), "{err}");
    }
}
