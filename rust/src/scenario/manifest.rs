//! Declarative experiment manifests: one TOML file names a fleet —
//! partition regime, availability/straggler model, codec, transport —
//! and the sweep axes that expand it into a grid of validated
//! `ExperimentConfig`s.
//!
//! Manifest schema (all keys optional unless noted; defaults match the
//! `tfed run` CLI flags exactly, so a single-cell manifest and the
//! equivalent flag-driven invocation produce byte-identical metrics):
//!
//! ```text
//! [scenario]                  # required
//! name = "paper_noniid"       # required: bundle + log label
//!
//! [experiment]
//! protocol = "tfedavg"        # baseline | ttq | fedavg | tfedavg
//! codec = "ternary"           # ternary | dense | fp16 | quant<b> | stc:k=<f>
//! task = "mnist"              # mnist | cifar
//! model = "mlp-large"         # native registry: mlp | mlp-large | cnn
//!                             # (omit for the task default)
//! clients = 10                # total clients N
//! participation = 1.0         # lambda
//! rounds = 30
//! local_epochs = 5
//! batch = 64
//! lr = 0.05                   # 0 = task default
//! seed = 42
//! train_samples = 8000        # 0 = task default
//! test_samples = 2000
//! eval_every = 1
//! native = true               # pure-Rust backend (no artifacts needed)
//! aggregator = "mean"         # mean | trimmed_mean[:beta] | median |
//!                             # norm_clip[:tau] | krum[:f]
//! kernel = "packed:2"         # native kernel tier (DESIGN.md §15):
//!                             # naive | blocked[:N] | packed[:N] |
//!                             # packed-naive; needs native = true.
//!                             # A local execution knob — never part of
//!                             # the wire config.
//!
//! [fleet]
//! partition = "nc:2"          # iid | nc:<k> | beta:<b> | dirichlet:alpha=<a>
//! transport = "loopback"      # loopback | tcp (tcp: single-cell grids only)
//! listen = "127.0.0.1:7878"   # tcp only
//!
//! [availability]
//! dropout = 0.1               # per-round client dropout probability
//! straggler_prob = 0.05       # P(surviving client replies late)
//! straggler_delay_ms = 50
//! phase_rounds = [10, 20]     # dropout becomes phase_dropout[i]
//! phase_dropout = [0.2, 0.5]  #   from round phase_rounds[i] onward
//!
//! [adversary]                 # Byzantine client axis (DESIGN.md §13)
//! behavior = "sign_flip"      # scale:<f> | sign_flip | replay |
//!                             # corrupt_frame | wrong_codec |
//!                             # wrong_samples | oversize
//! fraction = 0.3              # P(a client is adversarial); default 1.0
//! seed = 7                    # behavior-assignment seed; default 0
//!
//! [sim]                       # virtual-time fleet simulation (DESIGN.md §9)
//! registered_clients = 100000 # required: virtual fleet size (≥ clients)
//! cohort = 16                 # sampled per round; default: selected_per_round
//! seed = 99                   # fleet seed; default: experiment seed
//! device_us_per_sample = [400.0, 120.0, 30.0]   # device-speed tiers
//! device_weights = [0.3, 0.5, 0.2]              # default: uniform
//! bandwidth_mbps = [2.0, 20.0, 150.0]           # link tiers
//! bandwidth_weights = [0.5, 0.3, 0.2]
//! latency_ms = [10.0, 200.0]  # one-way latency, uniform in [lo, hi]
//! target_acc = 0.5            # time-to-accuracy target (optional)
//!
//! [sweep]          # grid = models × partitions × codecs × aggregators × seeds
//! seeds = [1, 2, 3]           # default: [experiment seed]
//! partitions = ["iid", "nc:2"]  # default: [fleet partition]
//! codecs = ["ternary", "stc:k=0.01"]  # default: [experiment codec]
//! models = ["mlp", "mlp-large"]  # default: [experiment model]
//! aggregators = ["mean", "median"]  # default: [experiment aggregator]
//!
//! [observability]             # phase tracing + metrics (DESIGN.md §11-12)
//! trace_out = "trace.json"    # Chrome trace events; `--trace-out` overrides
//! metrics_out = "metrics.prom"  # Prometheus text; `--metrics-out` overrides
//! telemetry_out = "telemetry.jsonl"  # per-round learning telemetry;
//!                              # `--telemetry-out` overrides
//! ledger_out = "runs.tfed"    # append-only cross-run ledger;
//!                              # `--ledger-out` overrides (DESIGN.md §14)
//!
//! [output]
//! path = "results.json"       # bundle sink; `--out` overrides
//! ```
//!
//! A `[sim]` table switches every cell onto the virtual-time simulator:
//! straggler delays become virtual, `wall_secs` is zeroed in the bundle
//! (wall time is not a property of a simulated system, and zeroing it
//! makes bundles byte-reproducible), and per-round `sim_secs` carries the
//! simulated timing. `[sim]` composes with loopback fleets only.
//!
//! Unknown tables and keys are rejected (typo safety), and every grid
//! cell passes `ExperimentConfig::validate` before anything runs.

use anyhow::{anyhow, bail, Context, Result};

use crate::compress::CodecSpec;
use crate::config::{ExperimentConfig, Protocol, Task};
use crate::coordinator::adversary::{behavior_names, AdversarySpec};
use crate::coordinator::aggregation::AggregatorSpec;
use crate::coordinator::availability::{AvailabilityModel, Phase};
use crate::data::partition::PartitionStrategy;
use crate::native::KernelPolicy;
use crate::scenario::toml::TomlDoc;
use crate::sim::{SimSpec, TierSet};

/// Which transport the runner drives the fleet over.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetTransport {
    /// In-process loopback (full frame codec, same accounting as TCP).
    Loopback,
    /// Real sockets: bind `listen`, wait for `clients` remote `tfed
    /// client` processes. Restricted to single-cell grids (the config
    /// handshake happens once per connection).
    Tcp { listen: String },
}

/// A parsed, validated scenario manifest.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::scenario::ScenarioManifest;
///
/// let m = ScenarioManifest::parse(
///     r#"
/// [scenario]
/// name = "demo"
/// [experiment]
/// rounds = 2
/// native = true
/// [fleet]
/// partition = "dirichlet:alpha=0.5"
/// [sweep]
/// seeds = [1, 2]
/// "#,
/// )
/// .unwrap();
/// assert_eq!(m.name, "demo");
/// assert_eq!(m.grid().unwrap().len(), 2);
/// ```
#[derive(Clone, Debug)]
pub struct ScenarioManifest {
    pub name: String,
    /// Per-cell template; sweep axes overwrite seed / partition / codec.
    pub base: ExperimentConfig,
    /// Was `[experiment] protocol` given explicitly? If not, each cell's
    /// protocol follows its codec (`Protocol::for_codec`), mirroring the
    /// CLI's `--codec`-implies-protocol rule.
    pub protocol_pinned: bool,
    /// Native kernel tier from `[experiment] kernel` (None = backend
    /// default). Lives on the manifest, NOT on `ExperimentConfig`: the
    /// config crosses the wire in the handshake Config frame, and a local
    /// execution knob must never change those bytes.
    pub kernel: Option<KernelPolicy>,
    pub availability: AvailabilityModel,
    pub transport: FleetTransport,
    /// Virtual-time fleet simulation (`[sim]` table); None = real time.
    pub sim: Option<SimSpec>,
    pub sweep: SweepSpec,
    /// Results-bundle path from `[output] path` (CLI `--out` overrides).
    pub output: Option<String>,
    /// Chrome trace sink from `[observability] trace_out`
    /// (CLI `--trace-out` overrides). Either obs sink turns tracing on;
    /// the results bundle stays byte-identical either way.
    pub trace_out: Option<String>,
    /// Prometheus text sink from `[observability] metrics_out`
    /// (CLI `--metrics-out` overrides).
    pub metrics_out: Option<String>,
    /// Per-round learning-telemetry JSONL sink from
    /// `[observability] telemetry_out` (CLI `--telemetry-out`
    /// overrides). Enables telemetry for the run; DESIGN.md §12.
    pub telemetry_out: Option<String>,
    /// Cross-run ledger from `[observability] ledger_out`
    /// (CLI `--ledger-out` overrides): every cell is appended as
    /// durable run records after the bundle is written. DESIGN.md §14.
    pub ledger_out: Option<String>,
}

/// The sweep axes; the grid is their cartesian product.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub seeds: Vec<u64>,
    pub partitions: Vec<PartitionStrategy>,
    pub codecs: Vec<CodecSpec>,
    /// registry model names; `""` = the task default (no override)
    pub models: Vec<String>,
    /// robust-aggregation rules (defense axis for adversary grids)
    pub aggregators: Vec<AggregatorSpec>,
}

/// One fully-resolved grid cell.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub cfg: ExperimentConfig,
    /// Canonical partition-strategy name (results-bundle label).
    pub partition: String,
}

impl GridCell {
    /// Stable display label: `seed=7 partition=nc:2 codec=ternary`, with
    /// ` model=<name>` appended only under an explicit model and
    /// ` aggregator=<rule>` only under a non-default aggregation rule (so
    /// default grids keep their pre-registry labels byte for byte).
    pub fn label(&self) -> String {
        let model = if self.cfg.model.is_empty() {
            String::new()
        } else {
            format!(" model={}", self.cfg.model)
        };
        let agg = if self.cfg.aggregator == AggregatorSpec::Mean {
            String::new()
        } else {
            format!(" aggregator={}", self.cfg.aggregator.name())
        };
        format!(
            "seed={} partition={} codec={}{model}{agg}",
            self.cfg.seed,
            self.partition,
            self.cfg.codec.name()
        )
    }
}

const TABLES: &[&str] = &[
    "scenario",
    "experiment",
    "fleet",
    "availability",
    "adversary",
    "sim",
    "sweep",
    "observability",
    "output",
];
const SCENARIO_KEYS: &[&str] = &["name"];
const EXPERIMENT_KEYS: &[&str] = &[
    "protocol",
    "codec",
    "task",
    "model",
    "clients",
    "participation",
    "rounds",
    "local_epochs",
    "batch",
    "lr",
    "seed",
    "train_samples",
    "test_samples",
    "eval_every",
    "native",
    "aggregator",
    "kernel",
];
const FLEET_KEYS: &[&str] = &["partition", "transport", "listen"];
const AVAILABILITY_KEYS: &[&str] =
    &["dropout", "straggler_prob", "straggler_delay_ms", "phase_rounds", "phase_dropout"];
const ADVERSARY_KEYS: &[&str] = &["behavior", "fraction", "seed"];
const SIM_KEYS: &[&str] = &[
    "registered_clients",
    "cohort",
    "seed",
    "device_us_per_sample",
    "device_weights",
    "bandwidth_mbps",
    "bandwidth_weights",
    "latency_ms",
    "target_acc",
];
const SWEEP_KEYS: &[&str] = &["seeds", "partitions", "codecs", "models", "aggregators"];
const OBSERVABILITY_KEYS: &[&str] = &["trace_out", "metrics_out", "telemetry_out", "ledger_out"];
const OUTPUT_KEYS: &[&str] = &["path"];

impl ScenarioManifest {
    /// Read and parse a manifest file.
    pub fn load(path: &str) -> Result<ScenarioManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        Self::parse(&text).with_context(|| format!("manifest {path:?}"))
    }

    /// Parse and validate manifest text.
    pub fn parse(text: &str) -> Result<ScenarioManifest> {
        let doc = TomlDoc::parse(text)?;
        check_surface(&doc)?;

        let name = doc
            .get("scenario", "name")
            .ok_or_else(|| anyhow!("manifest needs `[scenario] name = \"...\"`"))?
            .as_str()
            .context("[scenario] name")?
            .to_string();
        if name.is_empty() {
            bail!("[scenario] name must not be empty");
        }

        // -- [experiment]: identical resolution order to the CLI ---------
        let protocol_given = doc.get("experiment", "protocol").is_some();
        let mut protocol = match doc.get("experiment", "protocol") {
            Some(v) => Protocol::parse(v.as_str().context("[experiment] protocol")?)?,
            None => Protocol::TFedAvg, // the CLI default
        };
        let codec = match doc.get("experiment", "codec") {
            Some(v) => Some(CodecSpec::parse(v.as_str().context("[experiment] codec")?)?),
            None => None,
        };
        if let Some(spec) = codec {
            if !protocol_given {
                protocol = Protocol::for_codec(spec);
            }
        }
        let task = match doc.get("experiment", "task") {
            Some(v) => Task::parse(v.as_str().context("[experiment] task")?)?,
            None => Task::MnistLike,
        };
        let model = match doc.get("experiment", "model") {
            Some(v) => v.as_str().context("[experiment] model")?.to_string(),
            None => String::new(),
        };
        let seed = get_unsigned(&doc, "experiment", "seed")?.unwrap_or(42);
        let mut base = ExperimentConfig::table2(protocol, task, seed);
        if let Some(spec) = codec {
            base.codec = spec;
        }
        base.model = model;
        if !protocol.is_centralized() {
            if let Some(n) = get_unsigned(&doc, "experiment", "clients")? {
                base.n_clients = n as usize;
            }
            if let Some(p) = get_float(&doc, "experiment", "participation")? {
                base.participation = p;
            }
        }
        if let Some(n) = get_unsigned(&doc, "experiment", "batch")? {
            base.batch = n as usize;
        }
        if let Some(n) = get_unsigned(&doc, "experiment", "local_epochs")? {
            base.local_epochs = n as usize;
        }
        if let Some(n) = get_unsigned(&doc, "experiment", "rounds")? {
            base.rounds = n as usize;
        }
        if let Some(n) = get_unsigned(&doc, "experiment", "eval_every")? {
            base.eval_every = n as usize;
        }
        if let Some(n) = get_unsigned(&doc, "experiment", "test_samples")? {
            base.test_samples = n as usize;
        }
        if let Some(lr) = get_float(&doc, "experiment", "lr")? {
            if lr > 0.0 {
                base.lr = lr as f32;
            }
        }
        if let Some(n) = get_unsigned(&doc, "experiment", "train_samples")? {
            if n > 0 {
                base.train_samples = n as usize;
            }
        }
        if let Some(v) = doc.get("experiment", "native") {
            base.native_backend = v.as_bool().context("[experiment] native")?;
        }
        if let Some(v) = doc.get("experiment", "aggregator") {
            base.aggregator =
                AggregatorSpec::parse(v.as_str().context("[experiment] aggregator")?)
                    .map_err(|e| anyhow!("[experiment] aggregator: {e}"))?;
        }
        let kernel = match doc.get("experiment", "kernel") {
            Some(v) => {
                let spec = v.as_str().context("[experiment] kernel")?;
                if !base.native_backend {
                    bail!("[experiment] kernel selects a native kernel tier; it needs native = true");
                }
                Some(
                    KernelPolicy::parse(spec)
                        .map_err(|e| anyhow!("[experiment] kernel: {e}"))?,
                )
            }
            None => None,
        };

        // -- [fleet] ------------------------------------------------------
        let partition = match doc.get("fleet", "partition") {
            Some(v) => PartitionStrategy::parse(v.as_str().context("[fleet] partition")?)?,
            None => PartitionStrategy::Iid,
        };
        let transport = match doc.get("fleet", "transport") {
            None => FleetTransport::Loopback,
            Some(v) => match v.as_str().context("[fleet] transport")? {
                "loopback" => FleetTransport::Loopback,
                "tcp" => {
                    let listen = match doc.get("fleet", "listen") {
                        Some(l) => l.as_str().context("[fleet] listen")?.to_string(),
                        None => "127.0.0.1:7878".to_string(),
                    };
                    FleetTransport::Tcp { listen }
                }
                other => bail!("[fleet] transport must be loopback | tcp, got {other:?}"),
            },
        };
        if transport == FleetTransport::Loopback && doc.get("fleet", "listen").is_some() {
            bail!("[fleet] listen only applies to transport = \"tcp\"");
        }

        // -- [availability] -----------------------------------------------
        let availability = parse_availability(&doc)?;

        // -- [adversary] --------------------------------------------------
        base.adversary = parse_adversary(&doc)?;

        // -- [sim] --------------------------------------------------------
        let sim = parse_sim(&doc, &base)?;
        if sim.is_some() {
            if !matches!(transport, FleetTransport::Loopback) {
                bail!(
                    "[sim] replaces the transport with the virtual-time simulator; \
                     it cannot combine with [fleet] transport = \"tcp\""
                );
            }
            if protocol_given && protocol.is_centralized() {
                bail!("[sim] requires a federated protocol (fedavg | tfedavg)");
            }
        }

        // -- [sweep] ------------------------------------------------------
        let seeds = match doc.get("sweep", "seeds") {
            None => vec![seed],
            Some(v) => {
                let arr = v.as_arr().context("[sweep] seeds")?;
                if arr.is_empty() {
                    bail!("[sweep] seeds must not be empty");
                }
                arr.iter()
                    .map(|s| s.as_unsigned())
                    .collect::<Result<Vec<u64>>>()
                    .context("[sweep] seeds")?
            }
        };
        let partitions = match doc.get("sweep", "partitions") {
            None => vec![partition],
            Some(v) => {
                let arr = v.as_arr().context("[sweep] partitions")?;
                if arr.is_empty() {
                    bail!("[sweep] partitions must not be empty");
                }
                arr.iter()
                    .map(|s| PartitionStrategy::parse(s.as_str()?))
                    .collect::<Result<Vec<_>>>()
                    .context("[sweep] partitions")?
            }
        };
        let codecs = match doc.get("sweep", "codecs") {
            None => vec![base.codec],
            Some(v) => {
                let arr = v.as_arr().context("[sweep] codecs")?;
                if arr.is_empty() {
                    bail!("[sweep] codecs must not be empty");
                }
                arr.iter()
                    .map(|s| CodecSpec::parse(s.as_str()?))
                    .collect::<Result<Vec<_>>>()
                    .context("[sweep] codecs")?
            }
        };
        let models = match doc.get("sweep", "models") {
            None => vec![base.model.clone()],
            Some(v) => {
                let arr = v.as_arr().context("[sweep] models")?;
                if arr.is_empty() {
                    bail!("[sweep] models must not be empty");
                }
                arr.iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()
                    .context("[sweep] models")?
            }
        };
        let aggregators = match doc.get("sweep", "aggregators") {
            None => vec![base.aggregator],
            Some(v) => {
                let arr = v.as_arr().context("[sweep] aggregators")?;
                if arr.is_empty() {
                    bail!("[sweep] aggregators must not be empty");
                }
                arr.iter()
                    .map(|s| AggregatorSpec::parse(s.as_str()?).map_err(|e| anyhow!("{e}")))
                    .collect::<Result<Vec<_>>>()
                    .context("[sweep] aggregators")?
            }
        };

        // -- [observability] ----------------------------------------------
        let trace_out = match doc.get("observability", "trace_out") {
            Some(v) => Some(v.as_str().context("[observability] trace_out")?.to_string()),
            None => None,
        };
        let metrics_out = match doc.get("observability", "metrics_out") {
            Some(v) => Some(v.as_str().context("[observability] metrics_out")?.to_string()),
            None => None,
        };
        let telemetry_out = match doc.get("observability", "telemetry_out") {
            Some(v) => {
                Some(v.as_str().context("[observability] telemetry_out")?.to_string())
            }
            None => None,
        };
        let ledger_out = match doc.get("observability", "ledger_out") {
            Some(v) => Some(v.as_str().context("[observability] ledger_out")?.to_string()),
            None => None,
        };

        // -- [output] -----------------------------------------------------
        let output = match doc.get("output", "path") {
            Some(v) => Some(v.as_str().context("[output] path")?.to_string()),
            None => None,
        };

        let manifest = ScenarioManifest {
            name,
            base,
            protocol_pinned: protocol_given,
            kernel,
            availability,
            transport,
            sim,
            sweep: SweepSpec { seeds, partitions, codecs, models, aggregators },
            output,
            trace_out,
            metrics_out,
            telemetry_out,
            ledger_out,
        };
        // expanding validates every cell — a bad manifest fails at parse
        // time, not mid-sweep
        let grid = manifest.grid()?;
        if matches!(manifest.transport, FleetTransport::Tcp { .. }) && grid.len() != 1 {
            bail!(
                "transport = \"tcp\" supports single-cell grids only (this one has {} cells); \
                 remote clients receive their config once at the handshake",
                grid.len()
            );
        }
        Ok(manifest)
    }

    /// Expand the sweep into validated grid cells:
    /// models (outer) × partitions × codecs × aggregators × seeds (inner).
    pub fn grid(&self) -> Result<Vec<GridCell>> {
        let mut cells = Vec::new();
        for model in &self.sweep.models {
            for part in &self.sweep.partitions {
                for &codec in &self.sweep.codecs {
                    for &aggregator in &self.sweep.aggregators {
                        for &seed in &self.sweep.seeds {
                            let mut cfg = self.base.clone();
                            cfg.seed = seed;
                            part.apply(&mut cfg);
                            cfg.codec = codec;
                            cfg.model = model.clone();
                            cfg.aggregator = aggregator;
                            if !self.protocol_pinned {
                                cfg.protocol = Protocol::for_codec(codec);
                            }
                            let cell = GridCell { cfg, partition: part.name() };
                            cell.cfg
                                .validate()
                                .with_context(|| format!("grid cell {}", cell.label()))?;
                            cells.push(cell);
                        }
                    }
                }
            }
        }
        Ok(cells)
    }
}

/// Reject unknown tables / keys — a typo must fail, not silently no-op.
fn check_surface(doc: &TomlDoc) -> Result<()> {
    for table in doc.table_names() {
        if table.is_empty() {
            bail!("top-level keys are not allowed; use [scenario] / [experiment] / ...");
        }
        let allowed: &[&str] = match table {
            "scenario" => SCENARIO_KEYS,
            "experiment" => EXPERIMENT_KEYS,
            "fleet" => FLEET_KEYS,
            "availability" => AVAILABILITY_KEYS,
            "adversary" => ADVERSARY_KEYS,
            "sim" => SIM_KEYS,
            "sweep" => SWEEP_KEYS,
            "observability" => OBSERVABILITY_KEYS,
            "output" => OUTPUT_KEYS,
            other => bail!("unknown table [{other}] (expected one of {TABLES:?})"),
        };
        for key in doc.table(table).map(|t| t.keys()).into_iter().flatten() {
            if !allowed.contains(&key.as_str()) {
                bail!("unknown key {key:?} in [{table}] (allowed: {allowed:?})");
            }
        }
    }
    Ok(())
}

fn parse_availability(doc: &TomlDoc) -> Result<AvailabilityModel> {
    let dropout = get_float(doc, "availability", "dropout")?.unwrap_or(0.0);
    let straggler_prob = get_float(doc, "availability", "straggler_prob")?.unwrap_or(0.0);
    let straggler_delay_ms =
        get_unsigned(doc, "availability", "straggler_delay_ms")?.unwrap_or(0);
    let rounds = match doc.get("availability", "phase_rounds") {
        None => Vec::new(),
        Some(v) => v
            .as_arr()
            .context("[availability] phase_rounds")?
            .iter()
            .map(|x| x.as_unsigned().map(|r| r as usize))
            .collect::<Result<Vec<_>>>()
            .context("[availability] phase_rounds")?,
    };
    let drops = match doc.get("availability", "phase_dropout") {
        None => Vec::new(),
        Some(v) => v
            .as_arr()
            .context("[availability] phase_dropout")?
            .iter()
            .map(|x| x.as_float())
            .collect::<Result<Vec<_>>>()
            .context("[availability] phase_dropout")?,
    };
    if rounds.len() != drops.len() {
        bail!(
            "[availability] phase_rounds ({}) and phase_dropout ({}) must have equal length",
            rounds.len(),
            drops.len()
        );
    }
    let phases: Vec<Phase> = rounds
        .into_iter()
        .zip(drops)
        .map(|(from_round, dropout)| Phase { from_round, dropout })
        .collect();
    AvailabilityModel::new(dropout, phases, straggler_prob, straggler_delay_ms)
        .map_err(|e| anyhow!("[availability]: {e}"))
}

/// Parse the `[adversary]` table into a validated [`AdversarySpec`]
/// (honest when the table is absent). `behavior` is required; `fraction`
/// defaults to 1.0 (the whole fleet misbehaves) and `seed` to 0.
fn parse_adversary(doc: &TomlDoc) -> Result<AdversarySpec> {
    if doc.table("adversary").is_none() {
        return Ok(AdversarySpec::honest());
    }
    let behavior = match doc.get("adversary", "behavior") {
        Some(v) => v.as_str().context("[adversary] behavior")?.to_string(),
        None => bail!(
            "[adversary] needs `behavior = \"...\"` (one of {:?})",
            behavior_names()
        ),
    };
    let fraction = get_float(doc, "adversary", "fraction")?.unwrap_or(1.0);
    let seed = get_unsigned(doc, "adversary", "seed")?.unwrap_or(0);
    AdversarySpec::parse(&behavior, fraction, seed).map_err(|e| anyhow!("[adversary]: {e}"))
}

/// Parse the `[sim]` table into a validated [`SimSpec`] (None when the
/// table is absent). Defaults: cohort = the experiment's
/// `selected_per_round`, fleet seed = the experiment seed, tiers = the
/// [`SimSpec::new`] heterogeneity model, uniform weights when only
/// values are given.
fn parse_sim(doc: &TomlDoc, base: &ExperimentConfig) -> Result<Option<SimSpec>> {
    if doc.table("sim").is_none() {
        return Ok(None);
    }
    let registered = get_unsigned(doc, "sim", "registered_clients")?
        .ok_or_else(|| anyhow!("[sim] needs `registered_clients = <n>`"))?
        as usize;
    let cohort = get_unsigned(doc, "sim", "cohort")?
        .map(|c| c as usize)
        .unwrap_or_else(|| base.selected_per_round());
    let seed = get_unsigned(doc, "sim", "seed")?.unwrap_or(base.seed);
    let mut spec = SimSpec::new(registered, cohort, seed);
    if let Some(values) = get_float_arr(doc, "sim", "device_us_per_sample")? {
        spec.device_us_per_sample = tier_set(
            values,
            get_float_arr(doc, "sim", "device_weights")?,
        )
        .context("[sim] device_us_per_sample")?;
    } else if doc.get("sim", "device_weights").is_some() {
        bail!("[sim] device_weights needs device_us_per_sample");
    }
    if let Some(values) = get_float_arr(doc, "sim", "bandwidth_mbps")? {
        spec.bandwidth_mbps = tier_set(
            values,
            get_float_arr(doc, "sim", "bandwidth_weights")?,
        )
        .context("[sim] bandwidth_mbps")?;
    } else if doc.get("sim", "bandwidth_weights").is_some() {
        bail!("[sim] bandwidth_weights needs bandwidth_mbps");
    }
    if let Some(lat) = get_float_arr(doc, "sim", "latency_ms")? {
        let [lo, hi] = lat.as_slice() else {
            bail!("[sim] latency_ms must be a [lo, hi] pair, got {} values", lat.len());
        };
        spec.latency_ms = (*lo, *hi);
    }
    if let Some(t) = get_float(doc, "sim", "target_acc")? {
        spec.target_acc = Some(t);
    }
    spec.validate_for(base.n_clients).map_err(|e| anyhow!("[sim]: {e}"))?;
    Ok(Some(spec))
}

fn tier_set(values: Vec<f64>, weights: Option<Vec<f64>>) -> Result<TierSet> {
    Ok(match weights {
        Some(w) => TierSet::new(values, w)?,
        None => TierSet::uniform(values)?,
    })
}

fn get_unsigned(doc: &TomlDoc, table: &str, key: &str) -> Result<Option<u64>> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_unsigned().with_context(|| format!("[{table}] {key}"))?)),
    }
}

fn get_float(doc: &TomlDoc, table: &str, key: &str) -> Result<Option<f64>> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_float().with_context(|| format!("[{table}] {key}"))?)),
    }
}

fn get_float_arr(doc: &TomlDoc, table: &str, key: &str) -> Result<Option<Vec<f64>>> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => {
            let arr = v
                .as_arr()
                .and_then(|a| a.iter().map(|x| x.as_float()).collect::<Result<Vec<f64>>>())
                .with_context(|| format!("[{table}] {key}"))?;
            Ok(Some(arr))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = "[scenario]\nname = \"t\"\n";

    fn parse(extra: &str) -> Result<ScenarioManifest> {
        ScenarioManifest::parse(&format!("{MINIMAL}{extra}"))
    }

    #[test]
    fn minimal_manifest_matches_cli_defaults() {
        let m = parse("").unwrap();
        let cli_default = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 42);
        assert_eq!(m.base, cli_default);
        assert_eq!(m.transport, FleetTransport::Loopback);
        assert_eq!(m.availability, AvailabilityModel::always_on());
        let grid = m.grid().unwrap();
        assert_eq!(grid.len(), 1);
        assert_eq!(grid[0].cfg, cli_default);
        assert_eq!(grid[0].partition, "iid");
    }

    #[test]
    fn codec_implies_protocol_like_the_cli() {
        let m = parse("[experiment]\ncodec = \"stc:k=0.05\"\n").unwrap();
        assert_eq!(m.base.protocol, Protocol::FedAvg);
        assert_eq!(m.base.codec, CodecSpec::Stc { k: 0.05 });
        // explicit protocol wins (and impossible pairings are rejected)
        let m = parse("[experiment]\nprotocol = \"fedavg\"\ncodec = \"fp16\"\n").unwrap();
        assert_eq!(m.base.protocol, Protocol::FedAvg);
        let err = parse("[experiment]\nprotocol = \"tfedavg\"\ncodec = \"fp16\"\n");
        assert!(err.is_err());
    }

    #[test]
    fn sweep_grid_is_cartesian_product() {
        let m = parse(
            "[sweep]\nseeds = [1, 2, 3]\npartitions = [\"iid\", \"nc:2\"]\n\
             codecs = [\"ternary\", \"stc:k=0.01\"]\n",
        )
        .unwrap();
        let grid = m.grid().unwrap();
        assert_eq!(grid.len(), 12);
        // codec drives the protocol when unpinned
        for cell in &grid {
            let want = Protocol::for_codec(cell.cfg.codec);
            assert_eq!(cell.cfg.protocol, want, "{}", cell.label());
        }
        // labels are unique
        let mut labels: Vec<String> = grid.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 12);
    }

    #[test]
    fn model_key_and_sweep_expand_the_grid() {
        // explicit model reaches every cell and its label
        let m = parse("[experiment]\nmodel = \"mlp-large\"\nnative = true\n").unwrap();
        let grid = m.grid().unwrap();
        assert_eq!(grid[0].cfg.model, "mlp-large");
        assert!(grid[0].label().ends_with("model=mlp-large"), "{}", grid[0].label());
        // models axis is the outermost grid dimension
        let m = parse(
            "[experiment]\nnative = true\n[sweep]\nseeds = [1, 2]\n\
             models = [\"mlp\", \"mlp-large\"]\n",
        )
        .unwrap();
        let grid = m.grid().unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].cfg.model, "mlp");
        assert_eq!(grid[3].cfg.model, "mlp-large");
        let mut labels: Vec<String> = grid.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 4);
        // default grids keep their pre-registry labels (no model suffix)
        let m = parse("").unwrap();
        assert!(!m.grid().unwrap()[0].label().contains("model="));
        // an unknown model fails at parse time (cells validate eagerly)
        assert!(parse("[experiment]\nmodel = \"vgg\"\nnative = true\n").is_err());
        // empty models axis rejected like the other axes
        assert!(parse("[sweep]\nmodels = []\n").is_err());
    }

    #[test]
    fn cnn_model_needs_the_cifar_task() {
        let err = parse("[experiment]\nmodel = \"cnn\"\nnative = true\n").unwrap_err();
        assert!(format!("{err:#}").contains("input dim"), "{err:#}");
        let m = parse("[experiment]\ntask = \"cifar\"\nmodel = \"cnn\"\nnative = true\n")
            .unwrap();
        assert_eq!(m.grid().unwrap()[0].cfg.model_name(), "cnn");
    }

    #[test]
    fn dirichlet_partition_reaches_config() {
        let m = parse("[fleet]\npartition = \"dirichlet:alpha=0.5\"\n").unwrap();
        let grid = m.grid().unwrap();
        assert_eq!(grid[0].cfg.dirichlet_alpha, 0.5);
        assert_eq!(grid[0].partition, "dirichlet:alpha=0.5");
    }

    #[test]
    fn availability_parses_phases() {
        let m = parse(
            "[availability]\ndropout = 0.1\nstraggler_prob = 0.2\n\
             straggler_delay_ms = 5\nphase_rounds = [10, 20]\nphase_dropout = [0.3, 0.6]\n",
        )
        .unwrap();
        assert_eq!(m.availability.dropout_for_round(1), 0.1);
        assert_eq!(m.availability.dropout_for_round(10), 0.3);
        assert_eq!(m.availability.dropout_for_round(25), 0.6);
        assert!(m.availability.has_stragglers());
    }

    #[test]
    fn adversary_table_and_aggregator_axis() {
        use crate::coordinator::adversary::Behavior;
        // [adversary] reaches every grid cell's config
        let m = parse(
            "[experiment]\nnative = true\n\
             [adversary]\nbehavior = \"sign_flip\"\nfraction = 0.3\nseed = 7\n",
        )
        .unwrap();
        let spec = m.grid().unwrap()[0].cfg.adversary;
        assert_eq!(spec.behavior, Behavior::SignFlip);
        assert_eq!(spec.fraction, 0.3);
        assert_eq!(spec.seed, 7);
        // defaults: fraction = 1.0 (whole fleet), seed = 0
        let m = parse("[adversary]\nbehavior = \"replay\"\n").unwrap();
        assert_eq!(m.base.adversary.fraction, 1.0);
        assert_eq!(m.base.adversary.seed, 0);
        // [experiment] aggregator pins the rule for the whole grid
        let m = parse("[experiment]\naggregator = \"median\"\n").unwrap();
        assert_eq!(m.grid().unwrap()[0].cfg.aggregator, AggregatorSpec::Median);
        // the aggregators sweep axis expands the grid and labels
        // non-default cells (default `mean` labels stay historical)
        let m = parse(
            "[sweep]\nseeds = [1, 2]\naggregators = [\"mean\", \"trimmed_mean:0.2\"]\n",
        )
        .unwrap();
        let grid = m.grid().unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].cfg.aggregator, AggregatorSpec::Mean);
        assert!(!grid[0].label().contains("aggregator="));
        assert_eq!(grid[2].cfg.aggregator, AggregatorSpec::TrimmedMean { beta: 0.2 });
        assert!(grid[2].label().contains("aggregator=trimmed_mean:0.2"));
        let mut labels: Vec<String> = grid.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 4);
    }

    #[test]
    fn adversary_reject_paths() {
        // behavior is required when the table is present
        assert!(parse("[adversary]\nfraction = 0.5\n").is_err());
        // unknown behavior / key, bad fraction (typed validation)
        assert!(parse("[adversary]\nbehavior = \"lie\"\n").is_err());
        assert!(parse("[adversary]\nbehaviour = \"replay\"\n").is_err());
        assert!(parse("[adversary]\nbehavior = \"replay\"\nfraction = 1.5\n").is_err());
        // bad aggregator key / param
        assert!(parse("[experiment]\naggregator = \"mode\"\n").is_err());
        assert!(parse("[experiment]\naggregator = \"trimmed_mean:0.9\"\n").is_err());
        assert!(parse("[sweep]\naggregators = []\n").is_err());
        assert!(parse("[sweep]\naggregators = [\"average\"]\n").is_err());
        // centralized protocols reject adversaries and robust rules
        // (ExperimentConfig::validate, exercised at parse time)
        assert!(parse(
            "[experiment]\nprotocol = \"baseline\"\n[adversary]\nbehavior = \"sign_flip\"\n"
        )
        .is_err());
        assert!(parse(
            "[experiment]\nprotocol = \"baseline\"\naggregator = \"median\"\n"
        )
        .is_err());
    }

    #[test]
    fn kernel_key_selects_a_native_tier() {
        let m = parse("[experiment]\nnative = true\nkernel = \"packed:2\"\n").unwrap();
        assert_eq!(m.kernel, Some(KernelPolicy::packed(2)));
        // the knob never reaches the wire config
        let plain = parse("[experiment]\nnative = true\n").unwrap();
        assert_eq!(m.base, plain.base);
        assert_eq!(plain.kernel, None);
        // needs the native backend, and typos fail like everywhere else
        assert!(parse("[experiment]\nkernel = \"packed\"\n").is_err());
        assert!(parse("[experiment]\nnative = true\nkernel = \"simd\"\n").is_err());
        assert!(parse("[experiment]\nnative = true\nkernel = \"packed:0\"\n").is_err());
    }

    #[test]
    fn tcp_transport_single_cell_only() {
        let m = parse("[fleet]\ntransport = \"tcp\"\n").unwrap();
        assert_eq!(m.transport, FleetTransport::Tcp { listen: "127.0.0.1:7878".into() });
        let err = parse("[fleet]\ntransport = \"tcp\"\n[sweep]\nseeds = [1, 2]\n");
        assert!(err.is_err());
    }

    #[test]
    fn reject_paths() {
        // not TOML at all
        assert!(ScenarioManifest::parse("{json?}").is_err());
        // missing scenario name
        assert!(ScenarioManifest::parse("[experiment]\nrounds = 1\n").is_err());
        // unknown table / key (typo safety)
        assert!(parse("[experimnet]\nrounds = 1\n").is_err());
        assert!(parse("[experiment]\nruonds = 1\n").is_err());
        assert!(ScenarioManifest::parse("top = 1\n[scenario]\nname = \"t\"\n").is_err());
        // wrong types
        assert!(parse("[experiment]\nrounds = \"thirty\"\n").is_err());
        assert!(parse("[experiment]\nrounds = -1\n").is_err());
        assert!(parse("[experiment]\nnative = 1\n").is_err());
        // bad probability (typed availability validation)
        assert!(parse("[availability]\ndropout = 1.5\n").is_err());
        // mismatched phase arrays
        assert!(parse("[availability]\nphase_rounds = [5]\n").is_err());
        // empty sweep axes
        assert!(parse("[sweep]\nseeds = []\n").is_err());
        assert!(parse("[sweep]\npartitions = []\n").is_err());
        // invalid partition / codec strings
        assert!(parse("[fleet]\npartition = \"zipf:2\"\n").is_err());
        assert!(parse("[sweep]\ncodecs = [\"lz4\"]\n").is_err());
        // invalid grid cell (validate() runs at parse time)
        assert!(parse("[experiment]\nparticipation = 2.0\n").is_err());
        // listen without tcp
        assert!(parse("[fleet]\nlisten = \"127.0.0.1:1\"\n").is_err());
    }

    #[test]
    fn sim_table_parses_with_defaults() {
        let m = parse("[sim]\nregistered_clients = 100_000\n").unwrap();
        let sim = m.sim.unwrap();
        assert_eq!(sim.registered, 100_000);
        // defaults follow the experiment: cohort = selected_per_round,
        // fleet seed = experiment seed
        assert_eq!(sim.cohort, m.base.selected_per_round());
        assert_eq!(sim.seed, m.base.seed);
        assert!(sim.target_acc.is_none());
        assert!(parse("").unwrap().sim.is_none());
    }

    #[test]
    fn sim_table_full_surface() {
        let m = parse(
            "[sim]\nregistered_clients = 1_000_000\ncohort = 64\nseed = 9\n\
             device_us_per_sample = [500.0, 50.0]\ndevice_weights = [0.9, 0.1]\n\
             bandwidth_mbps = [1.0, 100.0]\n\
             latency_ms = [5.0, 50.0]\ntarget_acc = 0.5\n",
        )
        .unwrap();
        let sim = m.sim.unwrap();
        assert_eq!(sim.registered, 1_000_000);
        assert_eq!(sim.cohort, 64);
        assert_eq!(sim.seed, 9);
        assert_eq!(sim.device_us_per_sample.values(), &[500.0, 50.0]);
        // bandwidth got uniform weights (values without weights)
        assert_eq!(sim.bandwidth_mbps.values(), &[1.0, 100.0]);
        assert_eq!(sim.latency_ms, (5.0, 50.0));
        assert_eq!(sim.target_acc, Some(0.5));
    }

    #[test]
    fn sim_reject_paths() {
        // missing population
        assert!(parse("[sim]\ncohort = 4\n").is_err());
        // unknown key (typo safety, like every other table)
        assert!(parse("[sim]\nregistered_clients = 100\nchoort = 4\n").is_err());
        // population smaller than the shard count (10 clients default)
        assert!(parse("[sim]\nregistered_clients = 5\n").is_err());
        // geometry / scalar validation flows through SimSpec
        assert!(parse("[sim]\nregistered_clients = 100\ncohort = 0\n").is_err());
        assert!(parse("[sim]\nregistered_clients = 100\ncohort = 101\n").is_err());
        assert!(parse("[sim]\nregistered_clients = 100\ntarget_acc = 1.5\n").is_err());
        assert!(
            parse("[sim]\nregistered_clients = 100\nlatency_ms = [9.0, 1.0]\n").is_err()
        );
        assert!(parse("[sim]\nregistered_clients = 100\nlatency_ms = [1.0]\n").is_err());
        // weights without values, mismatched lengths, bad tier values
        assert!(parse("[sim]\nregistered_clients = 100\ndevice_weights = [1.0]\n").is_err());
        assert!(parse(
            "[sim]\nregistered_clients = 100\n\
             bandwidth_mbps = [1.0, 2.0]\nbandwidth_weights = [1.0]\n"
        )
        .is_err());
        assert!(parse(
            "[sim]\nregistered_clients = 100\ndevice_us_per_sample = [0.0]\n"
        )
        .is_err());
        // sim × tcp and sim × centralized protocols are contradictions
        assert!(parse("[fleet]\ntransport = \"tcp\"\n[sim]\nregistered_clients = 100\n")
            .is_err());
        assert!(parse(
            "[experiment]\nprotocol = \"baseline\"\n[sim]\nregistered_clients = 100\n"
        )
        .is_err());
    }

    #[test]
    fn output_path_flows_through() {
        let m = parse("[output]\npath = \"bundle.json\"\n").unwrap();
        assert_eq!(m.output.as_deref(), Some("bundle.json"));
        assert_eq!(parse("").unwrap().output, None);
    }

    #[test]
    fn observability_table_flows_through() {
        let m = parse(
            "[observability]\ntrace_out = \"trace.json\"\nmetrics_out = \"m.prom\"\ntelemetry_out = \"t.jsonl\"\nledger_out = \"runs.tfed\"\n",
        )
        .unwrap();
        assert_eq!(m.trace_out.as_deref(), Some("trace.json"));
        assert_eq!(m.metrics_out.as_deref(), Some("m.prom"));
        assert_eq!(m.telemetry_out.as_deref(), Some("t.jsonl"));
        assert_eq!(m.ledger_out.as_deref(), Some("runs.tfed"));
        // all keys optional, independently
        let m = parse("[observability]\ntrace_out = \"t.json\"\n").unwrap();
        assert_eq!(m.trace_out.as_deref(), Some("t.json"));
        assert_eq!(m.metrics_out, None);
        assert_eq!(m.telemetry_out, None);
        assert_eq!(m.ledger_out, None);
        let m = parse("").unwrap();
        assert_eq!(
            (m.trace_out, m.metrics_out, m.telemetry_out, m.ledger_out),
            (None, None, None, None)
        );
        // typo safety like every other table
        assert!(parse("[observability]\ntrace = \"t.json\"\n").is_err());
        assert!(parse("[observability]\ntrace_out = 1\n").is_err());
        assert!(parse("[observability]\nledger_out = 1\n").is_err());
    }
}
