//! Scenario engine: declarative experiment manifests.
//!
//! The paper's headline claim — T-FedAvg holds up under non-IID and
//! unbalanced fleets — is a claim about a *grid* of scenarios, not one
//! CLI invocation. This subsystem makes that grid declarative: a TOML
//! manifest names a fleet (partition regime including Dirichlet(α) label
//! skew, per-round availability/dropout schedules, straggler traces,
//! codec, transport) and the sweep axes (models × seeds × partitions ×
//! codecs — the `[experiment] model` key / `[sweep] models` axis pick
//! native-registry architectures), and `tfed run <manifest.toml>`
//! executes the whole thing, emitting one JSON results bundle with
//! per-cell metrics and cross-cell aggregates.
//!
//! * `toml` — hand-rolled single-file TOML subset parser (`util::json`
//!   style; the build is offline, so no `toml`/`serde` crates)
//! * `manifest` — [`ScenarioManifest`]: schema, validation (unknown
//!   keys rejected), CLI-equivalent defaults, grid expansion, and the
//!   `[sim]` table that switches a grid onto the virtual-time fleet
//!   simulator ([`crate::sim`])
//! * `runner` — [`run_scenario`] / [`run_scenario_jobs`]: drive every
//!   grid cell through the `Orchestrator` (sequentially or `--jobs N`
//!   cells in flight, same bundle either way) and bundle
//!   [`ScenarioResults`]
//!
//! A single-cell manifest produces metrics byte-identical to the
//! equivalent flag-driven `tfed run` invocation (asserted in
//! `tests/scenario_e2e.rs`); fleets of 1k+ clients stay O(model) on the
//! server thanks to the streaming `coordinator::Aggregator`.

pub mod manifest;
pub mod runner;
pub mod toml;

use anyhow::Result;

pub use manifest::{FleetTransport, GridCell, ScenarioManifest, SweepSpec};
pub use runner::{run_scenario, run_scenario_jobs, CellResult, CellSim, ScenarioResults};
pub use toml::{TomlDoc, TomlValue};

/// CLI-side observability settings for a manifest run. The path
/// overrides (`--trace-out` / `--metrics-out` / `--telemetry-out`) win
/// over the manifest's `[observability]` table, mirroring how `--out`
/// wins over `[output] path`.
#[derive(Clone, Debug, Default)]
pub struct ObsOverrides {
    pub trace_out: Option<String>,
    pub metrics_out: Option<String>,
    pub telemetry_out: Option<String>,
    /// append every cell to this run ledger (`--ledger-out`)
    pub ledger_out: Option<String>,
    /// suppress the end-of-run phase summary table
    pub quiet: bool,
}

/// Load, run, and persist one manifest end-to-end — the
/// `tfed run <manifest.toml>` entry point. `out_override` replaces the
/// manifest's `[output] path`; `jobs` caps the number of grid cells in
/// flight (1 = sequential; order and deterministic bundle bytes are
/// identical at any value). Returns the results and the bundle path
/// written (if any).
///
/// When any obs sink resolves (CLI override or `[observability]`
/// table), tracing — plus per-round learning telemetry when
/// `telemetry_out` resolves — is enabled for the whole grid and the
/// artifacts are written after the results bundle; the bundle bytes
/// themselves are unaffected (`tests/obs_e2e.rs`,
/// `tests/telemetry_e2e.rs`). When a ledger path resolves
/// (`--ledger-out` / `[observability] ledger_out`), every cell is also
/// appended to that run ledger after the bundle is written
/// (`tests/store_e2e.rs`). Sink write failures never fail the run.
pub fn run_manifest_file(
    path: &str,
    out_override: Option<&str>,
    jobs: usize,
    obs: &ObsOverrides,
) -> Result<(ScenarioResults, Option<String>)> {
    let manifest = ScenarioManifest::load(path)?;
    let trace = obs.trace_out.clone().or_else(|| manifest.trace_out.clone());
    let metrics = obs.metrics_out.clone().or_else(|| manifest.metrics_out.clone());
    let telemetry = obs.telemetry_out.clone().or_else(|| manifest.telemetry_out.clone());
    if telemetry.is_some() {
        crate::obs::enable_telemetry();
    } else if trace.is_some() || metrics.is_some() {
        crate::obs::enable();
    }
    let ledger = obs.ledger_out.clone().or_else(|| manifest.ledger_out.clone());
    let results = run_scenario_jobs(&manifest, jobs)?;
    let out = out_override.map(str::to_string).or_else(|| manifest.output.clone());
    if let Some(p) = &out {
        results.write_json(p)?;
    }
    // ledger appends are best-effort like every other obs sink: the
    // bundle is already on disk, so a failed append must not fail the run
    if let Some(p) = &ledger {
        match crate::obs::store::append_cells(p, &results.cells) {
            Ok(n) => crate::info!("appended {n} run(s) to ledger {p}"),
            Err(e) => crate::warn!("obs ledger sink {p:?}: {e} (results unaffected)"),
        }
    }
    crate::obs::finish(&crate::obs::Sinks {
        trace_out: trace.as_deref(),
        metrics_out: metrics.as_deref(),
        telemetry_out: telemetry.as_deref(),
        quiet: obs.quiet,
    });
    Ok((results, out))
}
