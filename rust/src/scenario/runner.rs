//! Scenario execution: expand the manifest grid, drive every cell through
//! the `Orchestrator`, and emit one JSON results bundle.
//!
//! Each cell is an independent, fully-seeded experiment — a cell run from
//! a manifest is byte-identical to the same configuration run through CLI
//! flags (`tests/scenario_e2e.rs` asserts this). Cells execute
//! sequentially; inside a cell the round driver's worker pool already
//! parallelizes the fleet.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::backend::make_backend;
use crate::coordinator::server::Orchestrator;
use crate::metrics::RunMetrics;
use crate::runtime::manifest::default_artifacts_dir;
use crate::runtime::Engine;
use crate::scenario::manifest::{FleetTransport, GridCell, ScenarioManifest};
use crate::transport::TcpBinding;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats;
use crate::info;

/// One executed grid cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub label: String,
    pub seed: u64,
    pub partition: String,
    pub codec: String,
    pub protocol: String,
    pub metrics: RunMetrics,
}

/// The whole scenario's results — one bundle per `tfed run <manifest>`.
#[derive(Clone, Debug)]
pub struct ScenarioResults {
    pub name: String,
    pub cells: Vec<CellResult>,
}

impl ScenarioResults {
    /// Final accuracies across the grid (aggregate stats input).
    pub fn final_accs(&self) -> Vec<f64> {
        self.cells.iter().map(|c| c.metrics.final_acc() as f64).collect()
    }

    /// The results bundle: scenario identity, per-cell summary + full
    /// per-round metrics, and cross-cell aggregates.
    pub fn to_json(&self) -> Json {
        let accs = self.final_accs();
        obj(vec![
            ("scenario", s(&self.name)),
            ("grid_size", num(self.cells.len() as f64)),
            (
                "aggregate",
                obj(vec![
                    ("mean_final_acc", num(stats::mean(&accs))),
                    ("std_final_acc", num(stats::std_dev(&accs))),
                    ("min_final_acc", num(stats::min(&accs))),
                    ("max_final_acc", num(stats::max(&accs))),
                ]),
            ),
            (
                "cells",
                arr(self
                    .cells
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("label", s(&c.label)),
                            ("seed", num(c.seed as f64)),
                            ("partition", s(&c.partition)),
                            ("codec", s(&c.codec)),
                            ("protocol", s(&c.protocol)),
                            ("metrics", c.metrics.to_json()),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    pub fn write_json(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing results bundle {path:?}"))
    }
}

/// Run every grid cell of a parsed manifest.
pub fn run_scenario(manifest: &ScenarioManifest) -> Result<ScenarioResults> {
    let cells = manifest.grid()?;
    info!("scenario {:?}: {} grid cells", manifest.name, cells.len());
    let mut engine: Option<Arc<Engine>> = None;
    let mut results = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        info!("cell {}/{}: {}", i + 1, cells.len(), cell.label());
        let metrics = run_cell(manifest, cell, &mut engine)
            .with_context(|| format!("grid cell {}", cell.label()))?;
        results.push(CellResult {
            label: cell.label(),
            seed: cell.cfg.seed,
            partition: cell.partition.clone(),
            codec: cell.cfg.codec.name(),
            protocol: cell.cfg.protocol.name().to_string(),
            metrics,
        });
    }
    Ok(ScenarioResults { name: manifest.name.clone(), cells: results })
}

/// Run one cell; `engine` caches the PJRT runtime across non-native cells.
fn run_cell(
    manifest: &ScenarioManifest,
    cell: &GridCell,
    engine: &mut Option<Arc<Engine>>,
) -> Result<RunMetrics> {
    let cfg = cell.cfg.clone();
    let engine_ref = if cfg.native_backend {
        None
    } else {
        if engine.is_none() {
            *engine = Some(Arc::new(Engine::load(default_artifacts_dir())?));
        }
        engine.clone()
    };
    let backend =
        make_backend(engine_ref, cfg.task.model_name(), cfg.batch, cfg.native_backend)?;
    let mut orch = match &manifest.transport {
        FleetTransport::Loopback => Orchestrator::with_availability(
            cfg,
            backend.as_ref(),
            manifest.availability.clone(),
        )?,
        FleetTransport::Tcp { listen } => {
            if cfg.protocol.is_centralized() {
                bail!("tcp transport requires a federated protocol");
            }
            let binding = TcpBinding::bind(listen)?;
            let addr = binding.local_addr()?;
            info!("listening on {addr} — waiting for {} clients", cfg.n_clients);
            let transport = binding.accept_clients(cfg.n_clients, &cfg)?;
            Orchestrator::with_transport(
                cfg,
                backend.as_ref(),
                manifest.availability.clone(),
                Box::new(transport),
            )?
        }
    };
    let run_result = orch.run();
    if matches!(manifest.transport, FleetTransport::Tcp { .. }) {
        // teardown failure must never mask the run's own error
        if let Err(e) = orch.shutdown_transport() {
            crate::warn!("shutdown notify failed: {e:#}");
        }
    }
    run_result?;
    Ok(orch.metrics.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> ScenarioManifest {
        ScenarioManifest::parse(
            r#"
[scenario]
name = "tiny"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 240
test_samples = 60
seed = 5
native = true
[sweep]
seeds = [5, 6]
"#,
        )
        .unwrap()
    }

    #[test]
    fn runs_grid_and_bundles_json() {
        let m = tiny_manifest();
        let r = run_scenario(&m).unwrap();
        assert_eq!(r.name, "tiny");
        assert_eq!(r.cells.len(), 2);
        for c in &r.cells {
            assert_eq!(c.metrics.records.len(), 2);
            assert!(c.metrics.final_acc().is_finite());
        }
        // the bundle is valid JSON and round-trips through the parser
        let text = r.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("scenario").unwrap().as_str().unwrap(), "tiny");
        assert_eq!(parsed.get("grid_size").unwrap().as_usize().unwrap(), 2);
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        let rounds = cells[0]
            .get("metrics")
            .unwrap()
            .get("rounds")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(rounds.len(), 2);
        assert!(parsed.get("aggregate").unwrap().get("mean_final_acc").is_some());
    }

    #[test]
    fn seeds_change_results_deterministically() {
        let m = tiny_manifest();
        let a = run_scenario(&m).unwrap();
        let b = run_scenario(&m).unwrap();
        // same manifest twice: identical accuracy trajectories
        for (x, y) in a.cells.iter().zip(&b.cells) {
            for (rx, ry) in x.metrics.records.iter().zip(&y.metrics.records) {
                assert_eq!(rx.test_acc.to_bits(), ry.test_acc.to_bits());
                assert_eq!(rx.up_bytes, ry.up_bytes);
            }
        }
        // different seeds within a run: different data splits, different
        // training trajectories
        let (c5, c6) = (&a.cells[0], &a.cells[1]);
        assert_ne!(c5.seed, c6.seed);
        assert_ne!(
            c5.metrics.records[0].train_loss.to_bits(),
            c6.metrics.records[0].train_loss.to_bits()
        );
    }
}
