//! Scenario execution: expand the manifest grid, drive every cell through
//! the `Orchestrator`, and emit one JSON results bundle.
//!
//! Each cell is an independent, fully-seeded experiment — a cell run from
//! a manifest is byte-identical to the same configuration run through CLI
//! flags (`tests/scenario_e2e.rs` asserts this). Cells execute
//! sequentially by default; `run_scenario_jobs` (the CLI's `--jobs N`)
//! fans independent cells over a worker pool while keeping the bundle's
//! cell order — and, for deterministic fields, its bytes — identical to
//! the sequential run. Inside a cell the round driver's worker pool
//! already parallelizes the fleet.
//!
//! Cells under a `[sim]` manifest run on the virtual clock
//! (`Orchestrator::with_sim`): their `wall_secs` are zeroed in the stored
//! metrics (wall time is not a property of a simulated system, and
//! zeroing it makes sim bundles byte-reproducible run-over-run at any
//! `--jobs`/worker count) and the bundle carries a per-cell `sim` block
//! with total virtual time, rounds per virtual hour, and — when the
//! manifest names a `target_acc` — simulated time-to-accuracy.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::backend::make_backend_with_policy;
use crate::coordinator::server::Orchestrator;
use crate::info;
use crate::eval::RunMetrics;
use crate::runtime::manifest::default_artifacts_dir;
use crate::runtime::Engine;
use crate::scenario::manifest::{FleetTransport, GridCell, ScenarioManifest};
use crate::transport::TcpBinding;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::parallel::parallel_map_indexed;
use crate::util::stats;

/// Per-cell virtual-time summary (sim cells only).
#[derive(Clone, Debug)]
pub struct CellSim {
    pub total_sim_secs: f64,
    pub rounds_per_virtual_hour: f64,
    /// simulated seconds to the manifest's `target_acc` (None: no target
    /// configured, or never reached)
    pub sim_secs_to_target: Option<f64>,
    pub target_acc: Option<f64>,
}

/// One executed grid cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub label: String,
    pub seed: u64,
    pub partition: String,
    pub codec: String,
    pub protocol: String,
    /// resolved model name (explicit override or the task default)
    pub model: String,
    /// aggregation-rule registry key; emitted to the bundle only when it
    /// differs from the default `mean` (honest bundles keep their bytes)
    pub aggregator: String,
    /// adversary label (`behavior@fraction`); None for honest fleets —
    /// and absent from the bundle, same byte-stability contract
    pub adversary: Option<String>,
    pub metrics: RunMetrics,
    /// virtual-time summary; None for real-time cells
    pub sim: Option<CellSim>,
}

/// The whole scenario's results — one bundle per `tfed run <manifest>`.
#[derive(Clone, Debug)]
pub struct ScenarioResults {
    pub name: String,
    pub cells: Vec<CellResult>,
}

impl ScenarioResults {
    /// Final accuracies across the grid (aggregate stats input).
    pub fn final_accs(&self) -> Vec<f64> {
        self.cells.iter().map(|c| c.metrics.final_acc() as f64).collect()
    }

    /// The results bundle: scenario identity, per-cell summary + full
    /// per-round metrics, and cross-cell aggregates.
    pub fn to_json(&self) -> Json {
        let accs = self.final_accs();
        obj(vec![
            ("scenario", s(&self.name)),
            ("grid_size", num(self.cells.len() as f64)),
            (
                "aggregate",
                obj(vec![
                    ("mean_final_acc", num(stats::mean(&accs))),
                    ("std_final_acc", num(stats::std_dev(&accs))),
                    ("min_final_acc", num(stats::min(&accs))),
                    ("max_final_acc", num(stats::max(&accs))),
                ]),
            ),
            (
                "cells",
                arr(self
                    .cells
                    .iter()
                    .map(|c| {
                        let mut fields = vec![
                            ("label", s(&c.label)),
                            ("seed", num(c.seed as f64)),
                            ("partition", s(&c.partition)),
                            ("codec", s(&c.codec)),
                            ("protocol", s(&c.protocol)),
                            ("model", s(&c.model)),
                        ];
                        if c.aggregator != "mean" {
                            fields.push(("aggregator", s(&c.aggregator)));
                        }
                        if let Some(adv) = &c.adversary {
                            fields.push(("adversary", s(adv)));
                        }
                        if let Some(sim) = &c.sim {
                            fields.push((
                                "sim",
                                obj(vec![
                                    ("total_sim_secs", num(sim.total_sim_secs)),
                                    (
                                        "rounds_per_virtual_hour",
                                        num(sim.rounds_per_virtual_hour),
                                    ),
                                    (
                                        "sim_secs_to_target",
                                        sim.sim_secs_to_target.map_or(Json::Null, num),
                                    ),
                                    (
                                        "target_acc",
                                        sim.target_acc.map_or(Json::Null, num),
                                    ),
                                ]),
                            ));
                        }
                        fields.push(("metrics", c.metrics.to_json()));
                        obj(fields)
                    })
                    .collect()),
            ),
        ])
    }

    pub fn write_json(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing results bundle {path:?}"))
    }
}

/// The PJRT engine, shared across cells and loaded at most once (native
/// cells never touch it; `--jobs` workers share the same instance).
type EngineCache = Mutex<Option<Arc<Engine>>>;

/// Run every grid cell of a parsed manifest, sequentially.
pub fn run_scenario(manifest: &ScenarioManifest) -> Result<ScenarioResults> {
    run_scenario_jobs(manifest, 1)
}

/// Run the grid with up to `jobs` cells in flight. Cells are independent
/// and fully seeded, so results — bundle order included — are identical
/// to the sequential run at any `jobs` value; only wall time changes.
pub fn run_scenario_jobs(manifest: &ScenarioManifest, jobs: usize) -> Result<ScenarioResults> {
    let cells = manifest.grid()?;
    let jobs = jobs.max(1).min(cells.len().max(1));
    info!("scenario {:?}: {} grid cells, {jobs} job(s)", manifest.name, cells.len());
    if matches!(manifest.transport, FleetTransport::Tcp { .. }) && jobs > 1 {
        // unreachable through the manifest (tcp grids are single-cell,
        // so jobs clamps to 1), but keep the API honest
        bail!("tcp fleets are interactive and run one cell at a time");
    }
    let engine: EngineCache = Mutex::new(None);
    // fail fast on unresolvable PJRT models: a bad `model` name in a
    // multi-cell grid must abort before any cell burns compute, not after
    // the earlier cells already ran (native cells are registry-validated
    // at parse time and never touch the engine)
    let mut pjrt_models: Vec<&str> = cells
        .iter()
        .filter(|c| !c.cfg.native_backend)
        .map(|c| c.cfg.model_name())
        .collect();
    pjrt_models.sort_unstable();
    pjrt_models.dedup();
    if !pjrt_models.is_empty() {
        let mut cache = engine.lock().unwrap();
        if cache.is_none() {
            *cache = Some(Arc::new(Engine::load(default_artifacts_dir())?));
        }
        let eng = cache.as_ref().unwrap().clone();
        drop(cache);
        for m in pjrt_models {
            eng.manifest
                .model(m)
                .with_context(|| format!("grid model {m:?} has no artifacts"))?;
        }
    }
    let results: Vec<CellResult> = parallel_map_indexed(cells.len(), jobs, |i| {
        info!("cell {}/{}: {}", i + 1, cells.len(), cells[i].label());
        run_cell(manifest, &cells[i], i as u32, &engine)
    })
    .into_iter()
    .collect::<Result<Vec<_>>>()?;
    Ok(ScenarioResults { name: manifest.name.clone(), cells: results })
}

/// Run one cell end-to-end and summarize it. `lane` is the cell's grid
/// index: it keys the cell's spans in the obs trace, so `--jobs N` runs
/// produce the same trace structure as sequential ones.
fn run_cell(
    manifest: &ScenarioManifest,
    cell: &GridCell,
    lane: u32,
    engine: &EngineCache,
) -> Result<CellResult> {
    let metrics = run_cell_metrics(manifest, cell, lane, engine)
        .with_context(|| format!("grid cell {}", cell.label()))?;
    let sim = manifest.sim.as_ref().map(|spec| CellSim {
        total_sim_secs: metrics.total_sim_secs(),
        rounds_per_virtual_hour: metrics.rounds_per_virtual_hour().unwrap_or(0.0),
        sim_secs_to_target: spec
            .target_acc
            .and_then(|t| metrics.sim_secs_to_acc(t as f32)),
        target_acc: spec.target_acc,
    });
    Ok(CellResult {
        label: cell.label(),
        seed: cell.cfg.seed,
        partition: cell.partition.clone(),
        codec: cell.cfg.codec.name(),
        protocol: cell.cfg.protocol.name().to_string(),
        model: cell.cfg.model_name().to_string(),
        aggregator: cell.cfg.aggregator.name(),
        adversary: cell
            .cfg
            .adversary
            .is_active()
            .then(|| cell.cfg.adversary.label()),
        metrics,
        sim,
    })
}

/// Drive one cell through the orchestrator on the manifest's transport.
fn run_cell_metrics(
    manifest: &ScenarioManifest,
    cell: &GridCell,
    lane: u32,
    engine: &EngineCache,
) -> Result<RunMetrics> {
    let cfg = cell.cfg.clone();
    let engine_ref = if cfg.native_backend {
        None
    } else {
        let mut cache = engine.lock().unwrap();
        if cache.is_none() {
            *cache = Some(Arc::new(Engine::load(default_artifacts_dir())?));
        }
        cache.clone()
    };
    let backend = make_backend_with_policy(
        engine_ref,
        cfg.model_name(),
        cfg.batch,
        cfg.native_backend,
        manifest.kernel,
    )?;
    let mut orch = match (&manifest.sim, &manifest.transport) {
        (Some(sim), _) => Orchestrator::with_sim(
            cfg,
            backend.as_ref(),
            manifest.availability.clone(),
            sim.clone(),
        )?,
        (None, FleetTransport::Loopback) => Orchestrator::with_availability(
            cfg,
            backend.as_ref(),
            manifest.availability.clone(),
        )?,
        (None, FleetTransport::Tcp { listen }) => {
            if cfg.protocol.is_centralized() {
                bail!("tcp transport requires a federated protocol");
            }
            let binding = TcpBinding::bind(listen)?;
            let addr = binding.local_addr()?;
            info!("listening on {addr} — waiting for {} clients", cfg.n_clients);
            let transport = binding.accept_clients(cfg.n_clients, &cfg)?;
            Orchestrator::with_transport(
                cfg,
                backend.as_ref(),
                manifest.availability.clone(),
                Box::new(transport),
            )?
        }
    };
    orch.set_obs_lane(lane);
    orch.set_obs_cell(&cell.label());
    let run_result = orch.run();
    if matches!(manifest.transport, FleetTransport::Tcp { .. }) {
        // teardown failure must never mask the run's own error
        if let Err(e) = orch.shutdown_transport() {
            crate::warn!("shutdown notify failed: {e:#}");
        }
    }
    run_result?;
    let mut metrics = orch.metrics.clone();
    if manifest.sim.is_some() {
        // simulated cells report virtual time only: zeroing the wall
        // clock makes bundles byte-identical run-over-run
        for r in &mut metrics.records {
            r.wall_secs = 0.0;
        }
    }
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> ScenarioManifest {
        ScenarioManifest::parse(
            r#"
[scenario]
name = "tiny"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 240
test_samples = 60
seed = 5
native = true
[sweep]
seeds = [5, 6]
"#,
        )
        .unwrap()
    }

    #[test]
    fn runs_grid_and_bundles_json() {
        let m = tiny_manifest();
        let r = run_scenario(&m).unwrap();
        assert_eq!(r.name, "tiny");
        assert_eq!(r.cells.len(), 2);
        for c in &r.cells {
            assert_eq!(c.metrics.records.len(), 2);
            assert!(c.metrics.final_acc().is_finite());
            assert!(c.sim.is_none());
        }
        // the bundle is valid JSON and round-trips through the parser
        let text = r.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("scenario").unwrap().as_str().unwrap(), "tiny");
        assert_eq!(parsed.get("grid_size").unwrap().as_usize().unwrap(), 2);
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        // resolved model is recorded per cell (task default here)
        assert_eq!(cells[0].get("model").unwrap().as_str().unwrap(), "mlp");
        let rounds = cells[0]
            .get("metrics")
            .unwrap()
            .get("rounds")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(rounds.len(), 2);
        assert!(parsed.get("aggregate").unwrap().get("mean_final_acc").is_some());
        // real-time cells carry no sim block
        assert!(cells[0].get("sim").is_none());
        // honest default cells carry neither robustness field: bundles
        // from pre-adversary builds keep their exact keys
        assert!(cells[0].get("aggregator").is_none());
        assert!(cells[0].get("adversary").is_none());
    }

    #[test]
    fn adversarial_cells_label_the_bundle() {
        let m = ScenarioManifest::parse(
            r#"
[scenario]
name = "byz"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 240
test_samples = 60
seed = 5
native = true
aggregator = "median"
[adversary]
behavior = "sign_flip"
fraction = 0.4
seed = 9
"#,
        )
        .unwrap();
        let r = run_scenario(&m).unwrap();
        assert_eq!(r.cells.len(), 1);
        let text = r.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let cell = &parsed.get("cells").unwrap().as_arr().unwrap()[0];
        assert_eq!(cell.get("aggregator").unwrap().as_str().unwrap(), "median");
        assert_eq!(cell.get("adversary").unwrap().as_str().unwrap(), "sign_flip@0.4");
        // sign-flip is a statistical attack: updates stay well-formed, so
        // nothing is rejected — the round simply aggregates robustly
        assert!(r.cells[0].metrics.final_acc().is_finite());
    }

    #[test]
    fn seeds_change_results_deterministically() {
        let m = tiny_manifest();
        let a = run_scenario(&m).unwrap();
        let b = run_scenario(&m).unwrap();
        // same manifest twice: identical accuracy trajectories
        for (x, y) in a.cells.iter().zip(&b.cells) {
            for (rx, ry) in x.metrics.records.iter().zip(&y.metrics.records) {
                assert_eq!(rx.test_acc.to_bits(), ry.test_acc.to_bits());
                assert_eq!(rx.up_bytes, ry.up_bytes);
            }
        }
        // different seeds within a run: different data splits, different
        // training trajectories
        let (c5, c6) = (&a.cells[0], &a.cells[1]);
        assert_ne!(c5.seed, c6.seed);
        assert_ne!(
            c5.metrics.records[0].train_loss.to_bits(),
            c6.metrics.records[0].train_loss.to_bits()
        );
    }

    #[test]
    fn parallel_jobs_match_sequential_in_order_and_bytes() {
        let m = tiny_manifest();
        let seq = run_scenario(&m).unwrap();
        let par = run_scenario_jobs(&m, 2).unwrap();
        assert_eq!(
            seq.cells.iter().map(|c| c.label.clone()).collect::<Vec<_>>(),
            par.cells.iter().map(|c| c.label.clone()).collect::<Vec<_>>()
        );
        // byte-identical bundles once the (only nondeterministic) wall
        // clock is zeroed on both sides
        let zero_wall = |mut r: ScenarioResults| {
            for c in &mut r.cells {
                for rec in &mut c.metrics.records {
                    rec.wall_secs = 0.0;
                }
            }
            r.to_json().to_string_pretty()
        };
        assert_eq!(zero_wall(seq), zero_wall(par));
        // oversubscribed pools are clamped, not a hang or an error
        let over = run_scenario_jobs(&m, 64).unwrap();
        assert_eq!(over.cells.len(), 2);
    }
}
