//! Minimal blocking HTTP endpoint for live observability (std::net only).
//!
//! Toggled by `--metrics-addr HOST:PORT` on `tfed run` / `tfed serve`;
//! a first concrete step toward the ROADMAP's daemon control plane.
//! Serves, while the run is in flight:
//!
//! * `GET /metrics` — the obs registry's Prometheus text
//!   [`exposition`](crate::obs::metrics::exposition)
//! * `GET /telemetry` — a JSON tail of the most recent
//!   learning-dynamics records ([`crate::obs::telemetry`])
//! * `GET /` — a one-line index
//!
//! The server is a single accept thread handling one connection at a
//! time — scrape traffic, not a web service. Port 0 binds an ephemeral
//! port; the resolved address is printed (and flushed) by the CLI as
//! `metrics endpoint on http://ADDR` so launcher scripts and CI can
//! parse it. Observability never steers the run: the endpoint only
//! reads registry/telemetry state and cannot mutate anything.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

/// How many telemetry records the live `/telemetry` tail returns.
const TAIL_RECORDS: usize = 256;

/// Accept-loop poll interval (shutdown latency bound).
const POLL: Duration = Duration::from_millis(25);

/// A running observability endpoint. Dropping it (or calling
/// [`ObsServer::shutdown`]) stops the accept loop and joins the thread.
pub struct ObsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ObsServer {
    /// The bound address (resolved — port 0 becomes the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the server thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Bind `addr` and start serving in a background thread.
pub fn serve(addr: &str) -> Result<ObsServer> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
    let addr = listener.local_addr().context("resolving metrics endpoint address")?;
    listener.set_nonblocking(true).context("metrics endpoint set_nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_thread = stop.clone();
    let handle = std::thread::Builder::new()
        .name("tfed-obs-http".into())
        .spawn(move || accept_loop(listener, &stop_thread))
        .context("spawning metrics endpoint thread")?;
    Ok(ObsServer { addr, stop, handle: Some(handle) })
}

fn accept_loop(listener: TcpListener, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // one scrape at a time; a broken client never kills the run
                let _ = handle_conn(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(_) => std::thread::sleep(POLL),
        }
    }
}

fn handle_conn(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let path = read_request_path(&mut stream)?;
    let (status, content_type, body) = respond(&path);
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Read the request head (bounded) and return the request-target path.
fn read_request_path(stream: &mut TcpStream) -> std::io::Result<String> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    let line = buf.split(|&b| b == b'\r' || b == b'\n').next().unwrap_or(&[]);
    let line = String::from_utf8_lossy(line);
    // "GET /path HTTP/1.1" → "/path"; anything malformed maps to 404
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    if method != "GET" {
        return Ok(String::new());
    }
    Ok(target.split('?').next().unwrap_or("").to_string())
}

/// Route a request path to `(status line, content type, body)`. Pure —
/// unit-tested without sockets.
pub(crate) fn respond(path: &str) -> (&'static str, &'static str, String) {
    match path {
        "/metrics" => {
            ("200 OK", "text/plain; version=0.0.4", crate::obs::metrics::exposition())
        }
        "/telemetry" => {
            let recs = crate::obs::telemetry::tail(TAIL_RECORDS);
            let body = crate::util::json::obj(vec![
                (
                    "v",
                    crate::util::json::num(crate::obs::telemetry::SCHEMA_VERSION as f64),
                ),
                (
                    "records",
                    crate::util::json::arr(recs.iter().map(|r| r.to_json()).collect()),
                ),
            ]);
            ("200 OK", "application/json", body.to_string())
        }
        "/" => (
            "200 OK",
            "text/plain",
            "tfed observability endpoint: GET /metrics (Prometheus text), \
             GET /telemetry (JSON tail)\n"
                .to_string(),
        ),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_shaped_right() {
        let (status, ct, _) = respond("/metrics");
        assert_eq!(status, "200 OK");
        assert!(ct.starts_with("text/plain"));
        let (status, ct, body) = respond("/telemetry");
        assert_eq!(status, "200 OK");
        assert_eq!(ct, "application/json");
        let doc = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(doc.get("v").unwrap().as_usize().unwrap() as u64, 1);
        assert!(doc.get("records").unwrap().as_arr().is_ok());
        let (status, _, _) = respond("/nope");
        assert_eq!(status, "404 Not Found");
    }
}
