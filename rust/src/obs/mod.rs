//! Observability: metrics registry + phase tracing + learning telemetry.
//!
//! The measurement substrate for the whole stack (DESIGN.md §11–12): a
//! lock-cheap [`metrics`] registry (counters / gauges / log2
//! histograms, Prometheus text exposition via `--metrics-out`),
//! span-based [`trace`] phase tracing (Chrome trace-event JSON via
//! `--trace-out`, Perfetto-loadable, plus an end-of-run per-phase
//! summary table on stderr), per-round learning-dynamics [`telemetry`]
//! (schema-versioned JSONL via `--telemetry-out`), a live [`http`]
//! endpoint (`--metrics-addr`, `/metrics` + `/telemetry`), the
//! offline [`report`] renderer behind `tfed report`, and the durable
//! cross-run ledger (append-only [`store`], query/diff [`lens`],
//! DESIGN.md §14) behind `--ledger-out` and `tfed history` / `query`
//! / `diff`.
//!
//! Standing contract: **disabled (the default) must be free.** No RNG
//! draws, no wire-byte changes, and near-zero overhead — every
//! instrumentation site is behind the [`trace::enabled`] /
//! [`enabled`] / [`telemetry::enabled`] fast path (one relaxed atomic
//! load) or a no-op guard. Enabled runs produce byte-identical results,
//! summaries, and bundles too (observability reads, never steers); only
//! the separate obs artifacts are added. Regression-tested in
//! `tests/obs_e2e.rs` + `tests/telemetry_e2e.rs`, overhead-asserted in
//! the `--train` bench.
//!
//! Sink I/O failures at shutdown are **non-fatal**: a run that trained
//! for an hour must not exit nonzero because a trace path was
//! unwritable. Failures surface as [`ObsSinkError`] warnings through
//! [`crate::util::logging`] (with a one-time hint) and `finish` returns
//! them for callers that want to inspect.

pub mod http;
pub mod lens;
pub mod metrics;
pub mod report;
pub mod store;
pub mod telemetry;
pub mod trace;

use std::io::Write as _;

/// Open a phase span for the current scope (no-op unless obs is
/// enabled or `TFED_LOG=trace`):
///
/// ```no_run
/// fn aggregate() {
///     tfed::obs_span!("round.aggregate");
///     // ... phase body; the span closes when the scope ends
/// }
/// ```
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        let _obs_span_guard = $crate::obs::trace::span($name);
    };
}

/// Turn on span + metrics collection for this process.
pub fn enable() {
    trace::set_enabled(true);
}

/// Turn on learning-dynamics telemetry (and the span/metrics substrate
/// it annotates). Named `--telemetry-out` / `--metrics-addr` paths do
/// this; nothing else does.
pub fn enable_telemetry() {
    enable();
    telemetry::set_enabled(true);
}

/// Is observability collection enabled?
#[inline]
pub fn enabled() -> bool {
    trace::enabled()
}

/// A sink that could not be written at shutdown (non-fatal; see
/// [`finish`]).
#[derive(Debug)]
pub struct ObsSinkError {
    /// which artifact ("trace" | "metrics" | "telemetry")
    pub sink: &'static str,
    pub path: String,
    pub source: std::io::Error,
}

impl std::fmt::Display for ObsSinkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "obs {} sink {:?}: {}", self.sink, self.path, self.source)
    }
}

impl std::error::Error for ObsSinkError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.source)
    }
}

/// End-of-run artifact sinks for [`finish`] (None = not requested).
#[derive(Clone, Copy, Debug, Default)]
pub struct Sinks<'a> {
    pub trace_out: Option<&'a str>,
    pub metrics_out: Option<&'a str>,
    pub telemetry_out: Option<&'a str>,
    /// suppress the per-phase summary table
    pub quiet: bool,
}

/// End-of-run export: drain spans, print the per-phase summary table
/// (stderr, suppressed by `quiet`), and write the requested artifacts.
/// No-op when collection was never enabled.
///
/// Sink I/O failures are collected, not propagated: each failure is
/// logged as a warning (plus a one-time hint that obs sinks are
/// non-fatal) and returned. The run's own exit status never depends on
/// an observability artifact.
pub fn finish(sinks: &Sinks<'_>) -> Vec<ObsSinkError> {
    let mut errs = Vec::new();
    if trace::enabled() {
        let events = trace::take_events();
        if !sinks.quiet {
            print_summary(&events);
        }
        if let Some(path) = sinks.trace_out {
            match std::fs::write(path, trace::chrome_trace_json(&events)) {
                Ok(()) => {
                    crate::info!("wrote Chrome trace ({} spans) to {path}", events.len())
                }
                Err(source) => {
                    errs.push(ObsSinkError { sink: "trace", path: path.into(), source })
                }
            }
        }
        if let Some(path) = sinks.metrics_out {
            match std::fs::write(path, metrics::exposition()) {
                Ok(()) => crate::info!("wrote metrics exposition to {path}"),
                Err(source) => {
                    errs.push(ObsSinkError { sink: "metrics", path: path.into(), source })
                }
            }
        }
    }
    if telemetry::enabled() {
        if let Some(path) = sinks.telemetry_out {
            let recs = telemetry::take();
            match std::fs::write(path, telemetry::to_jsonl(&recs)) {
                Ok(()) => {
                    crate::info!("wrote {} telemetry records to {path}", recs.len())
                }
                Err(source) => {
                    errs.push(ObsSinkError { sink: "telemetry", path: path.into(), source })
                }
            }
        }
    }
    for e in &errs {
        warn_sink_error(e);
    }
    errs
}

/// Surface a sink failure: always a warning, plus a one-time hint that
/// obs artifacts are best-effort (mirrors the `TFED_LOG` parse warning).
fn warn_sink_error(e: &ObsSinkError) {
    static HINT: std::sync::Once = std::sync::Once::new();
    HINT.call_once(|| {
        crate::warn!("obs sinks are best-effort: the run's results are unaffected, but the artifact below is missing");
    });
    crate::warn!("{e}");
}

/// Per-phase summary table on stderr (count / total ms / mean µs).
fn print_summary(events: &[trace::SpanEvent]) {
    let rows = trace::phase_summary(events);
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "\n=== obs: per-phase summary ({} spans) ===", events.len());
    let _ = writeln!(err, "{:<24} {:>8} {:>12} {:>12}", "phase", "count", "total(ms)", "mean(us)");
    for (name, count, total_us) in rows {
        let _ = writeln!(
            err,
            "{:<24} {:>8} {:>12.3} {:>12.1}",
            name,
            count,
            total_us as f64 / 1e3,
            total_us as f64 / count.max(1) as f64
        );
    }
}
