//! Observability: metrics registry + phase tracing + round profiler.
//!
//! The measurement substrate for the whole stack (DESIGN.md §11): a
//! lock-cheap [`metrics`] registry (counters / gauges / log2
//! histograms, Prometheus text exposition via `--metrics-out`) and
//! span-based [`trace`] phase tracing (Chrome trace-event JSON via
//! `--trace-out`, Perfetto-loadable, plus an end-of-run per-phase
//! summary table on stderr).
//!
//! Standing contract: **disabled (the default) must be free.** No RNG
//! draws, no wire-byte changes, and near-zero overhead — every
//! instrumentation site is behind the [`trace::enabled`] /
//! [`enabled`] fast path (one relaxed atomic load) or a no-op guard.
//! Enabled runs produce byte-identical results, summaries, and
//! bundles too (observability reads, never steers); only the separate
//! obs artifacts are added. Regression-tested in `tests/obs_e2e.rs`,
//! overhead-asserted in the `--train` bench.

pub mod metrics;
pub mod trace;

use std::io::Write as _;

use anyhow::{Context, Result};

/// Open a phase span for the current scope (no-op unless obs is
/// enabled or `TFED_LOG=trace`):
///
/// ```no_run
/// fn aggregate() {
///     tfed::obs_span!("round.aggregate");
///     // ... phase body; the span closes when the scope ends
/// }
/// ```
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        let _obs_span_guard = $crate::obs::trace::span($name);
    };
}

/// Turn on span + metrics collection for this process.
pub fn enable() {
    trace::set_enabled(true);
}

/// Is observability collection enabled?
#[inline]
pub fn enabled() -> bool {
    trace::enabled()
}

/// End-of-run export: drain spans, print the per-phase summary table
/// (stderr, suppressed by `quiet`), and write the requested artifacts.
/// No-op when collection was never enabled.
pub fn finish(trace_out: Option<&str>, metrics_out: Option<&str>, quiet: bool) -> Result<()> {
    if !trace::enabled() {
        return Ok(());
    }
    let events = trace::take_events();
    if !quiet {
        print_summary(&events);
    }
    if let Some(path) = trace_out {
        std::fs::write(path, trace::chrome_trace_json(&events))
            .with_context(|| format!("writing trace to {path}"))?;
        crate::info!("wrote Chrome trace ({} spans) to {path}", events.len());
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, metrics::exposition())
            .with_context(|| format!("writing metrics to {path}"))?;
        crate::info!("wrote metrics exposition to {path}");
    }
    Ok(())
}

/// Per-phase summary table on stderr (count / total ms / mean µs).
fn print_summary(events: &[trace::SpanEvent]) {
    let rows = trace::phase_summary(events);
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "\n=== obs: per-phase summary ({} spans) ===", events.len());
    let _ = writeln!(err, "{:<24} {:>8} {:>12} {:>12}", "phase", "count", "total(ms)", "mean(us)");
    for (name, count, total_us) in rows {
        let _ = writeln!(
            err,
            "{:<24} {:>8} {:>12.3} {:>12.1}",
            name,
            count,
            total_us as f64 / 1e3,
            total_us as f64 / count.max(1) as f64
        );
    }
}
