//! Offline paper-metrics reporter: `tfed report <bundle|telemetry ...>`.
//!
//! Renders paper-style outputs from run artifacts alone — no re-run, no
//! model loading beyond the registry's schemas:
//!
//! * from a scenario **results bundle** (the JSON `tfed run <manifest>`
//!   writes): a Table-IV-style communication-cost / compression-ratio
//!   table (measured wire bytes vs the dense fp32 equivalent of the same
//!   frame count) and per-cell accuracy-vs-MB-transferred series;
//! * from a **telemetry JSONL** sink (`--telemetry-out`, DESIGN.md §12):
//!   quantization-factor-convergence series plus sparsity / divergence
//!   trajectories.
//!
//! Everything is emitted as markdown with embedded CSV blocks, so the
//! output is simultaneously human-readable and machine-parsable. The
//! dense equivalent is `frames × param_count(model) × 4` bytes: what the
//! same exchange pattern would have cost shipping raw fp32 tensors; the
//! measured side includes real frame headers, so ratios are honest.

use anyhow::{bail, Context, Result};

use crate::eval::mb;
use crate::util::json::Json;

/// Bytes per parameter for the dense fp32 reference payload.
const DENSE_BYTES_PER_PARAM: u64 = 4;

/// Render one artifact file (auto-detected) as a markdown report.
/// Binary files opening with the ledger magic are rendered through the
/// ledger query layer ([`crate::obs::lens`]); everything else is text
/// (bundle JSON or telemetry JSONL).
pub fn render_file(path: &str) -> Result<String> {
    let bytes = std::fs::read(path).with_context(|| format!("reading artifact {path:?}"))?;
    if bytes.len() >= 4
        && bytes[..4] == crate::obs::store::LEDGER_MAGIC.to_le_bytes()
    {
        return render_ledger(path);
    }
    let text = String::from_utf8(bytes)
        .map_err(|_| anyhow::anyhow!("artifact {path:?} is neither a ledger nor UTF-8 text"))?;
    render_text(path, &text)
}

/// Ledger report: the history table followed by every entry in full,
/// reusing the `tfed history`/`query` renderers verbatim.
fn render_ledger(path: &str) -> Result<String> {
    let view = crate::obs::lens::load(path)?;
    let mut out = format!("# Run ledger {path:?} ({} entries)\n\n", view.entries.len());
    out.push_str(&crate::obs::lens::render_history(
        &view,
        &crate::obs::lens::HistoryFilter::default(),
    ));
    for entry in &view.entries {
        out.push('\n');
        out.push_str(&crate::obs::lens::render_entry(entry));
    }
    Ok(out)
}

/// Render artifact content: scenario bundles are JSON objects with a
/// `cells` array; telemetry sinks are JSONL with `v`/`round` records.
pub fn render_text(name: &str, text: &str) -> Result<String> {
    let trimmed = text.trim_start();
    if trimmed.is_empty() {
        bail!("artifact {name:?} is empty");
    }
    if let Ok(doc) = Json::parse(text) {
        if doc.get("cells").is_some() {
            return report_bundle(name, &doc);
        }
    }
    // not a single JSON document with cells → try JSONL telemetry
    report_telemetry(name, text)
}

// -- scenario bundles -------------------------------------------------------

/// Table-IV-style communication table + accuracy-vs-MB series.
pub fn report_bundle(name: &str, doc: &Json) -> Result<String> {
    let cells = doc
        .get("cells")
        .and_then(|c| c.as_arr().ok())
        .with_context(|| format!("bundle {name:?} has no cells array"))?;
    if cells.is_empty() {
        bail!("bundle {name:?} has zero cells");
    }
    let scenario =
        doc.get("scenario").and_then(|s| s.as_str().ok()).unwrap_or("(unnamed)").to_string();
    let mut out = String::new();
    out.push_str(&format!("# tfed report — scenario `{scenario}` ({name})\n\n"));

    // Table IV analogue: measured wire cost vs dense fp32 equivalent.
    out.push_str("## Communication cost and compression ratio (Table IV analogue)\n\n");
    out.push_str(
        "| cell | model | params | up MB | down MB | dense MB | ratio | final acc |\n",
    );
    out.push_str("|---|---|---:|---:|---:|---:|---:|---:|\n");
    let mut csv = String::from(
        "cell,model,params,up_bytes,down_bytes,dense_bytes,compression_ratio,final_acc\n",
    );
    for cell in cells {
        let row = CellRow::parse(cell)?;
        let (dense_mb_s, ratio_s, dense_b, ratio_v) = match row.dense_bytes() {
            Some(d) => {
                let ratio = d as f64 / (row.up_bytes + row.down_bytes).max(1) as f64;
                (format!("{:.3}", mb(d)), format!("{ratio:.2}x"), d.to_string(), format!("{ratio:.4}"))
            }
            // model not in the native registry (e.g. PJRT-only): no
            // schema to price the dense payload from
            None => ("-".into(), "-".into(), String::new(), String::new()),
        };
        out.push_str(&format!(
            "| {} | {} | {} | {:.3} | {:.3} | {} | {} | {:.4} |\n",
            row.label,
            row.model,
            row.params.map_or("-".into(), |p| p.to_string()),
            mb(row.up_bytes),
            mb(row.down_bytes),
            dense_mb_s,
            ratio_s,
            row.final_acc,
        ));
        csv.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            csv_field(&row.label),
            row.model,
            row.params.map_or(String::new(), |p| p.to_string()),
            row.up_bytes,
            row.down_bytes,
            dense_b,
            ratio_v,
            row.final_acc,
        ));
    }
    out.push_str("\n```csv\n");
    out.push_str(&csv);
    out.push_str("```\n\n");

    // Fig. 6/10 analogue on the communication axis.
    out.push_str("## Accuracy vs MB transferred\n\n```csv\n");
    out.push_str("cell,round,cum_up_mb,cum_down_mb,test_acc\n");
    for cell in cells {
        let row = CellRow::parse(cell)?;
        let rounds = cell
            .get("metrics")
            .and_then(|m| m.get("rounds"))
            .and_then(|r| r.as_arr().ok())
            .with_context(|| format!("cell {:?} has no metrics.rounds", row.label))?;
        let (mut up, mut down) = (0u64, 0u64);
        for r in rounds {
            up += r.get("up_bytes").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
            down += r.get("down_bytes").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
            let evaluated =
                r.get("evaluated").and_then(|v| v.as_bool().ok()).unwrap_or(false);
            let acc = r.get("test_acc").and_then(|v| v.as_f64().ok());
            if let (true, Some(acc)) = (evaluated, acc) {
                let round = r.get("round").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
                out.push_str(&format!(
                    "{},{},{:.6},{:.6},{}\n",
                    csv_field(&row.label),
                    round,
                    mb(up),
                    mb(down),
                    acc
                ));
            }
        }
    }
    out.push_str("```\n");

    // Robustness section: only rendered when the grid had a Byzantine
    // axis or a non-default aggregator, so honest-default reports stay
    // byte-identical to pre-robustness builds.
    let robust: Vec<(String, String, String, u64, u64)> = cells
        .iter()
        .filter_map(|cell| {
            let label = cell
                .get("label")
                .and_then(|v| v.as_str().ok())
                .unwrap_or("(cell)")
                .to_string();
            let aggregator = cell
                .get("aggregator")
                .and_then(|v| v.as_str().ok())
                .unwrap_or("mean")
                .to_string();
            let adversary = cell
                .get("adversary")
                .and_then(|v| v.as_str().ok())
                .map(str::to_string);
            let (mut rejected, mut clipped) = (0u64, 0u64);
            if let Some(rounds) =
                cell.get("metrics").and_then(|m| m.get("rounds")).and_then(|r| r.as_arr().ok())
            {
                for r in rounds {
                    let count = |k: &str| {
                        r.get(k).and_then(|v| v.as_arr().ok()).map_or(0, |a| a.len() as u64)
                    };
                    rejected += count("rejected");
                    clipped += count("clipped");
                }
            }
            (adversary.is_some() || aggregator != "mean" || rejected + clipped > 0)
                .then(|| (label, aggregator, adversary.unwrap_or_else(|| "honest".into()), rejected, clipped))
        })
        .collect();
    if !robust.is_empty() {
        out.push_str("\n## Robust aggregation under Byzantine clients\n\n");
        out.push_str("| cell | aggregator | adversary | rejected | clipped |\n");
        out.push_str("|---|---|---|---:|---:|\n");
        for (label, aggregator, adversary, rejected, clipped) in &robust {
            out.push_str(&format!(
                "| {label} | {aggregator} | {adversary} | {rejected} | {clipped} |\n"
            ));
        }
    }
    Ok(out)
}

/// The per-cell fields the communication table needs.
struct CellRow {
    label: String,
    model: String,
    params: Option<usize>,
    up_bytes: u64,
    down_bytes: u64,
    /// total data frames both directions (one model payload each)
    frames: u64,
    final_acc: f64,
}

impl CellRow {
    fn parse(cell: &Json) -> Result<CellRow> {
        let label =
            cell.get("label").and_then(|v| v.as_str().ok()).unwrap_or("(cell)").to_string();
        let model =
            cell.get("model").and_then(|v| v.as_str().ok()).unwrap_or("?").to_string();
        let params = crate::model::registry::model_def(&model)
            .ok()
            .map(|d| d.schema.param_count());
        let metrics = cell
            .get("metrics")
            .with_context(|| format!("cell {label:?} has no metrics block"))?;
        let getn = |k: &str| metrics.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        let frames = metrics
            .get("rounds")
            .and_then(|r| r.as_arr().ok())
            .map(|rs| {
                rs.iter().fold(0u64, |acc, r| {
                    acc + r.get("up_frames").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64
                        + r.get("down_frames").and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
                            as u64
                })
            })
            .unwrap_or(0);
        Ok(CellRow {
            label,
            model,
            params,
            up_bytes: getn("total_up_bytes") as u64,
            down_bytes: getn("total_down_bytes") as u64,
            frames,
            final_acc: getn("final_acc"),
        })
    }

    /// Dense fp32 equivalent of the cell's exchange pattern, if the
    /// model schema is known.
    fn dense_bytes(&self) -> Option<u64> {
        self.params.map(|p| self.frames * p as u64 * DENSE_BYTES_PER_PARAM)
    }
}

// -- telemetry sinks --------------------------------------------------------

/// Factor-convergence + sparsity/divergence series from a JSONL sink.
pub fn report_telemetry(name: &str, text: &str) -> Result<String> {
    let mut recs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = Json::parse(line)
            .with_context(|| format!("{name}:{}: bad telemetry JSON", lineno + 1))?;
        let v = doc.get("v").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
        if v != crate::obs::telemetry::SCHEMA_VERSION {
            bail!(
                "{name}:{}: telemetry schema v{v}, this build reads v{}",
                lineno + 1,
                crate::obs::telemetry::SCHEMA_VERSION
            );
        }
        recs.push(doc);
    }
    if recs.is_empty() {
        bail!("telemetry sink {name:?} holds no records");
    }
    let mut out = String::new();
    out.push_str(&format!(
        "# tfed report — telemetry ({name}, {} records, schema v{})\n\n",
        recs.len(),
        crate::obs::telemetry::SCHEMA_VERSION
    ));
    out.push_str("## Quantization-factor convergence (Fig. 12/13 analogue)\n\n```csv\n");
    out.push_str("cell,lane,round,layer,factor\n");
    for r in &recs {
        let (cell, lane, round) = rec_key(r);
        if let Some(fs) = r.get("factors").and_then(|f| f.as_arr().ok()) {
            for (k, f) in fs.iter().enumerate() {
                if let Ok(v) = f.as_f64() {
                    out.push_str(&format!(
                        "{},{lane},{round},{k},{v}\n",
                        csv_field(&cell)
                    ));
                }
            }
        }
    }
    out.push_str("```\n\n## Sparsity and weight divergence\n\n```csv\n");
    out.push_str(
        "cell,lane,round,sparsity,unbias_residual,weight_divergence,rel_divergence,cum_up_bytes,cum_down_bytes\n",
    );
    for r in &recs {
        let (cell, lane, round) = rec_key(r);
        let g = |k: &str| {
            r.get(k).and_then(|v| v.as_f64().ok()).map_or(String::new(), |v| v.to_string())
        };
        out.push_str(&format!(
            "{},{lane},{round},{},{},{},{},{},{}\n",
            csv_field(&cell),
            g("sparsity"),
            g("unbias_residual"),
            g("weight_divergence"),
            g("rel_divergence"),
            g("cum_up_bytes"),
            g("cum_down_bytes"),
        ));
    }
    out.push_str("```\n");

    // Rejection/clip trajectory: only when some round actually rejected
    // or clipped an update, so honest-run reports are unchanged.
    let gu = |r: &Json, k: &str| r.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
    if recs.iter().any(|r| gu(r, "rejected") + gu(r, "clipped") > 0) {
        out.push_str("\n## Rejected and clipped updates per round\n\n```csv\n");
        out.push_str("cell,lane,round,rejected,clipped\n");
        for r in &recs {
            let (cell, lane, round) = rec_key(r);
            out.push_str(&format!(
                "{},{lane},{round},{},{}\n",
                csv_field(&cell),
                gu(r, "rejected"),
                gu(r, "clipped"),
            ));
        }
        out.push_str("```\n");
    }
    Ok(out)
}

fn rec_key(r: &Json) -> (String, u64, u64) {
    (
        r.get("cell").and_then(|v| v.as_str().ok()).unwrap_or("").to_string(),
        r.get("lane").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64,
        r.get("round").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64,
    )
}

/// Quote a CSV field if it holds a comma or quote.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}
