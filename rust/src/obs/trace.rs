//! Span-based phase tracing (offline stand-in for tracing + perfetto).
//!
//! A span covers one phase of one round — `round.select`,
//! `client.train`, `sim.end_round`, ... (full taxonomy in DESIGN.md
//! §11). Spans nest on a thread-local stack; every thread carries a
//! `(lane, round, client)` context set by the round driver so events
//! can be grouped after the fact no matter which worker thread ran the
//! exchange. Collection is gated by one relaxed [`enabled`] load — the
//! disabled path takes no locks, draws no RNG, and allocates nothing.
//!
//! Determinism contract: *structure* is deterministic — span names,
//! nesting depth, and the `(lane, round, client, seq)` export order are
//! identical run over run, because within one `(lane, round, client)`
//! group all spans are emitted by a single thread in program order.
//! Durations and timestamps are wall-clock and vary; regression tests
//! compare structure only (the same split `wall_secs` zeroing already
//! uses in scenario bundles).
//!
//! Export formats: Chrome trace-event JSON (`--trace-out`, loadable in
//! Perfetto; lane → pid, client → tid) and a per-phase summary table on
//! stderr at end of run.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s};
use crate::util::logging::{self, Level};

/// Context value for "no client": server-side phases.
pub const NO_CLIENT: u32 = u32::MAX;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SEQ: AtomicU64 = AtomicU64::new(0);
static EVENTS: Mutex<Vec<SpanEvent>> = Mutex::new(Vec::new());
/// Time zero for trace timestamps, pinned when tracing is enabled.
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// (lane, round, client) the current thread is working for.
    static CTX: Cell<(u32, u32, u32)> = const { Cell::new((0, 0, NO_CLIENT)) };
    /// Current span nesting depth on this thread.
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Grid-cell lane (0 outside scenario runs); keeps parallel `--jobs`
    /// cells from interleaving in the export order.
    pub lane: u32,
    pub round: u32,
    /// Client id, or [`NO_CLIENT`] for server-side phases.
    pub client: u32,
    /// Global start-order ticket; ties the per-thread program order down.
    pub seq: u64,
    /// Nesting depth at open (0 = top level).
    pub depth: u32,
    pub ts_us: u64,
    pub dur_us: u64,
}

/// Fast path: is span collection on? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span collection on/off. Pins the trace epoch on first enable.
pub fn set_enabled(on: bool) {
    if on {
        EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Set the calling thread's (lane, round, client) span context.
pub fn set_context(lane: u32, round: u32, client: u32) {
    CTX.with(|c| c.set((lane, round, client)));
}

/// Drop all collected events (tests / between bench sections).
pub fn clear() {
    EVENTS.lock().unwrap().clear();
    SEQ.store(0, Ordering::Relaxed);
}

/// Open a span. Returns `None` (a no-op) unless collection is enabled
/// or `TFED_LOG=trace` asked for span logging — the obs level gate.
#[must_use]
pub fn span(name: &'static str) -> Option<Span> {
    let record = enabled();
    if !record && !logging::enabled(Level::Trace) {
        return None;
    }
    let (lane, round, client) = CTX.with(|c| c.get());
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    Some(Span {
        name,
        lane,
        round,
        client,
        seq: SEQ.fetch_add(1, Ordering::Relaxed),
        depth,
        start: Instant::now(),
        record,
    })
}

/// Live span guard; records (and/or logs) on drop.
pub struct Span {
    name: &'static str,
    lane: u32,
    round: u32,
    client: u32,
    seq: u64,
    depth: u32,
    start: Instant,
    record: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let dur_us = self.start.elapsed().as_micros() as u64;
        if logging::enabled(Level::Trace) {
            let client = if self.client == NO_CLIENT {
                "-".to_string()
            } else {
                self.client.to_string()
            };
            logging::log(
                Level::Trace,
                "tfed::obs",
                format_args!(
                    "span {} lane={} round={} client={} {}us",
                    self.name, self.lane, self.round, client, dur_us
                ),
            );
        }
        if self.record {
            let epoch = EPOCH.get_or_init(Instant::now);
            let ts_us = self.start.saturating_duration_since(*epoch).as_micros() as u64;
            EVENTS.lock().unwrap().push(SpanEvent {
                name: self.name,
                lane: self.lane,
                round: self.round,
                client: self.client,
                seq: self.seq,
                depth: self.depth,
                ts_us,
                dur_us,
            });
        }
    }
}

/// Drain collected events in the deterministic `(lane, round, client,
/// seq)` export order.
pub fn take_events() -> Vec<SpanEvent> {
    let mut v = std::mem::take(&mut *EVENTS.lock().unwrap());
    v.sort_by_key(|e| (e.lane, e.round, e.client, e.seq));
    v
}

/// Chrome trace-event JSON ("X" complete events; Perfetto-loadable).
/// Lane maps to pid, client to tid (server lane = tid 0).
pub fn chrome_trace_json(events: &[SpanEvent]) -> String {
    let evs = events
        .iter()
        .map(|e| {
            obj(vec![
                ("name", s(e.name)),
                ("ph", s("X")),
                ("cat", s("tfed")),
                ("ts", num(e.ts_us as f64)),
                ("dur", num(e.dur_us as f64)),
                ("pid", num(e.lane as f64 + 1.0)),
                (
                    "tid",
                    num(if e.client == NO_CLIENT { 0.0 } else { e.client as f64 + 1.0 }),
                ),
                (
                    "args",
                    obj(vec![
                        ("round", num(e.round as f64)),
                        ("depth", num(e.depth as f64)),
                        ("seq", num(e.seq as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    obj(vec![("displayTimeUnit", s("ms")), ("traceEvents", arr(evs))]).to_string_pretty()
}

/// Per-phase rollup: (name, count, total_us), sorted by name.
pub fn phase_summary(events: &[SpanEvent]) -> Vec<(&'static str, u64, u64)> {
    let mut by_name: std::collections::BTreeMap<&'static str, (u64, u64)> = Default::default();
    for e in events {
        let entry = by_name.entry(e.name).or_insert((0, 0));
        entry.0 += 1;
        entry.1 += e.dur_us;
    }
    by_name.into_iter().map(|(n, (c, t))| (n, c, t)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Span collection is process-global; serialize the tests that flip it.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_span_is_none() {
        let _g = LOCK.lock().unwrap();
        set_enabled(false);
        // default log level is below trace, so the gate stays closed
        assert!(span("test.noop").is_none());
    }

    #[test]
    fn spans_record_names_nesting_and_order() {
        let _g = LOCK.lock().unwrap();
        set_enabled(true);
        clear();
        set_context(0, 3, NO_CLIENT);
        {
            let _outer = span("test.outer");
            {
                let _inner = span("test.inner");
            }
        }
        set_context(0, 3, 1);
        {
            let _c = span("test.client");
        }
        set_enabled(false);
        // other tests may run instrumented code concurrently; keep ours only
        let events: Vec<SpanEvent> =
            take_events().into_iter().filter(|e| e.name.starts_with("test.")).collect();
        set_context(0, 0, NO_CLIENT);
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        // server lane (client = NO_CLIENT = u32::MAX) sorts after client 1;
        // within a group, seq order = program order (inner closes first)
        assert_eq!(names, vec!["test.client", "test.inner", "test.outer"]);
        assert_eq!(events[1].depth, 1);
        assert_eq!(events[2].depth, 0);
        assert!(events.iter().all(|e| e.round == 3));
    }

    #[test]
    fn chrome_json_parses_and_maps_lanes() {
        let _g = LOCK.lock().unwrap();
        set_enabled(true);
        clear();
        set_context(2, 0, 4);
        {
            let _s = span("test.lane");
        }
        set_enabled(false);
        let events: Vec<SpanEvent> =
            take_events().into_iter().filter(|e| e.name.starts_with("test.")).collect();
        set_context(0, 0, NO_CLIENT);
        let text = chrome_trace_json(&events);
        let doc = crate::util::json::Json::parse(&text).expect("valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("name").unwrap().as_str().unwrap(), "test.lane");
        assert_eq!(evs[0].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(evs[0].get("pid").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(evs[0].get("tid").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn summary_rolls_up_by_name() {
        let events = vec![
            SpanEvent {
                name: "b",
                lane: 0,
                round: 0,
                client: 0,
                seq: 0,
                depth: 0,
                ts_us: 0,
                dur_us: 5,
            },
            SpanEvent {
                name: "a",
                lane: 0,
                round: 0,
                client: 0,
                seq: 1,
                depth: 0,
                ts_us: 5,
                dur_us: 7,
            },
            SpanEvent {
                name: "b",
                lane: 0,
                round: 1,
                client: 0,
                seq: 2,
                depth: 0,
                ts_us: 12,
                dur_us: 3,
            },
        ];
        assert_eq!(phase_summary(&events), vec![("a", 1, 7), ("b", 2, 8)]);
    }
}
