//! Append-only run ledger: the durable cross-run store behind
//! `tfed history` / `query` / `diff` (DESIGN.md §14).
//!
//! A ledger file (`runs.tfed` by convention) is a flat sequence of
//! CRC-framed, schema-versioned records. The framing discipline is
//! [`crate::transport::frame`]'s, reused rather than reinvented — same
//! length-prefix + CRC-32 layout, same typed-error posture, same
//! size bound — under a distinct magic so a ledger can never be
//! mistaken for wire traffic (or vice versa):
//!
//! | offset | size | field                               |
//! |--------|------|-------------------------------------|
//! | 0      | 4    | magic `0x4C524654` ("TFRL")         |
//! | 4      | 1    | record version (currently 1)        |
//! | 5      | 1    | kind ([`RecordKind`])               |
//! | 6      | 4    | payload length (<= [`MAX_RECORD`])  |
//! | 10     | 4    | CRC-32 (IEEE) of the payload        |
//! | 14     | len  | payload (canonical compact JSON)    |
//!
//! Payloads are `util::json` documents emitted compactly — objects are
//! BTreeMaps, so a given value has exactly one byte encoding.
//!
//! **Determinism contract.** Every record except [`RecordKind::Timestamp`]
//! is byte-reproducible: rerunning the same fully-seeded experiment and
//! appending it to a fresh ledger produces identical header/round/summary
//! payloads (run ids are config-derived, never clocked). All wall-clock
//! fields — per-round `wall_secs`, append time — are quarantined into the
//! run's single timestamp record, which diff/query treat as provenance,
//! never as a compared metric. `tests/store_e2e.rs` pins this.
//!
//! **Durability.** Appends are single `write_all` calls on an
//! append-mode handle. A crash mid-append leaves a torn final record;
//! [`Ledger::open`] recovers by truncating back to the last intact
//! record boundary, so the next append lands on a clean frame. Readers
//! ([`read_ledger`]) return the intact prefix plus the typed damage, so
//! `tfed history` on a torn ledger still lists every completed run.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::eval::RunMetrics;
use crate::transport::frame::{crc32, MAX_FRAME};
use crate::util::json::{arr, num, obj, s, Json};

/// "TFRL" — distinct from the wire-frame magic "TFRM" and the
/// message-layer magic "TFED".
pub const LEDGER_MAGIC: u32 = u32::from_le_bytes(*b"TFRL");
/// Bump on any payload-schema change so an old binary fails a new ledger
/// with a clear [`LedgerError::BadVersion`], never a confusing decode.
pub const RECORD_VERSION: u8 = 1;
/// Fixed header size: magic + version + kind + length + CRC.
pub const HEADER_BYTES: usize = 14;
/// Upper bound on one record's payload — the transport's frame bound;
/// a corrupt length can never trigger a giant allocation.
pub const MAX_RECORD: usize = MAX_FRAME;

/// What a ledger record carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecordKind {
    /// Run identity: config fingerprint, model/codec/aggregator/partition,
    /// seed, repo stamp, and the deterministic run id.
    RunHeader = 1,
    /// One communication round (loss/acc, wire bytes, sim_secs,
    /// rejections) — everything except the quarantined wall clock.
    Round = 2,
    /// Whole-run rollup: final/best accuracy, byte/frame totals,
    /// virtual-time aggregates.
    Summary = 3,
    /// One bench section's results as a flat name → value map
    /// (`paper_tables` perf trajectory).
    Bench = 4,
    /// The run's wall-clock quarantine: append time + per-round
    /// `wall_secs`. The only record kind allowed to differ across reruns.
    Timestamp = 5,
}

impl RecordKind {
    pub fn from_u8(k: u8) -> Option<RecordKind> {
        Some(match k {
            1 => RecordKind::RunHeader,
            2 => RecordKind::Round,
            3 => RecordKind::Summary,
            4 => RecordKind::Bench,
            5 => RecordKind::Timestamp,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RecordKind::RunHeader => "run_header",
            RecordKind::Round => "round",
            RecordKind::Summary => "summary",
            RecordKind::Bench => "bench",
            RecordKind::Timestamp => "timestamp",
        }
    }

    /// Only this kind may carry nondeterministic (wall-clock) fields.
    pub fn is_wall_clock(self) -> bool {
        matches!(self, RecordKind::Timestamp)
    }
}

/// Typed decode/IO errors, mirroring [`crate::transport::frame::FrameError`]:
/// corruption maps to a specific variant; nothing here panics on file input.
#[derive(Debug)]
pub enum LedgerError {
    WrongMagic(u32),
    BadVersion(u8),
    UnknownKind(u8),
    /// Declared payload length exceeds [`MAX_RECORD`].
    Oversized { len: usize },
    /// Ran out of bytes before the declared end of the record.
    Truncated { wanted: usize, got: usize },
    CrcMismatch { expected: u32, got: u32 },
    /// The framing was intact but the payload JSON was not what the
    /// record kind promises.
    BadPayload { kind: &'static str, reason: String },
    Io(std::io::Error),
}

impl std::fmt::Display for LedgerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LedgerError::WrongMagic(m) => write!(f, "bad ledger magic {m:#010x}"),
            LedgerError::BadVersion(v) => write!(f, "unsupported ledger record version {v}"),
            LedgerError::UnknownKind(k) => write!(f, "unknown ledger record kind {k}"),
            LedgerError::Oversized { len } => {
                write!(f, "record payload length {len} exceeds MAX_RECORD {MAX_RECORD}")
            }
            LedgerError::Truncated { wanted, got } => {
                write!(f, "record truncated: got {got} of {wanted} bytes")
            }
            LedgerError::CrcMismatch { expected, got } => {
                write!(f, "record CRC mismatch: header says {expected:#010x}, payload hashes to {got:#010x}")
            }
            LedgerError::BadPayload { kind, reason } => {
                write!(f, "bad {kind} record payload: {reason}")
            }
            LedgerError::Io(e) => write!(f, "ledger I/O error: {e}"),
        }
    }
}

impl std::error::Error for LedgerError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LedgerError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LedgerError {
    fn from(e: std::io::Error) -> LedgerError {
        LedgerError::Io(e)
    }
}

/// One decoded ledger record: kind + canonical-JSON payload bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub kind: RecordKind,
    pub payload: Vec<u8>,
}

impl Record {
    /// Wrap a JSON document as a record (compact emission — the one
    /// canonical byte encoding).
    pub fn json(kind: RecordKind, doc: &Json) -> Record {
        Record { kind, payload: doc.to_string().into_bytes() }
    }

    /// Parse the payload back into a document.
    pub fn doc(&self) -> Result<Json, LedgerError> {
        let text = std::str::from_utf8(&self.payload).map_err(|e| LedgerError::BadPayload {
            kind: self.kind.name(),
            reason: format!("payload is not UTF-8: {e}"),
        })?;
        Json::parse(text).map_err(|e| LedgerError::BadPayload {
            kind: self.kind.name(),
            reason: format!("payload is not JSON: {e}"),
        })
    }

    /// Total bytes this record occupies in the file.
    pub fn wire_len(&self) -> usize {
        HEADER_BYTES + self.payload.len()
    }

    /// Serialize header + payload.
    pub fn encode(&self) -> Result<Vec<u8>, LedgerError> {
        if self.payload.len() > MAX_RECORD {
            return Err(LedgerError::Oversized { len: self.payload.len() });
        }
        let mut out = Vec::with_capacity(self.wire_len());
        out.extend_from_slice(&LEDGER_MAGIC.to_le_bytes());
        out.push(RECORD_VERSION);
        out.push(self.kind as u8);
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&self.payload).to_le_bytes());
        out.extend_from_slice(&self.payload);
        Ok(out)
    }
}

/// Validate a header; returns (kind, payload length, expected CRC).
fn parse_header(head: [u8; HEADER_BYTES]) -> Result<(RecordKind, usize, u32), LedgerError> {
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != LEDGER_MAGIC {
        return Err(LedgerError::WrongMagic(magic));
    }
    if head[4] != RECORD_VERSION {
        return Err(LedgerError::BadVersion(head[4]));
    }
    let kind = RecordKind::from_u8(head[5]).ok_or(LedgerError::UnknownKind(head[5]))?;
    let len = u32::from_le_bytes(head[6..10].try_into().unwrap()) as usize;
    if len > MAX_RECORD {
        return Err(LedgerError::Oversized { len });
    }
    let crc = u32::from_le_bytes(head[10..14].try_into().unwrap());
    Ok((kind, len, crc))
}

/// Decode one record starting at `off`; returns it plus the next offset.
fn decode_at(bytes: &[u8], off: usize) -> Result<(Record, usize), LedgerError> {
    let rest = &bytes[off..];
    if rest.len() < HEADER_BYTES {
        return Err(LedgerError::Truncated { wanted: HEADER_BYTES, got: rest.len() });
    }
    let (kind, len, crc) = parse_header(rest[..HEADER_BYTES].try_into().unwrap())?;
    let total = HEADER_BYTES + len;
    if rest.len() < total {
        return Err(LedgerError::Truncated { wanted: total, got: rest.len() });
    }
    let payload = &rest[HEADER_BYTES..total];
    let got = crc32(payload);
    if got != crc {
        return Err(LedgerError::CrcMismatch { expected: crc, got });
    }
    Ok((Record { kind, payload: payload.to_vec() }, off + total))
}

/// A scan's outcome: the intact record prefix, the byte offset where it
/// ends, and the typed damage that stopped the scan (None = clean EOF).
pub struct ScanResult {
    pub records: Vec<Record>,
    /// Offset of the last intact record boundary — the recovery
    /// truncation point for an append after a torn write.
    pub good_len: usize,
    pub damage: Option<LedgerError>,
}

/// Decode records front-to-back, stopping (not failing) at the first
/// damaged one — an append-only log's tail is the only place an
/// interrupted writer can leave garbage, and everything before it is
/// still good.
pub fn scan(bytes: &[u8]) -> ScanResult {
    let mut records = Vec::new();
    let mut off = 0usize;
    let mut damage = None;
    while off < bytes.len() {
        match decode_at(bytes, off) {
            Ok((rec, next)) => {
                records.push(rec);
                off = next;
            }
            Err(e) => {
                damage = Some(e);
                break;
            }
        }
    }
    ScanResult { records, good_len: off, damage }
}

/// Read every intact record of a ledger file; torn-tail damage is
/// reported in the result, not fatal. Only real I/O failures error.
pub fn read_ledger(path: impl AsRef<Path>) -> Result<ScanResult, LedgerError> {
    let bytes = std::fs::read(path.as_ref())?;
    Ok(scan(&bytes))
}

/// An open (append-mode) ledger.
pub struct Ledger {
    path: PathBuf,
}

impl Ledger {
    /// Open a ledger for appending, creating it if absent. If a previous
    /// append was interrupted, the torn final record is truncated away so
    /// the file ends on an intact record boundary.
    pub fn open(path: impl AsRef<Path>) -> Result<Ledger, LedgerError> {
        let p = path.as_ref().to_path_buf();
        match std::fs::metadata(&p) {
            Ok(md) => {
                let scanned = read_ledger(&p)?;
                if (scanned.good_len as u64) < md.len() {
                    let f = std::fs::OpenOptions::new().write(true).open(&p)?;
                    f.set_len(scanned.good_len as u64)?;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(e.into()),
        }
        Ok(Ledger { path: p })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append records as one contiguous write, so a run's header, rounds,
    /// summary, and timestamp land together (or a single torn tail).
    pub fn append(&self, records: &[Record]) -> Result<(), LedgerError> {
        let mut buf = Vec::new();
        for r in records {
            buf.extend_from_slice(&r.encode()?);
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
        f.write_all(&buf)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// record builders
// ---------------------------------------------------------------------------

/// Identity + results of one run about to be appended.
pub struct RunInfo<'a> {
    /// Display label (the scenario cell label, or its CLI equivalent).
    pub label: &'a str,
    pub seed: u64,
    /// Canonical partition-strategy name (`iid`, `nc:2`, ...).
    pub partition: &'a str,
    pub codec: &'a str,
    pub protocol: &'a str,
    /// Resolved model name (registry key).
    pub model: &'a str,
    pub aggregator: &'a str,
    /// Adversary label (`behavior@fraction`); None for honest fleets.
    pub adversary: Option<&'a str>,
    pub metrics: &'a RunMetrics,
    /// Time-to-accuracy target (sim grids); threads `sim_secs_to_target`
    /// into the summary record.
    pub target_acc: Option<f64>,
}

/// Build-stamp for the run header: git-describe output when the build
/// exports `TFED_GIT_DESCRIBE`, the package identity otherwise. Constant
/// per binary, so reruns from one build are byte-identical.
pub fn repo_stamp() -> &'static str {
    option_env!("TFED_GIT_DESCRIBE").unwrap_or(concat!("tfed-", env!("CARGO_PKG_VERSION")))
}

/// The identity fields of the run-header payload, id excluded.
fn header_fields(info: &RunInfo<'_>) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("label", s(info.label)),
        ("config", s(&info.metrics.config_summary)),
        ("seed", num(info.seed as f64)),
        ("partition", s(info.partition)),
        ("codec", s(info.codec)),
        ("protocol", s(info.protocol)),
        ("model", s(info.model)),
        ("aggregator", s(info.aggregator)),
        ("repo", s(repo_stamp())),
        ("rounds", num(info.metrics.records.len() as f64)),
    ];
    if let Some(adv) = info.adversary {
        fields.push(("adversary", s(adv)));
    }
    fields
}

/// Deterministic run id: `r` + CRC-32 (hex) of the canonical header
/// payload with the id itself excluded. No clock, no counter — the same
/// fully-seeded config always maps to the same id, which is what makes
/// rerun payloads byte-identical. Reruns therefore *share* an id; the
/// CLI disambiguates by ledger sequence number or an `@<k>` suffix.
pub fn run_id(info: &RunInfo<'_>) -> String {
    format!("r{:08x}", crc32(obj(header_fields(info)).to_string().as_bytes()))
}

/// Build the full record sequence for one run: header, one record per
/// round, summary, and the wall-clock timestamp record.
pub fn run_records(info: &RunInfo<'_>) -> Vec<Record> {
    let id = run_id(info);
    let mut fields = header_fields(info);
    fields.push(("id", s(&id)));
    let mut out = vec![Record::json(RecordKind::RunHeader, &obj(fields))];

    for r in &info.metrics.records {
        // wall_secs deliberately absent: quarantined below
        let mut f = vec![
            ("run", s(&id)),
            ("round", num(r.round as f64)),
            ("train_loss", num(r.train_loss as f64)),
            ("test_acc", num(r.test_acc as f64)),
            ("test_loss", num(r.test_loss as f64)),
            ("up_bytes", num(r.up_bytes as f64)),
            ("down_bytes", num(r.down_bytes as f64)),
            ("up_frames", num(r.up_frames as f64)),
            ("down_frames", num(r.down_frames as f64)),
            ("sim_secs", num(r.sim_secs)),
            ("straggler_delay_ms", num(r.straggler_delay_ms as f64)),
            ("evaluated", Json::Bool(r.evaluated)),
        ];
        // same conditional emission as the bundle: honest rounds keep
        // their bytes
        if !r.rejected.is_empty() {
            f.push(("rejected", arr(r.rejected.iter().map(|&c| num(c as f64)).collect())));
        }
        if !r.clipped.is_empty() {
            f.push(("clipped", arr(r.clipped.iter().map(|&c| num(c as f64)).collect())));
        }
        out.push(Record::json(RecordKind::Round, &obj(f)));
    }

    let m = info.metrics;
    let mut sf = vec![
        ("run", s(&id)),
        ("final_acc", num(m.final_acc() as f64)),
        ("best_acc", num(m.best_acc() as f64)),
        ("total_up_bytes", num(m.total_up_bytes() as f64)),
        ("total_down_bytes", num(m.total_down_bytes() as f64)),
        ("total_up_frames", num(m.total_up_frames() as f64)),
        ("total_down_frames", num(m.total_down_frames() as f64)),
        ("total_sim_secs", num(m.total_sim_secs())),
    ];
    if let Some(rvh) = m.rounds_per_virtual_hour() {
        sf.push(("rounds_per_virtual_hour", num(rvh)));
    }
    if let Some(t) = info.target_acc {
        sf.push(("target_acc", num(t)));
        if let Some(tta) = m.sim_secs_to_acc(t as f32) {
            sf.push(("sim_secs_to_target", num(tta)));
        }
    }
    out.push(Record::json(RecordKind::Summary, &obj(sf)));

    // the wall-clock quarantine: every nondeterministic field of the run
    // lives in this one record and nowhere else
    let unix_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0);
    out.push(Record::json(
        RecordKind::Timestamp,
        &obj(vec![
            ("run", s(&id)),
            ("unix_ms", num(unix_ms)),
            ("total_wall_secs", num(m.total_wall_secs())),
            ("wall_secs", arr(m.records.iter().map(|r| num(r.wall_secs)).collect())),
        ]),
    ));
    out
}

/// One bench section's results as a flat name → value map (keys like
/// `mlp/fp/blocked-4t/samples_per_sec`). Bench values are measured
/// throughput, so this kind is *not* covered by the rerun byte-identity
/// contract — it is the perf-trajectory series diff gates on.
pub fn bench_record(section: &str, values: &[(String, f64)]) -> Record {
    let doc = obj(vec![
        ("section", s(section)),
        ("repo", s(repo_stamp())),
        ("values", obj(values.iter().map(|(k, v)| (k.as_str(), num(*v))).collect())),
    ]);
    Record::json(RecordKind::Bench, &doc)
}

/// Append every cell of a finished scenario, in bundle order — the cell
/// order is the grid order at any `--jobs`, so ledgers are append-order
/// deterministic too. Returns the number of runs appended.
pub fn append_cells(
    path: &str,
    cells: &[crate::scenario::runner::CellResult],
) -> Result<usize, LedgerError> {
    let ledger = Ledger::open(path)?;
    let mut records = Vec::new();
    for c in cells {
        let info = RunInfo {
            label: &c.label,
            seed: c.seed,
            partition: &c.partition,
            codec: &c.codec,
            protocol: &c.protocol,
            model: &c.model,
            aggregator: &c.aggregator,
            adversary: c.adversary.as_deref(),
            metrics: &c.metrics,
            target_acc: c.sim.as_ref().and_then(|s| s.target_acc),
        };
        records.extend(run_records(&info));
    }
    ledger.append(&records)?;
    Ok(cells.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::RoundRecord;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tfed_store_{}_{name}.tfed", std::process::id()))
    }

    fn metrics(rounds: usize, wall: f64) -> RunMetrics {
        let mut m = RunMetrics::new("cfg summary".into());
        for round in 1..=rounds {
            m.push(RoundRecord {
                round,
                train_loss: 0.5,
                test_acc: 0.25 + round as f32 / 10.0,
                test_loss: 0.9,
                up_bytes: 100 * round as u64,
                down_bytes: 90 * round as u64,
                up_frames: 4,
                down_frames: 4,
                wall_secs: wall,
                sim_secs: 0.0,
                straggler_delay_ms: 0,
                selected: vec![0, 1],
                factors: vec![0.1],
                evaluated: true,
                rejected: vec![],
                clipped: vec![],
            });
        }
        m
    }

    fn info<'a>(m: &'a RunMetrics) -> RunInfo<'a> {
        RunInfo {
            label: "seed=7 partition=iid codec=ternary",
            seed: 7,
            partition: "iid",
            codec: "ternary",
            protocol: "T-FedAvg",
            model: "mlp",
            aggregator: "mean",
            adversary: None,
            metrics: m,
            target_acc: None,
        }
    }

    #[test]
    fn record_roundtrip_all_kinds() {
        for kind in [
            RecordKind::RunHeader,
            RecordKind::Round,
            RecordKind::Summary,
            RecordKind::Bench,
            RecordKind::Timestamp,
        ] {
            let rec = Record::json(kind, &obj(vec![("k", num(1.0))]));
            let bytes = rec.encode().unwrap();
            assert_eq!(bytes.len(), rec.wire_len());
            let (back, next) = decode_at(&bytes, 0).unwrap();
            assert_eq!(back, rec);
            assert_eq!(next, bytes.len());
            assert_eq!(RecordKind::from_u8(kind as u8), Some(kind));
        }
        assert_eq!(RecordKind::from_u8(77), None);
    }

    #[test]
    fn every_truncation_and_byte_flip_is_detected() {
        let rec = Record::json(RecordKind::Summary, &obj(vec![("final_acc", num(0.9))]));
        let bytes = rec.encode().unwrap();
        for cut in 0..bytes.len() {
            let r = scan(&bytes[..cut]);
            assert!(r.records.is_empty(), "cut={cut}");
            assert_eq!(r.good_len, 0, "cut={cut}");
            if cut > 0 {
                assert!(r.damage.is_some(), "cut={cut}");
            }
        }
        for pos in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[pos] ^= 0xFF;
            assert!(scan(&bad).damage.is_some(), "flip at {pos} went undetected");
        }
    }

    #[test]
    fn torn_tail_recovery_on_open() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let m = metrics(2, 0.1);
        let ledger = Ledger::open(&path).unwrap();
        ledger.append(&run_records(&info(&m))).unwrap();
        let intact = read_ledger(&path).unwrap();
        assert!(intact.damage.is_none());
        let n_intact = intact.records.len();

        // tear the final record: cut 5 bytes off the file
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let torn = read_ledger(&path).unwrap();
        assert!(matches!(torn.damage, Some(LedgerError::Truncated { .. })));
        assert_eq!(torn.records.len(), n_intact - 1);

        // reopen: the torn tail is truncated away, and a fresh append
        // decodes cleanly end to end
        let ledger = Ledger::open(&path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len() as usize,
            torn.good_len
        );
        ledger.append(&run_records(&info(&m))).unwrap();
        let healed = read_ledger(&path).unwrap();
        assert!(healed.damage.is_none());
        assert_eq!(healed.records.len(), (n_intact - 1) + n_intact);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rerun_payloads_are_byte_identical_outside_timestamp() {
        // different wall clocks, same experiment: only the timestamp
        // record may differ
        let m1 = metrics(3, 0.25);
        let m2 = metrics(3, 7.5);
        let a = run_records(&info(&m1));
        let b = run_records(&info(&m2));
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.kind, rb.kind);
            if ra.kind.is_wall_clock() {
                continue;
            }
            assert_eq!(ra.encode().unwrap(), rb.encode().unwrap(), "{}", ra.kind.name());
            // and the wall clock never leaks outside the quarantine
            assert!(!String::from_utf8(ra.payload.clone()).unwrap().contains("wall_secs"));
        }
        // ids are config-derived and stable
        assert_eq!(run_id(&info(&m1)), run_id(&info(&m2)));
    }

    #[test]
    fn bench_record_shape() {
        let rec = bench_record(
            "train",
            &[("mlp/fp/blocked-4t/samples_per_sec".to_string(), 1234.5)],
        );
        assert_eq!(rec.kind, RecordKind::Bench);
        let doc = rec.doc().unwrap();
        assert_eq!(doc.get("section").unwrap().as_str().unwrap(), "train");
        let v = doc.get("values").unwrap().get("mlp/fp/blocked-4t/samples_per_sec").unwrap();
        assert_eq!(v.as_f64().unwrap(), 1234.5);
    }
}
