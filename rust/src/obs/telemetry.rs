//! Learning-dynamics telemetry: one structured record per round per
//! grid cell (DESIGN.md §12).
//!
//! The paper's central claims are trajectory claims — convergence of the
//! self-learned quantization factors (Fig. 12/13), unbiasedness of FTTQ
//! updates (§IV-B), reduced weight divergence on non-IID data — so this
//! sink records exactly those quantities per round: per-layer
//! quantization factors (FTTQ mean w^q, TTQ wp/wn), ternary sparsity
//! (zero fraction, overall and per layer), the update-unbiasedness
//! residual, L2 weight divergence of the quantized projection against
//! the server's dense fp32 state (the "shadow accumulator" — the
//! orchestrator's `global` is already the full-precision reference), the
//! train/test metrics, and cumulative up/down wire bytes from
//! `LinkStats`, plus the cumulative virtual clock for sim runs.
//!
//! Records accumulate in a process-global store and are drained to an
//! append-only, schema-versioned JSONL file ([`SCHEMA_VERSION`], one
//! JSON object per line) at `obs::finish`, sorted by `(lane, round)` so
//! parallel `--jobs` grids serialize deterministically. A live tail is
//! served by [`crate::obs::http`] while a run is in flight.
//!
//! Standing contract: disabled (the default) this module costs one
//! relaxed atomic load per site ([`enabled`]), draws no RNG, and leaves
//! every existing artifact byte-identical; enabled it only ever adds the
//! separate sink file — never a bundle byte (`tests/telemetry_e2e.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::model::ParamSet;
use crate::util::json::{arr, num, obj, s, Json};

/// Version of the JSONL record schema; bumped whenever a field is
/// renamed, removed, or changes meaning (additions are backward
/// compatible and do not bump it). Every record carries it as `"v"`.
pub const SCHEMA_VERSION: u64 = 1;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDS: Mutex<Vec<TelemetryRecord>> = Mutex::new(Vec::new());

/// Is telemetry collection on? One relaxed load — the whole cost of the
/// disabled path at every instrumentation site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn record collection on or off (process-global).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Drop all collected records (tests; does not change enablement).
pub fn clear() {
    RECORDS.lock().unwrap().clear();
}

/// One per-round learning-dynamics record (schema v1, DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct TelemetryRecord {
    /// obs lane = scenario grid-cell index (0 for standalone runs)
    pub lane: u32,
    pub round: u64,
    /// grid-cell label ("" for standalone runs)
    pub cell: String,
    pub protocol: String,
    pub train_loss: f64,
    /// NaN when the round was not evaluated (emitted as JSON null)
    pub test_acc: f64,
    pub test_loss: f64,
    pub evaluated: bool,
    /// per-layer quantization factors: T-FedAvg mean w^q per quantized
    /// layer; TTQ `[wp..., wn...]`; empty for dense protocols
    pub factors: Vec<f64>,
    /// zero fraction of the quantized projection, per quantized layer
    pub layer_zero_fraction: Vec<f64>,
    /// overall ternary sparsity (zero fraction across all quantized
    /// elements; 0 for dense protocols)
    pub sparsity: f64,
    /// signed mean of (projection − fp32 global) over quantized
    /// elements — the update-unbiasedness residual (≈0 when eq. 20's
    /// scaling is unbiased on this weight distribution)
    pub unbias_residual: f64,
    /// L2 distance between the quantized projection and the dense fp32
    /// server state, over quantized layers
    pub weight_divergence: f64,
    /// `weight_divergence` normalized by the fp32 norm of the same
    /// layers (0 when that norm is 0)
    pub rel_divergence: f64,
    /// cumulative upstream wire bytes at the end of this round
    pub cum_up_bytes: u64,
    pub cum_down_bytes: u64,
    /// cumulative virtual clock (sim runs; 0 on real transports)
    pub sim_secs: f64,
    /// clients rejected this round by per-update validation (typed
    /// `ClientFault`s — Byzantine / malformed updates; 0 on honest runs)
    pub rejected: u64,
    /// clients norm-clipped this round by the `norm_clip` aggregator
    pub clipped: u64,
}

impl TelemetryRecord {
    /// The record as one JSON object (NaN metrics become null).
    pub fn to_json(&self) -> Json {
        let fin = |v: f64| if v.is_finite() { num(v) } else { Json::Null };
        obj(vec![
            ("v", num(SCHEMA_VERSION as f64)),
            ("lane", num(self.lane as f64)),
            ("round", num(self.round as f64)),
            ("cell", s(&self.cell)),
            ("protocol", s(&self.protocol)),
            ("train_loss", fin(self.train_loss)),
            ("test_acc", fin(self.test_acc)),
            ("test_loss", fin(self.test_loss)),
            ("evaluated", Json::Bool(self.evaluated)),
            ("factors", arr(self.factors.iter().map(|&f| fin(f)).collect())),
            (
                "layer_zero_fraction",
                arr(self.layer_zero_fraction.iter().map(|&f| fin(f)).collect()),
            ),
            ("sparsity", fin(self.sparsity)),
            ("unbias_residual", fin(self.unbias_residual)),
            ("weight_divergence", fin(self.weight_divergence)),
            ("rel_divergence", fin(self.rel_divergence)),
            ("cum_up_bytes", num(self.cum_up_bytes as f64)),
            ("cum_down_bytes", num(self.cum_down_bytes as f64)),
            ("sim_secs", fin(self.sim_secs)),
            // schema v1 addition (additive, no version bump): robustness
            // counters — 0/0 on honest rounds
            ("rejected", num(self.rejected as f64)),
            ("clipped", num(self.clipped as f64)),
        ])
    }
}

/// Append one record to the process-global store (no-op advice: callers
/// gate on [`enabled`] so the disabled path never takes this lock).
pub fn record(rec: TelemetryRecord) {
    RECORDS.lock().unwrap().push(rec);
}

/// Drain every collected record, sorted by `(lane, round)` — the same
/// deterministic order whether grid cells ran sequentially or under
/// `--jobs N`.
pub fn take() -> Vec<TelemetryRecord> {
    let mut recs: Vec<TelemetryRecord> = std::mem::take(&mut *RECORDS.lock().unwrap());
    recs.sort_by_key(|r| (r.lane, r.round));
    recs
}

/// Up to `n` most recent records in collection order (live HTTP tail;
/// insertion order is arrival order, which may interleave lanes while a
/// `--jobs` grid is in flight — the JSONL sink is the sorted artifact).
pub fn tail(n: usize) -> Vec<TelemetryRecord> {
    let recs = RECORDS.lock().unwrap();
    recs[recs.len().saturating_sub(n)..].to_vec()
}

/// Render records as schema-versioned JSONL (one compact object per
/// line, trailing newline).
pub fn to_jsonl(recs: &[TelemetryRecord]) -> String {
    let mut out = String::new();
    for r in recs {
        out.push_str(&r.to_json().to_string());
        out.push('\n');
    }
    out
}

// -- the per-round math (pure; hand-checked in tests/telemetry_e2e.rs) ----

/// Signed mean of `(proj − reference)` over the `qidx` tensors: the
/// update-unbiasedness residual. 0 when there are no quantized elements.
pub fn unbias_residual(reference: &ParamSet, proj: &ParamSet, qidx: &[usize]) -> f64 {
    let mut sum = 0f64;
    let mut n = 0usize;
    for &i in qidx {
        let (a, b) = (&reference.tensors[i].data, &proj.tensors[i].data);
        for (&r, &p) in a.iter().zip(b.iter()) {
            sum += p as f64 - r as f64;
        }
        n += a.len();
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// `(L2 distance, relative L2 distance)` between `proj` and `reference`
/// over the `qidx` tensors. The relative form divides by the reference
/// norm of the same layers (0 when that norm is 0).
pub fn weight_divergence(
    reference: &ParamSet,
    proj: &ParamSet,
    qidx: &[usize],
) -> (f64, f64) {
    let mut dist2 = 0f64;
    let mut norm2 = 0f64;
    for &i in qidx {
        let (a, b) = (&reference.tensors[i].data, &proj.tensors[i].data);
        for (&r, &p) in a.iter().zip(b.iter()) {
            let d = p as f64 - r as f64;
            dist2 += d * d;
            norm2 += r as f64 * r as f64;
        }
    }
    let dist = dist2.sqrt();
    let rel = if norm2 > 0.0 { dist / norm2.sqrt() } else { 0.0 };
    (dist, rel)
}

/// Zero fraction of the `qidx` tensors of a quantized projection, per
/// layer and overall (exact zeros — ternary projections are built from
/// `{−w, 0, +w}` so this is the pattern sparsity, no epsilon games).
pub fn zero_fractions(proj: &ParamSet, qidx: &[usize]) -> (Vec<f64>, f64) {
    let mut per_layer = Vec::with_capacity(qidx.len());
    let mut zeros = 0usize;
    let mut total = 0usize;
    for &i in qidx {
        let data = &proj.tensors[i].data;
        let z = data.iter().filter(|&&v| v == 0.0).count();
        per_layer.push(if data.is_empty() { 0.0 } else { z as f64 / data.len() as f64 });
        zeros += z;
        total += data.len();
    }
    let overall = if total == 0 { 0.0 } else { zeros as f64 / total as f64 };
    (per_layer, overall)
}
