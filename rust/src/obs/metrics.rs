//! Lock-cheap metrics registry (offline stand-in for prometheus-client).
//!
//! Three instrument kinds — monotonic [`Counter`]s, [`Gauge`]s, and
//! fixed-log2-bucket [`Histogram`]s — registered once by name and handed
//! out as `&'static` handles (the registry `Mutex` is touched only at
//! registration/scrape, never on the hot path). Counters are sharded
//! across a fixed stripe array so concurrent round workers don't bounce
//! one cache line; stripes are folded in fixed order at scrape time, so
//! a scrape of a quiesced registry is deterministic. Exposition follows
//! the Prometheus text format (`# TYPE` lines, `_bucket{le=...}`
//! cumulative buckets, `_sum`/`_count`), written by `--metrics-out`.
//!
//! Names may carry inline labels (`tfed_frames_total{kind="data"}`);
//! the label block is spliced after histogram suffixes so the emitted
//! series stay well-formed. Names are validated at registration (typed
//! [`MetricError`]; the `try_*` variants return it, the plain variants
//! panic on it) and label values are escaped (`\`, `"`, newline) at
//! exposition, so a scrape can never see malformed Prometheus text.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Stripe fan-out for counters. Power of two; folded at scrape.
const STRIPES: usize = 8;

/// Log2 histogram resolution: bucket `k` holds values of bit-length `k`
/// (`2^(k-1) <= v < 2^k`), bucket 0 holds zero, bucket 63 the rest.
pub const HIST_BUCKETS: usize = 64;

static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

fn stripe_idx() -> usize {
    thread_local! {
        static STRIPE: usize = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) & (STRIPES - 1);
    }
    STRIPE.with(|s| *s)
}

/// Monotonic counter, striped per-thread to keep `add` contention-free.
pub struct Counter {
    stripes: [AtomicU64; STRIPES],
}

impl Counter {
    fn new() -> Self {
        Counter { stripes: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    pub fn add(&self, v: u64) {
        self.stripes[stripe_idx()].fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Fold the stripes (fixed order; wrapping sum is order-independent).
    pub fn value(&self) -> u64 {
        self.stripes.iter().map(|s| s.load(Ordering::Relaxed)).fold(0u64, u64::wrapping_add)
    }
}

/// Last-write-wins gauge holding an `f64` (stored as bits).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Self {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-log2-bucket histogram over `u64` observations.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

/// Bucket index for `v`: its bit length (0 for 0), capped at the top bucket.
pub fn bucket_index(v: u64) -> usize {
    ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `k` (`le` label), except the top bucket
/// which is `+Inf`.
fn bucket_le(k: usize) -> u64 {
    (1u64 << k) - 1
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
}

#[derive(Clone, Copy)]
enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// Why a metric could not be registered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MetricError {
    /// The name (or its inline label block) is not valid Prometheus
    /// syntax; emitting it would corrupt the whole exposition.
    InvalidName { name: String, reason: String },
    /// The name is already registered as a different instrument kind.
    TypeMismatch { name: String, registered: &'static str, requested: &'static str },
}

impl std::fmt::Display for MetricError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricError::InvalidName { name, reason } => {
                write!(f, "invalid metric name {name:?}: {reason}")
            }
            MetricError::TypeMismatch { name, registered, requested } => write!(
                f,
                "metric {name:?} already registered as a {registered}, requested as a {requested}"
            ),
        }
    }
}

impl std::error::Error for MetricError {}

/// One registered series: identity string, parsed name parts (label
/// values stored raw — escaped at exposition), and the instrument.
struct Entry {
    name: String,
    base: String,
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// Registration-ordered registry; locked only to register or scrape.
static REGISTRY: Mutex<Vec<Entry>> = Mutex::new(Vec::new());

fn invalid(name: &str, reason: impl Into<String>) -> MetricError {
    MetricError::InvalidName { name: name.to_string(), reason: reason.into() }
}

/// Validate `name{label="value",...}` and split it into the base name
/// and raw (unescaped) label pairs.
fn parse_name(name: &str) -> Result<(String, Vec<(String, String)>), MetricError> {
    let (base, label_block) = match name.find('{') {
        Some(i) => {
            let rest = &name[i..];
            let inner = rest
                .strip_prefix('{')
                .and_then(|r| r.strip_suffix('}'))
                .ok_or_else(|| invalid(name, "label block must end with '}'"))?;
            (&name[..i], Some(inner))
        }
        None => (name, None),
    };
    let mut chars = base.chars();
    match chars.next() {
        None => return Err(invalid(name, "empty metric name")),
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        Some(c) => return Err(invalid(name, format!("name starts with {c:?}"))),
    }
    if let Some(c) = chars.find(|&c| !(c.is_ascii_alphanumeric() || c == '_' || c == ':')) {
        return Err(invalid(name, format!("name contains {c:?}")));
    }
    let labels = match label_block {
        None => Vec::new(),
        Some(inner) => parse_labels(name, inner)?,
    };
    Ok((base.to_string(), labels))
}

/// Parse `key="value",key="value"`; values may escape `\\`, `\"`, `\n`.
fn parse_labels(name: &str, inner: &str) -> Result<Vec<(String, String)>, MetricError> {
    let mut labels = Vec::new();
    let mut it = inner.chars().peekable();
    loop {
        let mut key = String::new();
        while let Some(&c) = it.peek() {
            if c.is_ascii_alphanumeric() || c == '_' {
                key.push(c);
                it.next();
            } else {
                break;
            }
        }
        if key.is_empty() || key.starts_with(|c: char| c.is_ascii_digit()) {
            return Err(invalid(name, "label name must match [a-zA-Z_][a-zA-Z0-9_]*"));
        }
        if it.next() != Some('=') || it.next() != Some('"') {
            return Err(invalid(name, format!("label {key:?} needs =\"value\"")));
        }
        let mut value = String::new();
        loop {
            match it.next() {
                None => return Err(invalid(name, format!("unterminated value for {key:?}"))),
                Some('"') => break,
                Some('\\') => match it.next() {
                    Some('\\') => value.push('\\'),
                    Some('"') => value.push('"'),
                    Some('n') => value.push('\n'),
                    other => {
                        return Err(invalid(
                            name,
                            format!("bad escape {other:?} in value of {key:?}"),
                        ))
                    }
                },
                Some(c) => value.push(c),
            }
        }
        labels.push((key, value));
        match it.next() {
            None => break,
            Some(',') => continue,
            Some(c) => return Err(invalid(name, format!("expected ',' after a label, got {c:?}"))),
        }
    }
    Ok(labels)
}

/// Look `name` up, or insert the instrument `make` builds. Validates the
/// name on every call (cheap; registration is off the hot path).
fn lookup_or_insert(name: &str, make: fn() -> Metric) -> Result<Metric, MetricError> {
    let (base, labels) = parse_name(name)?;
    let mut reg = REGISTRY.lock().unwrap();
    if let Some(e) = reg.iter().find(|e| e.name == name) {
        return Ok(e.metric);
    }
    let metric = make();
    reg.push(Entry { name: name.to_string(), base, labels, metric });
    Ok(metric)
}

/// Register (or look up) a counter by name. Same name → same handle.
pub fn try_counter(name: &str) -> Result<&'static Counter, MetricError> {
    match lookup_or_insert(name, || Metric::Counter(Box::leak(Box::new(Counter::new()))))? {
        Metric::Counter(c) => Ok(c),
        other => Err(MetricError::TypeMismatch {
            name: name.to_string(),
            registered: other.kind(),
            requested: "counter",
        }),
    }
}

/// Register (or look up) a gauge by name. Same name → same handle.
pub fn try_gauge(name: &str) -> Result<&'static Gauge, MetricError> {
    match lookup_or_insert(name, || Metric::Gauge(Box::leak(Box::new(Gauge::new()))))? {
        Metric::Gauge(g) => Ok(g),
        other => Err(MetricError::TypeMismatch {
            name: name.to_string(),
            registered: other.kind(),
            requested: "gauge",
        }),
    }
}

/// Register (or look up) a histogram by name. Same name → same handle.
pub fn try_histogram(name: &str) -> Result<&'static Histogram, MetricError> {
    match lookup_or_insert(name, || {
        Metric::Histogram(Box::leak(Box::new(Histogram::new())))
    })? {
        Metric::Histogram(h) => Ok(h),
        other => Err(MetricError::TypeMismatch {
            name: name.to_string(),
            registered: other.kind(),
            requested: "histogram",
        }),
    }
}

/// Infallible [`try_counter`]: instrumentation sites use literal names,
/// so a bad name is a programming error — panic with the typed message.
pub fn counter(name: &str) -> &'static Counter {
    try_counter(name).unwrap_or_else(|e| panic!("{e}"))
}

/// Infallible [`try_gauge`] (panics with the typed [`MetricError`]).
pub fn gauge(name: &str) -> &'static Gauge {
    try_gauge(name).unwrap_or_else(|e| panic!("{e}"))
}

/// Infallible [`try_histogram`] (panics with the typed [`MetricError`]).
pub fn histogram(name: &str) -> &'static Histogram {
    try_histogram(name).unwrap_or_else(|e| panic!("{e}"))
}

/// Escape a label value for the Prometheus text format.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Series name with a suffix and an extra (pre-rendered, trusted) label
/// spliced into the block; stored values are escaped here.
fn series(base: &str, suffix: &str, labels: &[(String, String)], extra: &str) -> String {
    let mut all: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if !extra.is_empty() {
        all.push(extra.to_string());
    }
    if all.is_empty() {
        format!("{base}{suffix}")
    } else {
        format!("{base}{suffix}{{{}}}", all.join(","))
    }
}

/// Prometheus text exposition of every registered metric, registration
/// order, `# TYPE` emitted once per base name.
pub fn exposition() -> String {
    use std::fmt::Write as _;
    let reg = REGISTRY.lock().unwrap();
    let mut out = String::new();
    let mut typed: Vec<&str> = Vec::new();
    for entry in reg.iter() {
        let (base, labels, metric) = (entry.base.as_str(), &entry.labels, &entry.metric);
        let kind = metric.kind();
        if !typed.contains(&base) {
            let _ = writeln!(out, "# TYPE {base} {kind}");
            typed.push(base);
        }
        match metric {
            Metric::Counter(c) => {
                let _ = writeln!(out, "{} {}", series(base, "", labels, ""), c.value());
            }
            Metric::Gauge(g) => {
                let _ = writeln!(out, "{} {}", series(base, "", labels, ""), g.value());
            }
            Metric::Histogram(h) => {
                let counts: Vec<u64> =
                    h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
                let top = counts.iter().rposition(|&c| c > 0).unwrap_or(0);
                let mut cum = 0u64;
                for (k, &c) in counts.iter().enumerate().take(top.min(HIST_BUCKETS - 2) + 1) {
                    cum += c;
                    let le = format!("le=\"{}\"", bucket_le(k));
                    let _ = writeln!(out, "{} {}", series(base, "_bucket", labels, &le), cum);
                }
                let _ = writeln!(
                    out,
                    "{} {}",
                    series(base, "_bucket", labels, "le=\"+Inf\""),
                    h.count()
                );
                let _ = writeln!(out, "{} {}", series(base, "_sum", labels, ""), h.sum());
                let _ = writeln!(out, "{} {}", series(base, "_count", labels, ""), h.count());
                // estimated quantiles from the log2 bucket bounds: the
                // upper bound of the first bucket covering the target
                // rank. Conservative (over-estimates within a bucket),
                // but readable without a Perfetto/PromQL round-trip.
                let count = h.count();
                if count > 0 {
                    for (q, suffix) in [(0.50, "_p50"), (0.95, "_p95"), (0.99, "_p99")] {
                        let target = ((q * count as f64).ceil() as u64).max(1);
                        let mut cum = 0u64;
                        let mut at = HIST_BUCKETS - 1;
                        for (k, &c) in counts.iter().enumerate() {
                            cum += c;
                            if cum >= target {
                                at = k;
                                break;
                            }
                        }
                        if at == HIST_BUCKETS - 1 {
                            let _ = writeln!(out, "{} +Inf", series(base, suffix, labels, ""));
                        } else {
                            let _ = writeln!(
                                out,
                                "{} {}",
                                series(base, suffix, labels, ""),
                                bucket_le(at)
                            );
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_folds_across_threads() {
        let c = counter("test_obs_counter_fold_total");
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 4000);
        // same name returns the same handle
        assert!(std::ptr::eq(c, counter("test_obs_counter_fold_total")));
    }

    #[test]
    fn gauge_last_write_wins() {
        let g = gauge("test_obs_gauge");
        g.set(1.5);
        g.set(-2.25);
        assert_eq!(g.value(), -2.25);
    }

    #[test]
    fn histogram_bucket_index_is_bit_length() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn exposition_is_prometheus_shaped() {
        let c = counter("test_obs_expo_total");
        c.add(7);
        let h = histogram("test_obs_expo_bytes");
        h.observe(0);
        h.observe(5);
        h.observe(6);
        let text = exposition();
        assert!(text.contains("# TYPE test_obs_expo_total counter"));
        assert!(text.contains("test_obs_expo_total 7"));
        assert!(text.contains("# TYPE test_obs_expo_bytes histogram"));
        // cumulative buckets: le=0 -> 1 (the zero), le=7 -> 3 (all)
        assert!(text.contains("test_obs_expo_bytes_bucket{le=\"0\"} 1"));
        assert!(text.contains("test_obs_expo_bytes_bucket{le=\"7\"} 3"));
        assert!(text.contains("test_obs_expo_bytes_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("test_obs_expo_bytes_sum 11"));
        assert!(text.contains("test_obs_expo_bytes_count 3"));
    }

    #[test]
    fn histogram_quantiles_are_bucket_bound_estimates() {
        let h = histogram("test_obs_quantile_us");
        // 10 observations: nine land in le=7 (values 4..=7), one in le=63
        for v in [4, 4, 5, 5, 5, 6, 6, 7, 7, 40] {
            h.observe(v);
        }
        let text = exposition();
        // p50 rank 5 and p95 rank 10 resolve to their buckets' upper
        // bounds; p99 rounds up to rank 10 as well
        assert!(text.contains("test_obs_quantile_us_p50 7"));
        assert!(text.contains("test_obs_quantile_us_p95 63"));
        assert!(text.contains("test_obs_quantile_us_p99 63"));
        // an empty histogram emits no quantile series at all
        let _ = histogram("test_obs_quantile_empty_us");
        let text = exposition();
        assert!(!text.contains("test_obs_quantile_empty_us_p50"));
    }

    #[test]
    fn labeled_names_splice_le_into_block() {
        let h = histogram("test_obs_labeled_bytes{kind=\"data\"}");
        h.observe(2);
        let text = exposition();
        assert!(text.contains("# TYPE test_obs_labeled_bytes histogram"));
        assert!(text.contains("test_obs_labeled_bytes_bucket{kind=\"data\",le=\"+Inf\"} 1"));
        assert!(text.contains("test_obs_labeled_bytes_sum{kind=\"data\"} 2"));
    }

    #[test]
    fn bad_names_are_typed_errors() {
        for bad in [
            "",
            "9starts_with_digit",
            "has space",
            "has-dash_total",
            "name{unclosed=\"x\"",
            "name{=\"x\"}",
            "name{k=x}",
            "name{k=\"unterminated}",
            "name{k=\"v\" j=\"w\"}",
            "name{k=\"bad\\q\"}",
        ] {
            match try_counter(bad) {
                Err(MetricError::InvalidName { name, .. }) => assert_eq!(name, bad),
                Err(other) => panic!("{bad:?} should be InvalidName, got {other}"),
                Ok(_) => panic!("{bad:?} should have been rejected"),
            }
        }
    }

    #[test]
    fn type_mismatch_is_a_typed_error() {
        try_counter("test_obs_kind_clash_total").unwrap();
        match try_gauge("test_obs_kind_clash_total") {
            Err(MetricError::TypeMismatch { registered, requested, .. }) => {
                assert_eq!((registered, requested), ("counter", "gauge"));
            }
            Err(other) => panic!("expected TypeMismatch, got {other}"),
            Ok(_) => panic!("kind clash should not resolve"),
        }
    }

    #[test]
    fn label_values_are_escaped_at_exposition() {
        // registered with input-side escapes: value is `pa\th "q"` + newline
        let c = counter("test_obs_escape_total{path=\"pa\\\\th \\\"q\\\"\\n\"}");
        c.inc();
        let text = exposition();
        // emitted with the Prometheus escapes, newline as literal \n
        assert!(
            text.contains("test_obs_escape_total{path=\"pa\\\\th \\\"q\\\"\\n\"} 1"),
            "missing escaped series in {text:?}"
        );
        // the raw newline in the value never splits the exposition line
        let series_lines =
            text.lines().filter(|l| l.starts_with("test_obs_escape_total{")).count();
        assert_eq!(series_lines, 1);
    }
}
