//! Lock-cheap metrics registry (offline stand-in for prometheus-client).
//!
//! Three instrument kinds — monotonic [`Counter`]s, [`Gauge`]s, and
//! fixed-log2-bucket [`Histogram`]s — registered once by name and handed
//! out as `&'static` handles (the registry `Mutex` is touched only at
//! registration/scrape, never on the hot path). Counters are sharded
//! across a fixed stripe array so concurrent round workers don't bounce
//! one cache line; stripes are folded in fixed order at scrape time, so
//! a scrape of a quiesced registry is deterministic. Exposition follows
//! the Prometheus text format (`# TYPE` lines, `_bucket{le=...}`
//! cumulative buckets, `_sum`/`_count`), written by `--metrics-out`.
//!
//! Names may carry inline labels (`tfed_frames_total{kind="data"}`);
//! the label block is spliced after histogram suffixes so the emitted
//! series stay well-formed.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Stripe fan-out for counters. Power of two; folded at scrape.
const STRIPES: usize = 8;

/// Log2 histogram resolution: bucket `k` holds values of bit-length `k`
/// (`2^(k-1) <= v < 2^k`), bucket 0 holds zero, bucket 63 the rest.
pub const HIST_BUCKETS: usize = 64;

static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

fn stripe_idx() -> usize {
    thread_local! {
        static STRIPE: usize = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) & (STRIPES - 1);
    }
    STRIPE.with(|s| *s)
}

/// Monotonic counter, striped per-thread to keep `add` contention-free.
pub struct Counter {
    stripes: [AtomicU64; STRIPES],
}

impl Counter {
    fn new() -> Self {
        Counter { stripes: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    pub fn add(&self, v: u64) {
        self.stripes[stripe_idx()].fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Fold the stripes (fixed order; wrapping sum is order-independent).
    pub fn value(&self) -> u64 {
        self.stripes.iter().map(|s| s.load(Ordering::Relaxed)).fold(0u64, u64::wrapping_add)
    }
}

/// Last-write-wins gauge holding an `f64` (stored as bits).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Self {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-log2-bucket histogram over `u64` observations.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

/// Bucket index for `v`: its bit length (0 for 0), capped at the top bucket.
pub fn bucket_index(v: u64) -> usize {
    ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `k` (`le` label), except the top bucket
/// which is `+Inf`.
fn bucket_le(k: usize) -> u64 {
    (1u64 << k) - 1
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

/// Registration-ordered registry; locked only to register or scrape.
static REGISTRY: Mutex<Vec<(String, Metric)>> = Mutex::new(Vec::new());

/// Register (or look up) a counter by name. Same name → same handle.
pub fn counter(name: &str) -> &'static Counter {
    let mut reg = REGISTRY.lock().unwrap();
    for (n, m) in reg.iter() {
        if n == name {
            match m {
                Metric::Counter(c) => return c,
                _ => panic!("metric {name:?} already registered with a different type"),
            }
        }
    }
    let c: &'static Counter = Box::leak(Box::new(Counter::new()));
    reg.push((name.to_string(), Metric::Counter(c)));
    c
}

/// Register (or look up) a gauge by name. Same name → same handle.
pub fn gauge(name: &str) -> &'static Gauge {
    let mut reg = REGISTRY.lock().unwrap();
    for (n, m) in reg.iter() {
        if n == name {
            match m {
                Metric::Gauge(g) => return g,
                _ => panic!("metric {name:?} already registered with a different type"),
            }
        }
    }
    let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
    reg.push((name.to_string(), Metric::Gauge(g)));
    g
}

/// Register (or look up) a histogram by name. Same name → same handle.
pub fn histogram(name: &str) -> &'static Histogram {
    let mut reg = REGISTRY.lock().unwrap();
    for (n, m) in reg.iter() {
        if n == name {
            match m {
                Metric::Histogram(h) => return h,
                _ => panic!("metric {name:?} already registered with a different type"),
            }
        }
    }
    let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
    reg.push((name.to_string(), Metric::Histogram(h)));
    h
}

/// Split `name{labels}` into (`name`, `labels`); labels may be empty.
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i..].trim_start_matches('{').trim_end_matches('}')),
        None => (name, ""),
    }
}

/// Series name with a suffix and an extra label spliced into the block.
fn series(base: &str, suffix: &str, labels: &str, extra: &str) -> String {
    let mut all = String::new();
    if !labels.is_empty() {
        all.push_str(labels);
    }
    if !extra.is_empty() {
        if !all.is_empty() {
            all.push(',');
        }
        all.push_str(extra);
    }
    if all.is_empty() {
        format!("{base}{suffix}")
    } else {
        format!("{base}{suffix}{{{all}}}")
    }
}

/// Prometheus text exposition of every registered metric, registration
/// order, `# TYPE` emitted once per base name.
pub fn exposition() -> String {
    use std::fmt::Write as _;
    let reg = REGISTRY.lock().unwrap();
    let mut out = String::new();
    let mut typed: Vec<&str> = Vec::new();
    for (name, metric) in reg.iter() {
        let (base, labels) = split_labels(name);
        let kind = match metric {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        };
        if !typed.contains(&base) {
            let _ = writeln!(out, "# TYPE {base} {kind}");
            typed.push(base);
        }
        match metric {
            Metric::Counter(c) => {
                let _ = writeln!(out, "{} {}", series(base, "", labels, ""), c.value());
            }
            Metric::Gauge(g) => {
                let _ = writeln!(out, "{} {}", series(base, "", labels, ""), g.value());
            }
            Metric::Histogram(h) => {
                let counts: Vec<u64> =
                    h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
                let top = counts.iter().rposition(|&c| c > 0).unwrap_or(0);
                let mut cum = 0u64;
                for (k, &c) in counts.iter().enumerate().take(top.min(HIST_BUCKETS - 2) + 1) {
                    cum += c;
                    let le = format!("le=\"{}\"", bucket_le(k));
                    let _ = writeln!(out, "{} {}", series(base, "_bucket", labels, &le), cum);
                }
                let _ = writeln!(
                    out,
                    "{} {}",
                    series(base, "_bucket", labels, "le=\"+Inf\""),
                    h.count()
                );
                let _ = writeln!(out, "{} {}", series(base, "_sum", labels, ""), h.sum());
                let _ = writeln!(out, "{} {}", series(base, "_count", labels, ""), h.count());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_folds_across_threads() {
        let c = counter("test_obs_counter_fold_total");
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 4000);
        // same name returns the same handle
        assert!(std::ptr::eq(c, counter("test_obs_counter_fold_total")));
    }

    #[test]
    fn gauge_last_write_wins() {
        let g = gauge("test_obs_gauge");
        g.set(1.5);
        g.set(-2.25);
        assert_eq!(g.value(), -2.25);
    }

    #[test]
    fn histogram_bucket_index_is_bit_length() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn exposition_is_prometheus_shaped() {
        let c = counter("test_obs_expo_total");
        c.add(7);
        let h = histogram("test_obs_expo_bytes");
        h.observe(0);
        h.observe(5);
        h.observe(6);
        let text = exposition();
        assert!(text.contains("# TYPE test_obs_expo_total counter"));
        assert!(text.contains("test_obs_expo_total 7"));
        assert!(text.contains("# TYPE test_obs_expo_bytes histogram"));
        // cumulative buckets: le=0 -> 1 (the zero), le=7 -> 3 (all)
        assert!(text.contains("test_obs_expo_bytes_bucket{le=\"0\"} 1"));
        assert!(text.contains("test_obs_expo_bytes_bucket{le=\"7\"} 3"));
        assert!(text.contains("test_obs_expo_bytes_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("test_obs_expo_bytes_sum 11"));
        assert!(text.contains("test_obs_expo_bytes_count 3"));
    }

    #[test]
    fn labeled_names_splice_le_into_block() {
        let h = histogram("test_obs_labeled_bytes{kind=\"data\"}");
        h.observe(2);
        let text = exposition();
        assert!(text.contains("# TYPE test_obs_labeled_bytes histogram"));
        assert!(text.contains("test_obs_labeled_bytes_bucket{kind=\"data\",le=\"+Inf\"} 1"));
        assert!(text.contains("test_obs_labeled_bytes_sum{kind=\"data\"} 2"));
    }
}
