//! Read-side of the run ledger: grouping, selection, and the rendering
//! behind `tfed history` / `tfed query` / `tfed diff`.
//!
//! [`crate::obs::store`] owns bytes; this module owns meaning. It folds
//! a record stream into per-run entries, resolves the CLI's run
//! selectors, and renders the three views. `diff` doubles as the CI perf
//! gate: it compares two runs (or two bench records) and reports every
//! threshold breach, which the CLI turns into a nonzero exit.
//!
//! Run ids are config-derived ([`store::run_id`]), so reruns of the same
//! experiment share an id. Selectors therefore come in three shapes:
//! a bare sequence number (`3` — the stable per-ledger position shown by
//! `history`), a bare id (`r1c0ffee2` — latest occurrence wins), or
//! `id@k` (k-th occurrence of that id, 0-based, for comparing reruns).

use anyhow::{bail, Context, Result};

use crate::eval::mb;
use crate::obs::store::{self, Record, RecordKind};
use crate::util::json::Json;

/// One run folded out of the record stream.
pub struct RunEntry {
    /// 1-based position in the ledger (order of appearance).
    pub seq: usize,
    /// Config-derived run id from the header record.
    pub id: String,
    pub header: Json,
    pub rounds: Vec<Json>,
    pub summary: Option<Json>,
    pub timestamp: Option<Json>,
}

/// One bench record (standalone — no rounds/summary attached).
pub struct BenchEntry {
    pub seq: usize,
    /// `b` + CRC-32 of the payload: content-derived like run ids.
    pub id: String,
    pub section: String,
    pub values: Vec<(String, f64)>,
}

pub enum Entry {
    Run(RunEntry),
    Bench(BenchEntry),
}

impl Entry {
    pub fn seq(&self) -> usize {
        match self {
            Entry::Run(r) => r.seq,
            Entry::Bench(b) => b.seq,
        }
    }

    pub fn id(&self) -> &str {
        match self {
            Entry::Run(r) => &r.id,
            Entry::Bench(b) => &b.id,
        }
    }
}

/// A fully grouped ledger, plus any torn-tail damage the scan hit.
pub struct LedgerView {
    pub entries: Vec<Entry>,
    /// Human-readable damage note (None for a clean file). The intact
    /// prefix is still fully usable.
    pub damage: Option<String>,
}

/// String field accessor with "" default — header fields are
/// emit-controlled by us, so absence means an older record version.
fn st<'a>(doc: &'a Json, key: &str) -> &'a str {
    doc.get(key).and_then(|v| v.as_str().ok()).unwrap_or("")
}

fn f(doc: &Json, key: &str) -> f64 {
    doc.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
}

/// Group a decoded record stream into run/bench entries.
pub fn view_of(records: &[Record], damage: Option<String>) -> Result<LedgerView> {
    let mut entries: Vec<Entry> = Vec::new();
    let mut current: Option<RunEntry> = None;
    for rec in records {
        let doc = rec.doc()?;
        match rec.kind {
            RecordKind::RunHeader => {
                if let Some(run) = current.take() {
                    entries.push(Entry::Run(run));
                }
                let id = st(&doc, "id").to_string();
                current = Some(RunEntry {
                    seq: 0,
                    id,
                    header: doc,
                    rounds: Vec::new(),
                    summary: None,
                    timestamp: None,
                });
            }
            RecordKind::Round | RecordKind::Summary | RecordKind::Timestamp => {
                let run = current
                    .as_mut()
                    .with_context(|| format!("{} record before any run header", rec.kind.name()))?;
                match rec.kind {
                    RecordKind::Round => run.rounds.push(doc),
                    RecordKind::Summary => run.summary = Some(doc),
                    _ => run.timestamp = Some(doc),
                }
            }
            RecordKind::Bench => {
                if let Some(run) = current.take() {
                    entries.push(Entry::Run(run));
                }
                let values = doc
                    .get("values")
                    .and_then(|v| v.as_obj().ok())
                    .map(|m| {
                        m.iter()
                            .filter_map(|(k, v)| v.as_f64().ok().map(|x| (k.clone(), x)))
                            .collect()
                    })
                    .unwrap_or_default();
                entries.push(Entry::Bench(BenchEntry {
                    seq: 0,
                    id: format!("b{:08x}", crate::transport::frame::crc32(&rec.payload)),
                    section: st(&doc, "section").to_string(),
                    values,
                }));
            }
        }
    }
    if let Some(run) = current.take() {
        entries.push(Entry::Run(run));
    }
    for (i, e) in entries.iter_mut().enumerate() {
        match e {
            Entry::Run(r) => r.seq = i + 1,
            Entry::Bench(b) => b.seq = i + 1,
        }
    }
    Ok(LedgerView { entries, damage })
}

/// Load and group a ledger file. Torn-tail damage is surfaced as a note,
/// never an error — `history` on a crashed run's ledger must still work.
pub fn load(path: &str) -> Result<LedgerView> {
    let scanned = store::read_ledger(path).with_context(|| format!("reading ledger {path:?}"))?;
    let damage = scanned.damage.as_ref().map(|d| {
        format!("torn tail at byte {} ({d}); listing the intact prefix", scanned.good_len)
    });
    view_of(&scanned.records, damage)
}

/// Resolve a run selector: `3` (seq) | `r1c0ffee2` (latest with that id)
/// | `r1c0ffee2@0` (k-th occurrence, 0-based).
pub fn find<'a>(view: &'a LedgerView, sel: &str) -> Result<&'a Entry> {
    if !sel.is_empty() && sel.bytes().all(|b| b.is_ascii_digit()) {
        let seq: usize = sel.parse().unwrap();
        return view
            .entries
            .iter()
            .find(|e| e.seq() == seq)
            .with_context(|| format!("no entry with seq {seq} (ledger has {})", view.entries.len()));
    }
    if let Some((id, k)) = sel.rsplit_once('@') {
        let k: usize = k.parse().with_context(|| format!("bad occurrence index in {sel:?}"))?;
        return view
            .entries
            .iter()
            .filter(|e| e.id() == id)
            .nth(k)
            .with_context(|| format!("fewer than {} occurrences of id {id:?}", k + 1));
    }
    view.entries
        .iter()
        .rev()
        .find(|e| e.id() == sel)
        .with_context(|| format!("no entry with id {sel:?} (try `tfed history`)"))
}

/// `tfed history` filters — empty/None means "any".
#[derive(Default)]
pub struct HistoryFilter {
    pub model: Option<String>,
    pub codec: Option<String>,
    pub aggregator: Option<String>,
    pub partition: Option<String>,
    pub seed: Option<u64>,
}

impl HistoryFilter {
    fn is_empty(&self) -> bool {
        self.model.is_none()
            && self.codec.is_none()
            && self.aggregator.is_none()
            && self.partition.is_none()
            && self.seed.is_none()
    }

    fn matches(&self, run: &RunEntry) -> bool {
        let want = |field: &Option<String>, key: &str| {
            field.as_deref().is_none_or(|w| st(&run.header, key) == w)
        };
        want(&self.model, "model")
            && want(&self.codec, "codec")
            && want(&self.aggregator, "aggregator")
            && want(&self.partition, "partition")
            && self.seed.is_none_or(|w| f(&run.header, "seed") as u64 == w)
    }
}

/// Render the run list. Bench entries are listed too (they share the
/// sequence numbering) unless a run-identity filter is active.
pub fn render_history(view: &LedgerView, filter: &HistoryFilter) -> String {
    let mut out = String::from("  seq  id         final_acc  rounds  label\n");
    let mut shown = 0usize;
    for e in &view.entries {
        match e {
            Entry::Run(r) => {
                if !filter.matches(r) {
                    continue;
                }
                let final_acc = r.summary.as_ref().map(|s| f(s, "final_acc")).unwrap_or(0.0);
                out.push_str(&format!(
                    "{:>5}  {}  {:>9.4}  {:>6}  {}\n",
                    r.seq,
                    r.id,
                    final_acc,
                    r.rounds.len(),
                    st(&r.header, "label"),
                ));
                shown += 1;
            }
            Entry::Bench(b) => {
                if !filter.is_empty() {
                    continue;
                }
                out.push_str(&format!(
                    "{:>5}  {}  {:>9}  {:>6}  bench [{}] ({} values)\n",
                    b.seq,
                    b.id,
                    "-",
                    "-",
                    b.section,
                    b.values.len(),
                ));
                shown += 1;
            }
        }
    }
    if shown == 0 {
        out.push_str("  (no matching entries)\n");
    }
    if let Some(d) = &view.damage {
        out.push_str(&format!("warning: {d}\n"));
    }
    out
}

/// Dense fp32 reference ratio, priced exactly like `obs/report.rs`:
/// every data frame re-costed at `param_count × 4` bytes, divided by the
/// measured wire bytes. None when the model is unknown to the registry.
fn compression_ratio(run: &RunEntry) -> Option<(f64, usize)> {
    let model = st(&run.header, "model");
    let params = crate::model::registry::model_def(model).ok().map(|d| d.schema.param_count())?;
    let summary = run.summary.as_ref()?;
    let frames =
        (f(summary, "total_up_frames") + f(summary, "total_down_frames")) as u64;
    let wire =
        ((f(summary, "total_up_bytes") + f(summary, "total_down_bytes")) as u64).max(1);
    let dense = frames * params as u64 * 4;
    Some((dense as f64 / wire as f64, params))
}

/// Render one run in full (`tfed query`).
pub fn render_entry(entry: &Entry) -> String {
    let run = match entry {
        Entry::Run(r) => r,
        Entry::Bench(b) => {
            let mut out = format!("bench {} (seq {}) [{}]\n", b.id, b.seq, b.section);
            for (k, v) in &b.values {
                out.push_str(&format!("  {k} : {v}\n"));
            }
            return out;
        }
    };
    let h = &run.header;
    let mut out = format!("run {} (seq {})\n", run.id, run.seq);
    out.push_str(&format!("  label      : {}\n", st(h, "label")));
    out.push_str(&format!("  config     : {}\n", st(h, "config")));
    out.push_str(&format!("  repo       : {}\n", st(h, "repo")));
    out.push_str(&format!(
        "  identity   : model={} codec={} aggregator={} partition={} protocol={} seed={}\n",
        st(h, "model"),
        st(h, "codec"),
        st(h, "aggregator"),
        st(h, "partition"),
        st(h, "protocol"),
        f(h, "seed") as u64,
    ));
    if h.get("adversary").is_some() {
        out.push_str(&format!("  adversary  : {}\n", st(h, "adversary")));
    }
    if let Some(s) = &run.summary {
        out.push_str(&format!(
            "  accuracy   : final {:.4}, best {:.4} over {} rounds\n",
            f(s, "final_acc"),
            f(s, "best_acc"),
            run.rounds.len(),
        ));
        out.push_str(&format!(
            "  upstream   : {:.3} MB in {} frames\n",
            mb(f(s, "total_up_bytes") as u64),
            f(s, "total_up_frames") as u64,
        ));
        out.push_str(&format!(
            "  downstream : {:.3} MB in {} frames\n",
            mb(f(s, "total_down_bytes") as u64),
            f(s, "total_down_frames") as u64,
        ));
        if let Some((ratio, params)) = compression_ratio(run) {
            out.push_str(&format!(
                "  compression: {ratio:.2}x vs dense fp32 ({params} params)\n"
            ));
        }
        if f(s, "total_sim_secs") > 0.0 {
            out.push_str(&format!(
                "  sim        : {:.1} virtual secs, {:.1} rounds/virtual-hour\n",
                f(s, "total_sim_secs"),
                f(s, "rounds_per_virtual_hour"),
            ));
            if s.get("target_acc").is_some() {
                match s.get("sim_secs_to_target") {
                    Some(t) => out.push_str(&format!(
                        "  to-target  : {:.1} virtual secs to acc {:.2}\n",
                        t.as_f64().unwrap_or(0.0),
                        f(s, "target_acc"),
                    )),
                    None => out.push_str(&format!(
                        "  to-target  : acc {:.2} never reached\n",
                        f(s, "target_acc"),
                    )),
                }
            }
        }
    }
    if let Some(t) = &run.timestamp {
        out.push_str(&format!(
            "  recorded   : unix_ms {} (wall {:.2}s)\n",
            f(t, "unix_ms") as u64,
            f(t, "total_wall_secs"),
        ));
    }
    out.push_str("  rounds:\n");
    out.push_str("  round,train_loss,test_acc,up_bytes,down_bytes,sim_secs\n");
    for r in &run.rounds {
        out.push_str(&format!(
            "  {},{},{},{},{},{}\n",
            f(r, "round") as u64,
            f(r, "train_loss"),
            f(r, "test_acc"),
            f(r, "up_bytes") as u64,
            f(r, "down_bytes") as u64,
            f(r, "sim_secs"),
        ));
    }
    out
}

/// Regression thresholds for the diff gate. A breach is *b regressing
/// relative to a* beyond the allowance; negatives tighten the gate
/// (e.g. `--max-acc-drop=-0.01` demands improvement).
pub struct DiffThresholds {
    /// Max tolerated `a.final_acc − b.final_acc`.
    pub max_acc_drop: f64,
    /// Max tolerated total-MB growth, in percent of a's total.
    pub max_mb_grow_pct: f64,
    /// Max tolerated throughput drop (rounds/virtual-hour, bench
    /// samples/sec), in percent of a's value.
    pub max_perf_drop_pct: f64,
}

/// A rendered diff plus every threshold breach (empty = gate passes).
pub struct Diff {
    pub text: String,
    pub breaches: Vec<String>,
}

fn diff_runs(a: &RunEntry, b: &RunEntry, t: &DiffThresholds) -> Diff {
    let mut text = format!(
        "diff a={} (seq {}) vs b={} (seq {})\n",
        a.id, a.seq, b.id, b.seq
    );
    let mut breaches = Vec::new();
    let sa = a.summary.as_ref();
    let sb = b.summary.as_ref();
    let g = |s: &Option<&Json>, k: &str| s.map(|s| f(s, k)).unwrap_or(0.0);
    let mut drift = false;

    let acc_a = g(&sa, "final_acc");
    let acc_b = g(&sb, "final_acc");
    let acc_drop = acc_a - acc_b;
    text.push_str(&format!(
        "  final_acc     : a {:.4}  b {:.4}  delta {:+.4}\n",
        acc_a,
        acc_b,
        acc_b - acc_a
    ));
    drift |= acc_drop != 0.0;
    if acc_drop > t.max_acc_drop {
        breaches.push(format!(
            "final_acc dropped {acc_drop:.4} (> max-acc-drop {:.4})",
            t.max_acc_drop
        ));
    }

    let mb_a = mb((g(&sa, "total_up_bytes") + g(&sa, "total_down_bytes")) as u64);
    let mb_b = mb((g(&sb, "total_up_bytes") + g(&sb, "total_down_bytes")) as u64);
    let grow_pct = if mb_a > 0.0 { (mb_b - mb_a) / mb_a * 100.0 } else { 0.0 };
    text.push_str(&format!(
        "  total MB      : a {:.3}  b {:.3}  delta {:+.1}%\n",
        mb_a, mb_b, grow_pct
    ));
    drift |= mb_a != mb_b;
    if grow_pct > t.max_mb_grow_pct {
        breaches.push(format!(
            "wire bytes grew {grow_pct:.1}% (> max-mb-grow-pct {:.1})",
            t.max_mb_grow_pct
        ));
    }

    let rvh_a = g(&sa, "rounds_per_virtual_hour");
    let rvh_b = g(&sb, "rounds_per_virtual_hour");
    if rvh_a > 0.0 && rvh_b > 0.0 {
        let drop_pct = (rvh_a - rvh_b) / rvh_a * 100.0;
        text.push_str(&format!(
            "  rounds/vhour  : a {:.1}  b {:.1}  delta {:+.1}%\n",
            rvh_a, rvh_b, -drop_pct
        ));
        drift |= rvh_a != rvh_b;
        if drop_pct > t.max_perf_drop_pct {
            breaches.push(format!(
                "rounds/virtual-hour dropped {drop_pct:.1}% (> max-perf-drop-pct {:.1})",
                t.max_perf_drop_pct
            ));
        }
    }

    if a.rounds.len() == b.rounds.len() {
        text.push_str("  per-round (b − a):\n");
        text.push_str("  round,d_test_acc,d_up_bytes,d_sim_secs\n");
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            let d_acc = f(rb, "test_acc") - f(ra, "test_acc");
            let d_up = f(rb, "up_bytes") - f(ra, "up_bytes");
            let d_sim = f(rb, "sim_secs") - f(ra, "sim_secs");
            drift |= d_acc != 0.0 || d_up != 0.0 || d_sim != 0.0;
            text.push_str(&format!("  {},{},{},{}\n", f(ra, "round") as u64, d_acc, d_up, d_sim));
        }
    } else {
        text.push_str(&format!(
            "  rounds        : a has {}, b has {} (per-round diff skipped)\n",
            a.rounds.len(),
            b.rounds.len()
        ));
        drift = true;
    }

    if !drift {
        text.push_str("  zero drift: runs are metrically identical\n");
    }
    Diff { text, breaches }
}

fn diff_benches(a: &BenchEntry, b: &BenchEntry, t: &DiffThresholds) -> Result<Diff> {
    if a.section != b.section {
        bail!(
            "cannot diff bench sections {:?} vs {:?} — pick entries from the same section",
            a.section,
            b.section
        );
    }
    let mut text = format!(
        "bench diff [{}] a={} (seq {}) vs b={} (seq {})\n",
        a.section, a.id, a.seq, b.id, b.seq
    );
    let mut breaches = Vec::new();
    let mut drift = false;
    for (k, va) in &a.values {
        let Some(vb) = b.values.iter().find(|(kb, _)| kb == k).map(|(_, v)| *v) else {
            text.push_str(&format!("  {k} : only in a\n"));
            drift = true;
            continue;
        };
        let pct = if *va != 0.0 { (vb - va) / va * 100.0 } else { 0.0 };
        text.push_str(&format!("  {k} : a {va}  b {vb}  delta {pct:+.1}%\n"));
        drift |= *va != vb;
        // throughput-shaped keys are gated; cost-shaped keys are
        // informational (their gate is the run-level MB check)
        let higher_better =
            k.contains("samples_per_sec") || k.contains("rounds_per_virtual_hour");
        if higher_better && -pct > t.max_perf_drop_pct {
            breaches.push(format!(
                "{k} dropped {:.1}% (> max-perf-drop-pct {:.1})",
                -pct, t.max_perf_drop_pct
            ));
        }
    }
    for (k, _) in &b.values {
        if !a.values.iter().any(|(ka, _)| ka == k) {
            text.push_str(&format!("  {k} : only in b\n"));
            drift = true;
        }
    }
    if !drift {
        text.push_str("  zero drift: bench values are identical\n");
    }
    Ok(Diff { text, breaches })
}

/// Diff two ledger entries (`tfed diff`). Run-vs-run and bench-vs-bench
/// are supported; mixing the two is an error.
pub fn diff(view: &LedgerView, sel_a: &str, sel_b: &str, t: &DiffThresholds) -> Result<Diff> {
    let a = find(view, sel_a)?;
    let b = find(view, sel_b)?;
    match (a, b) {
        (Entry::Run(a), Entry::Run(b)) => Ok(diff_runs(a, b, t)),
        (Entry::Bench(a), Entry::Bench(b)) => diff_benches(a, b, t),
        _ => bail!("cannot diff a run against a bench record"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{RoundRecord, RunMetrics};
    use crate::obs::store::{bench_record, run_records, RunInfo};

    fn metrics(accs: &[f32]) -> RunMetrics {
        let mut m = RunMetrics::new("cfg".into());
        for (i, &acc) in accs.iter().enumerate() {
            m.push(RoundRecord {
                round: i + 1,
                train_loss: 0.4,
                test_acc: acc,
                test_loss: 0.8,
                up_bytes: 1000,
                down_bytes: 900,
                up_frames: 4,
                down_frames: 4,
                wall_secs: 0.2,
                sim_secs: 30.0,
                straggler_delay_ms: 0,
                selected: vec![0],
                factors: vec![],
                evaluated: true,
                rejected: vec![],
                clipped: vec![],
            });
        }
        m
    }

    fn records_for(seed: u64, accs: &[f32]) -> Vec<Record> {
        let m = metrics(accs);
        run_records(&RunInfo {
            label: "cell",
            seed,
            partition: "iid",
            codec: "ternary",
            protocol: "T-FedAvg",
            model: "mlp",
            aggregator: "mean",
            adversary: None,
            metrics: &m,
            target_acc: None,
        })
    }

    fn thresholds() -> DiffThresholds {
        DiffThresholds { max_acc_drop: 0.02, max_mb_grow_pct: 10.0, max_perf_drop_pct: 20.0 }
    }

    #[test]
    fn grouping_selectors_and_history() {
        let mut recs = records_for(1, &[0.5, 0.6]);
        recs.extend(records_for(2, &[0.4, 0.7]));
        recs.push(bench_record("train", &[("mlp/samples_per_sec".into(), 100.0)]));
        let view = view_of(&recs, None).unwrap();
        assert_eq!(view.entries.len(), 3);

        // seq selector
        let e = find(&view, "2").unwrap();
        assert_eq!(e.seq(), 2);
        // id selector (ids differ by seed)
        let id1 = view.entries[0].id().to_string();
        assert!(id1.starts_with('r'));
        assert_eq!(find(&view, &id1).unwrap().seq(), 1);
        // occurrence selector on a rerun-shared id
        let mut rerun = records_for(1, &[0.5, 0.6]);
        rerun.extend(records_for(1, &[0.5, 0.6]));
        let rview = view_of(&rerun, None).unwrap();
        assert_eq!(rview.entries[0].id(), rview.entries[1].id());
        let sel = format!("{}@1", rview.entries[0].id());
        assert_eq!(find(&rview, &sel).unwrap().seq(), 2);
        // bare id → latest occurrence
        assert_eq!(find(&rview, rview.entries[0].id()).unwrap().seq(), 2);

        let hist = render_history(&view, &HistoryFilter::default());
        assert!(hist.contains(&id1));
        assert!(hist.contains("bench [train]"));
        // filter by seed keeps exactly one run and hides bench rows
        let hist =
            render_history(&view, &HistoryFilter { seed: Some(2), ..Default::default() });
        assert!(!hist.contains(&id1));
        assert!(!hist.contains("bench"));
        assert!(hist.contains("0.7000"));
        // no match → explicit empty marker
        let hist = render_history(
            &view,
            &HistoryFilter { codec: Some("topk".into()), ..Default::default() },
        );
        assert!(hist.contains("no matching entries"));
    }

    #[test]
    fn query_renders_pricing_and_sim() {
        let view = view_of(&records_for(1, &[0.5, 0.6]), None).unwrap();
        let q = render_entry(find(&view, "1").unwrap());
        assert!(q.contains("final 0.6000"));
        assert!(q.contains("compression:"));
        assert!(q.contains("x vs dense fp32"));
        assert!(q.contains("rounds/virtual-hour"));
        assert!(q.contains("round,train_loss,test_acc"));
    }

    #[test]
    fn identical_runs_diff_to_zero_drift() {
        let mut recs = records_for(1, &[0.5, 0.6]);
        recs.extend(records_for(1, &[0.5, 0.6]));
        let view = view_of(&recs, None).unwrap();
        let d = diff(&view, "1", "2", &thresholds()).unwrap();
        assert!(d.breaches.is_empty(), "{:?}", d.breaches);
        assert!(d.text.contains("zero drift"));
        // a negative allowance turns even zero drift into a breach — the
        // CI lever for asserting the gate trips
        let strict =
            DiffThresholds { max_acc_drop: -0.01, ..thresholds() };
        let d = diff(&view, "1", "2", &strict).unwrap();
        assert!(!d.breaches.is_empty());
    }

    #[test]
    fn regressions_breach_their_thresholds() {
        let mut recs = records_for(1, &[0.5, 0.6]);
        recs.extend(records_for(2, &[0.4, 0.5]));
        let view = view_of(&recs, None).unwrap();
        // acc dropped 0.1 > 0.02 allowance
        let d = diff(&view, "1", "2", &thresholds()).unwrap();
        assert!(d.breaches.iter().any(|b| b.contains("final_acc")), "{:?}", d.breaches);

        // injected bench throughput regression: 1000 → 500 samples/sec
        let recs = vec![
            bench_record("train", &[("mlp/samples_per_sec".into(), 1000.0)]),
            bench_record("train", &[("mlp/samples_per_sec".into(), 500.0)]),
        ];
        let view = view_of(&recs, None).unwrap();
        let d = diff(&view, "1", "2", &thresholds()).unwrap();
        assert!(d.breaches.iter().any(|b| b.contains("samples_per_sec")), "{:?}", d.breaches);
        // and the reverse direction (speedup) passes the gate
        let d = diff(&view, "2", "1", &thresholds()).unwrap();
        assert!(d.breaches.is_empty(), "{:?}", d.breaches);
    }

    #[test]
    fn mixed_and_mismatched_diffs_error() {
        let mut recs = records_for(1, &[0.5]);
        recs.push(bench_record("train", &[("x".into(), 1.0)]));
        recs.push(bench_record("sim", &[("x".into(), 1.0)]));
        let view = view_of(&recs, None).unwrap();
        assert!(diff(&view, "1", "2", &thresholds()).is_err());
        assert!(diff(&view, "2", "3", &thresholds()).is_err());
        assert!(find(&view, "9").is_err());
        assert!(find(&view, "nope").is_err());
    }
}
