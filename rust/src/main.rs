//! `tfed` — launcher for the T-FedAvg federated learning system.
//!
//! Subcommands:
//!   run       run one experiment in-process (loopback transport), or a
//!             whole declarative scenario grid: `tfed run <manifest.toml>`
//!   serve     run the coordinator over TCP; waits for N `client` processes
//!   client    join a coordinator as one federated client
//!   inspect   print the artifact manifest the runtime will use
//!   selftest  PJRT smoke: load + execute every artifact kind once
//!   report    render paper-style tables/series from run artifacts
//!   history   list the runs recorded in a ledger (see --ledger-out)
//!   query     render one recorded run: metrics, wire totals, compression
//!   diff      compare two recorded runs/benches; nonzero exit on a
//!             threshold breach (CI perf gate)
//!
//! Examples:
//!   tfed run --protocol tfedavg --task mnist --rounds 30
//!   tfed run --protocol fedavg --task mnist --nc 2 --clients 10
//!   tfed run --codec stc:k=0.01 --rounds 30          # FedAvg + STC payloads
//!   tfed run --codec quant8 --rounds 30              # 8-bit stochastic quant
//!   tfed run --alpha 0.5 --rounds 30                 # Dirichlet label skew
//!   tfed run --task cifar --model cnn --native       # CNN on the cifar-like task
//!   tfed run ../examples/scenarios/paper_noniid.toml # declarative grid
//!   tfed run ../examples/scenarios/paper_noniid.toml --jobs 4   # parallel cells
//!   tfed run ../examples/scenarios/sim_fleet.toml    # 100k-client virtual-time sim
//!   tfed run --rounds 5 --trace-out trace.json --metrics-out metrics.prom  # profile
//!   tfed run --rounds 5 --telemetry-out telemetry.jsonl  # learning telemetry
//!   tfed run --rounds 30 --metrics-addr 127.0.0.1:9898   # watch the run live
//!   tfed serve --listen 127.0.0.1:7878 --clients 4 --native
//!   tfed client --connect 127.0.0.1:7878 --client-id 0
//!   tfed inspect
//!   tfed selftest
//!   tfed report results.json telemetry.jsonl
//!   tfed run scenario.toml --ledger-out runs.tfed  # record runs durably
//!   tfed history --ledger-out runs.tfed --codec ternary
//!   tfed query 3 --ledger-out runs.tfed
//!   tfed diff 1 3 --ledger-out runs.tfed --max-acc-drop 0.01

use std::io::Write;
use std::sync::Arc;

use anyhow::{bail, Result};

use tfed::compress::CodecSpec;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::availability::AvailabilityModel;
use tfed::coordinator::backend::{make_backend, make_backend_with_policy};
use tfed::coordinator::server::{materialize_shard, Orchestrator};
use tfed::coordinator::{
    AdversaryModel, AdversarySpec, AggregatorSpec, ClientAdversary, ClientRuntime,
};
use tfed::eval::{mb, RunMetrics};
use tfed::native::KernelPolicy;
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;
use tfed::transport::{TcpBinding, TcpClient};
use tfed::util::cli::{Args, Cli};

fn main() {
    if let Err(e) = real_main() {
        // --help surfaces as an "error" carrying the help text
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Cli::new("tfed — Ternary Compression for Communication-Efficient Federated Learning (TNNLS 2020 reproduction)")
        .opt("protocol", "tfedavg", "baseline | ttq | fedavg | tfedavg")
        .opt("codec", "auto", "ternary | dense | fp16 | quant<bits> | stc:k=<frac> | auto")
        .opt("task", "mnist", "mnist | cifar")
        .opt("model", "auto", "mlp | mlp-large | cnn | auto (task default; native registry)")
        .opt("clients", "10", "total clients N")
        .opt("participation", "1.0", "participation ratio lambda")
        .opt("nc", "10", "classes per client (10 = IID)")
        .opt("beta", "1.0", "unbalancedness ratio (eq. 29)")
        .opt("alpha", "0", "Dirichlet label-skew concentration (0 = use nc/beta)")
        .opt("batch", "64", "local batch size B")
        .opt("epochs", "5", "local epochs E")
        .opt("rounds", "30", "communication rounds")
        .opt("lr", "0", "learning rate (0 = task default)")
        .opt("seed", "42", "RNG seed")
        .opt("train-samples", "0", "train set size (0 = task default)")
        .opt("test-samples", "2000", "test set size")
        .opt("eval-every", "1", "evaluate every k rounds")
        .opt("dropout", "0.0", "client dropout probability (fault injection)")
        .opt("straggler-prob", "0.0", "per-client straggler probability")
        .opt("straggler-delay-ms", "0", "straggler reply delay in ms")
        .opt("aggregator", "mean", "mean | trimmed_mean[:beta] | median | norm_clip[:tau] | krum[:f]")
        .opt("adversary", "honest", "Byzantine cast: honest | scale:<f> | sign_flip | replay | corrupt_frame | wrong_codec | wrong_samples | oversize")
        .opt("adversary-fraction", "1.0", "fraction of registered clients cast as adversarial")
        .opt("adversary-seed", "0", "seed for the adversary casting generator")
        .opt("out", "", "write metrics JSON/CSV (scenario: results bundle) here")
        .opt("trace-out", "", "write a Chrome/Perfetto trace of the run's phases here")
        .opt("metrics-out", "", "write Prometheus-text metrics here at end of run")
        .opt("telemetry-out", "", "write per-round learning telemetry (JSONL) here")
        .opt("metrics-addr", "", "serve /metrics + /telemetry live on this address")
        .opt("metrics-hold-secs", "0", "keep the live endpoint up this long after the run")
        .opt("ledger-out", "", "append run records to this ledger; history/query/diff read it (default runs.tfed)")
        .opt("partition", "", "history: filter by partition name (iid | nc:2 | ...)")
        .opt("max-acc-drop", "0.02", "diff: max tolerated final-accuracy drop")
        .opt("max-mb-grow-pct", "10", "diff: max tolerated wire-MB growth, percent")
        .opt("max-perf-drop-pct", "20", "diff: max tolerated throughput drop, percent")
        .opt("listen", "127.0.0.1:7878", "serve: TCP listen address (port 0 = ephemeral)")
        .opt("connect", "", "client: coordinator address to dial")
        .opt("client-id", "0", "client: this process's client id")
        .opt("kernel", "auto", "native kernel tier: naive | blocked[:N] | packed[:N] | packed-naive | auto")
        .opt("workers", "0", "round-driver worker threads (0 = auto)")
        .opt("jobs", "1", "scenario runs: grid cells in flight (manifest only)")
        .flag("native", "use the pure-Rust layer-graph backend (registry models)")
        .flag("quiet", "suppress per-round logs")
        .parse_env()?;

    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("run");
    match cmd {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "inspect" => cmd_inspect(),
        "selftest" => cmd_selftest(),
        "report" => cmd_report(&args),
        "history" => cmd_history(&args),
        "query" => cmd_query(&args),
        "diff" => cmd_diff(&args),
        other => bail!(
            "unknown command {other:?} (run | serve | client | inspect | selftest | report | history | query | diff)"
        ),
    }
}

/// Assemble the experiment config from CLI knobs (shared by run + serve).
fn build_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut protocol = Protocol::parse(&args.get("protocol")?)?;
    let codec_arg = args.get("codec")?;
    let codec = if codec_arg == "auto" {
        None
    } else {
        Some(CodecSpec::parse(&codec_arg)?)
    };
    // `--codec quant8` alone means "FedAvg with quant8 payloads"; an
    // explicit --protocol always wins (and validate() rejects impossible
    // pairings like tfedavg+fp16)
    if let Some(spec) = codec {
        if !args.is_set("protocol") {
            protocol = Protocol::for_codec(spec);
        }
    }
    let task = Task::parse(&args.get("task")?)?;
    let mut cfg = ExperimentConfig::table2(protocol, task, args.get_u64("seed")?);
    if let Some(spec) = codec {
        cfg.codec = spec;
    }
    let model = args.get("model")?;
    if model != "auto" {
        cfg.model = model;
    }
    if !protocol.is_centralized() {
        cfg.n_clients = args.get_usize("clients")?;
        cfg.participation = args.get_f64("participation")?;
        cfg.nc = args.get_usize("nc")?;
        cfg.beta = args.get_f64("beta")?;
        cfg.dirichlet_alpha = args.get_f64("alpha")?;
    }
    cfg.aggregator = AggregatorSpec::parse(&args.get("aggregator")?)?;
    cfg.adversary = AdversarySpec::parse(
        &args.get("adversary")?,
        args.get_f64("adversary-fraction")?,
        args.get_u64("adversary-seed")?,
    )
    .map_err(|e| anyhow::anyhow!("invalid --adversary: {e}"))?;
    cfg.batch = args.get_usize("batch")?;
    cfg.local_epochs = args.get_usize("epochs")?;
    cfg.rounds = args.get_usize("rounds")?;
    cfg.eval_every = args.get_usize("eval-every")?;
    cfg.test_samples = args.get_usize("test-samples")?;
    let lr = args.get_f32("lr")?;
    if lr > 0.0 {
        cfg.lr = lr;
    }
    let ts = args.get_usize("train-samples")?;
    if ts > 0 {
        cfg.train_samples = ts;
    }
    cfg.native_backend = args.flag("native");
    cfg.validate()?;
    Ok(cfg)
}

/// The `--kernel` tier spec as an explicit native-kernel policy
/// (`auto` = None: keep the backend's env-derived default).
fn kernel_policy_from(args: &Args) -> Result<Option<KernelPolicy>> {
    let v = args.get("kernel")?;
    if v == "auto" {
        return Ok(None);
    }
    KernelPolicy::parse(&v)
        .map(Some)
        .map_err(|e| anyhow::anyhow!("invalid --kernel: {e}"))
}

fn apply_quiet(args: &Args) {
    if args.flag("quiet") {
        tfed::util::logging::set_level(tfed::util::logging::Level::Warn);
    }
}

/// The observability surface named on the CLI (empty string = not
/// requested). Naming any sink turns collection on for the run —
/// `--telemetry-out` / `--metrics-addr` additionally turn on per-round
/// learning telemetry; without them observability stays fully off (the
/// standing contract: identical outputs, no extra RNG draws, near-zero
/// overhead).
struct ObsCli {
    trace_out: Option<String>,
    metrics_out: Option<String>,
    telemetry_out: Option<String>,
    /// append the finished run to this cross-run ledger (needs no
    /// collection switches — it reads the run's metrics after the fact)
    ledger_out: Option<String>,
    /// live `/metrics` + `/telemetry` endpoint address
    metrics_addr: Option<String>,
    /// keep the endpoint alive this long after the run (for scrapes)
    hold_secs: u64,
}

impl ObsCli {
    fn parse(args: &Args) -> Result<ObsCli> {
        let opt = |name: &str| -> Result<Option<String>> {
            let v = args.get(name)?;
            Ok((!v.is_empty()).then_some(v))
        };
        Ok(ObsCli {
            trace_out: opt("trace-out")?,
            metrics_out: opt("metrics-out")?,
            telemetry_out: opt("telemetry-out")?,
            ledger_out: opt("ledger-out")?,
            metrics_addr: opt("metrics-addr")?,
            hold_secs: args.get_u64("metrics-hold-secs")?,
        })
    }

    /// Flip the process-wide collection switches this invocation needs.
    fn enable(&self) {
        if self.telemetry_out.is_some() || self.metrics_addr.is_some() {
            tfed::obs::enable_telemetry();
        } else if self.trace_out.is_some() || self.metrics_out.is_some() {
            tfed::obs::enable();
        }
    }

    /// Start the live endpoint when `--metrics-addr` was given. Prints a
    /// flushed `metrics endpoint on http://<addr>` line (launcher scripts
    /// parse it for the resolved port, like serve's "listening on" line).
    fn serve_endpoint(&self) -> Result<Option<tfed::obs::http::ObsServer>> {
        let Some(addr) = &self.metrics_addr else { return Ok(None) };
        let server = tfed::obs::http::serve(addr)?;
        println!("metrics endpoint on http://{}", server.addr());
        std::io::stdout().flush().ok();
        Ok(Some(server))
    }

    /// End-of-run: write the sinks (non-fatal), then hold the live
    /// endpoint open for late scrapes before shutting it down.
    fn finish(&self, quiet: bool, server: Option<tfed::obs::http::ObsServer>) {
        tfed::obs::finish(&tfed::obs::Sinks {
            trace_out: self.trace_out.as_deref(),
            metrics_out: self.metrics_out.as_deref(),
            telemetry_out: self.telemetry_out.as_deref(),
            quiet,
        });
        if let Some(server) = server {
            // flush the run summary before holding: scripts watch for it
            // to know the endpoint now serves final state
            std::io::stdout().flush().ok();
            if self.hold_secs > 0 {
                std::thread::sleep(std::time::Duration::from_secs(self.hold_secs));
            }
            server.shutdown();
        }
    }
}

fn engine_for(cfg: &ExperimentConfig) -> Result<Option<Arc<Engine>>> {
    if cfg.native_backend {
        Ok(None)
    } else {
        Ok(Some(Arc::new(Engine::load(default_artifacts_dir())?)))
    }
}

fn report(m: &RunMetrics, args: &Args) -> Result<()> {
    println!("== {} ==", m.config_summary);
    println!("final acc  : {:.4}", m.final_acc());
    println!("best acc   : {:.4}", m.best_acc());
    println!(
        "upstream   : {:.3} MB in {} frames",
        mb(m.total_up_bytes()),
        m.total_up_frames()
    );
    println!(
        "downstream : {:.3} MB in {} frames",
        mb(m.total_down_bytes()),
        m.total_down_frames()
    );
    println!("wall time  : {:.1} s", m.total_wall_secs());
    let out = args.get("out")?;
    if !out.is_empty() {
        m.write_json(format!("{out}.json"))?;
        m.write_csv(format!("{out}.csv"))?;
        println!("metrics    : {out}.json / {out}.csv");
    }
    Ok(())
}

/// The CLI's fault-injection knobs as a validated availability model.
fn availability_from(args: &Args) -> Result<AvailabilityModel> {
    Ok(AvailabilityModel::new(
        args.get_f64("dropout")?,
        Vec::new(),
        args.get_f64("straggler-prob")?,
        args.get_u64("straggler-delay-ms")?,
    )?)
}

/// Canonical partition name for a flag-driven config — the inverse of
/// `PartitionStrategy::apply`, so CLI runs land in the ledger with the
/// same partition identity a manifest cell would have.
fn partition_label(cfg: &ExperimentConfig) -> String {
    if cfg.dirichlet_alpha > 0.0 {
        format!("dirichlet:alpha={}", cfg.dirichlet_alpha)
    } else if cfg.beta != 1.0 {
        format!("beta:{}", cfg.beta)
    } else if cfg.nc != 10 {
        format!("nc:{}", cfg.nc)
    } else {
        "iid".into()
    }
}

/// Append a finished flag-driven run (`tfed run` / `tfed serve`) to the
/// ledger, labeled exactly like the equivalent scenario grid cell.
/// Best-effort like every obs sink: a failed append warns, never fails
/// the run that already finished.
fn append_run_ledger(path: &str, cfg: &ExperimentConfig, metrics: &RunMetrics) {
    let partition = partition_label(cfg);
    let codec = cfg.codec.name();
    let aggregator = cfg.aggregator.name();
    let mut label = format!("seed={} partition={partition} codec={codec}", cfg.seed);
    if !cfg.model.is_empty() {
        label.push_str(&format!(" model={}", cfg.model));
    }
    if aggregator != "mean" {
        label.push_str(&format!(" aggregator={aggregator}"));
    }
    let adversary = cfg.adversary.is_active().then(|| cfg.adversary.label());
    let info = tfed::obs::store::RunInfo {
        label: &label,
        seed: cfg.seed,
        partition: &partition,
        codec: &codec,
        protocol: cfg.protocol.name(),
        model: cfg.model_name(),
        aggregator: &aggregator,
        adversary: adversary.as_deref(),
        metrics,
        target_acc: None,
    };
    let append = || -> std::result::Result<(), tfed::obs::store::LedgerError> {
        let ledger = tfed::obs::store::Ledger::open(path)?;
        ledger.append(&tfed::obs::store::run_records(&info))
    };
    match append() {
        Ok(()) => println!("ledger     : {path}"),
        Err(e) => eprintln!("warning: ledger append to {path:?} failed: {e} (run results unaffected)"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    apply_quiet(args);
    // `tfed run <manifest.toml>` switches to the declarative scenario
    // engine; bare `tfed run` keeps the flag-driven single experiment
    if let Some(path) = args.positional().get(1) {
        return cmd_run_scenario(path, args);
    }
    if args.is_set("jobs") {
        bail!("--jobs parallelizes scenario grid cells; it needs a manifest run");
    }
    let obs = ObsCli::parse(args)?;
    obs.enable();
    let server = obs.serve_endpoint()?;
    let cfg = build_cfg(args)?;
    let engine = engine_for(&cfg)?;
    let backend = make_backend_with_policy(
        engine,
        cfg.model_name(),
        cfg.batch,
        cfg.native_backend,
        kernel_policy_from(args)?,
    )?;
    // the orchestrator takes the config by value; keep a copy only when
    // the ledger will need its identity after the run
    let ledger_cfg = obs.ledger_out.is_some().then(|| cfg.clone());
    let mut orch =
        Orchestrator::with_availability(cfg, backend.as_ref(), availability_from(args)?)?;
    let workers = args.get_usize("workers")?;
    if workers > 0 {
        orch.set_workers(workers);
    }
    orch.run()?;
    report(&orch.metrics, args)?;
    if let (Some(path), Some(cfg)) = (&obs.ledger_out, &ledger_cfg) {
        append_run_ledger(path, cfg, &orch.metrics);
    }
    obs.finish(args.flag("quiet"), server);
    Ok(())
}

/// Execute a whole manifest grid and print the per-cell summary table.
fn cmd_run_scenario(path: &str, args: &Args) -> Result<()> {
    // the manifest is the single source of truth for a grid: silently
    // ignoring `--rounds 2` next to a 30-round manifest would be a trap,
    // so every config-affecting flag is rejected (only --out, --jobs and
    // --quiet compose with a manifest)
    let config_opts = [
        "protocol", "codec", "task", "model", "clients", "participation", "nc", "beta",
        "alpha", "batch", "epochs", "rounds", "lr", "seed", "train-samples",
        "test-samples", "eval-every", "dropout", "straggler-prob", "straggler-delay-ms",
        "aggregator", "adversary", "adversary-fraction", "adversary-seed",
        "kernel", "workers", "listen", "connect", "client-id",
    ];
    let offending: Vec<&str> = config_opts
        .iter()
        .copied()
        .filter(|name| args.is_set(name))
        .chain(args.flag("native").then_some("native"))
        .collect();
    if !offending.is_empty() {
        bail!(
            "scenario manifests carry the whole experiment config; move {} into \
             {path:?} (its [experiment]/[fleet]/[availability]/[adversary]/[sim] tables) — only \
             --out, --jobs, --quiet, --trace-out, --metrics-out, --telemetry-out, \
             --ledger-out, --metrics-addr and --metrics-hold-secs combine with a manifest run",
            offending
                .iter()
                .map(|n| format!("--{n}"))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    let out = args.get("out")?;
    let out = if out.is_empty() { None } else { Some(out.as_str()) };
    let jobs = args.get_usize("jobs")?.max(1);
    let obs = ObsCli::parse(args)?;
    // the grid's sink resolution (CLI over [observability] table) lives in
    // run_manifest_file; the live endpoint is CLI-only and needs telemetry
    // on regardless of sinks
    if obs.metrics_addr.is_some() {
        tfed::obs::enable_telemetry();
    }
    let server = obs.serve_endpoint()?;
    let overrides = tfed::scenario::ObsOverrides {
        trace_out: obs.trace_out.clone(),
        metrics_out: obs.metrics_out.clone(),
        telemetry_out: obs.telemetry_out.clone(),
        ledger_out: obs.ledger_out.clone(),
        quiet: args.flag("quiet"),
    };
    let (results, written) = tfed::scenario::run_manifest_file(path, out, jobs, &overrides)?;
    println!("== scenario {} ({} cells) ==", results.name, results.cells.len());
    for c in &results.cells {
        let sim = match &c.sim {
            Some(s) => {
                let tta = match s.sim_secs_to_target {
                    Some(t) => format!(" tta={t:.0}s"),
                    None => String::new(),
                };
                format!(" vtime={:.0}s{tta}", s.total_sim_secs)
            }
            None => String::new(),
        };
        println!(
            "{:<55} final={:.4} best={:.4} up={:.3}MB down={:.3}MB{sim}",
            c.label,
            c.metrics.final_acc(),
            c.metrics.best_acc(),
            mb(c.metrics.total_up_bytes()),
            mb(c.metrics.total_down_bytes()),
        );
    }
    let accs = results.final_accs();
    println!(
        "final acc  : mean={:.4} std={:.4} min={:.4} max={:.4}",
        tfed::util::stats::mean(&accs),
        tfed::util::stats::std_dev(&accs),
        tfed::util::stats::min(&accs),
        tfed::util::stats::max(&accs),
    );
    if let Some(p) = written {
        println!("bundle     : {p}");
    }
    if let Some(server) = server {
        // flush the grid summary before holding: scripts watch for the
        // "bundle" line to know the endpoint now serves final state
        std::io::stdout().flush().ok();
        if obs.hold_secs > 0 {
            std::thread::sleep(std::time::Duration::from_secs(obs.hold_secs));
        }
        server.shutdown();
    }
    Ok(())
}

/// Run the coordinator over TCP: bind, wait for the fleet, drive rounds.
fn cmd_serve(args: &Args) -> Result<()> {
    apply_quiet(args);
    let obs = ObsCli::parse(args)?;
    obs.enable();
    let server = obs.serve_endpoint()?;
    let cfg = build_cfg(args)?;
    if cfg.protocol.is_centralized() {
        bail!("serve requires a federated protocol (fedavg | tfedavg)");
    }
    let engine = engine_for(&cfg)?;
    let backend = make_backend_with_policy(
        engine,
        cfg.model_name(),
        cfg.batch,
        cfg.native_backend,
        kernel_policy_from(args)?,
    )?;
    let binding = TcpBinding::bind(&args.get("listen")?)?;
    let addr = binding.local_addr()?;
    // flush before blocking: launcher scripts parse this line for the port
    println!("listening on {addr} — waiting for {} clients", cfg.n_clients);
    std::io::stdout().flush().ok();
    let transport = binding.accept_clients(cfg.n_clients, &cfg)?;
    let ledger_cfg = obs.ledger_out.is_some().then(|| cfg.clone());
    let mut orch = Orchestrator::with_transport(
        cfg,
        backend.as_ref(),
        availability_from(args)?,
        Box::new(transport),
    )?;
    let workers = args.get_usize("workers")?;
    if workers > 0 {
        orch.set_workers(workers);
    }
    let run_result = orch.run();
    // teardown failure must never mask the run's own error
    if let Err(e) = orch.shutdown_transport() {
        eprintln!("warning: shutdown notify failed: {e:#}");
    }
    run_result?;
    report(&orch.metrics, args)?;
    if let (Some(path), Some(cfg)) = (&obs.ledger_out, &ledger_cfg) {
        append_run_ledger(path, cfg, &orch.metrics);
    }
    obs.finish(args.flag("quiet"), server);
    Ok(())
}

/// Join a coordinator as one client: the experiment config (and thus the
/// local data shard) comes from the server; only model payloads cross the
/// wire after the handshake.
fn cmd_client(args: &Args) -> Result<()> {
    apply_quiet(args);
    let addr = args.get("connect")?;
    if addr.is_empty() {
        bail!("client requires --connect <host:port>");
    }
    let client_id = args.get_usize("client-id")? as u32;
    let (mut client, cfg) = TcpClient::connect(&addr, client_id)?;
    cfg.validate()?;
    if client_id as usize >= cfg.n_clients {
        bail!("client id {client_id} out of range for {} clients", cfg.n_clients);
    }
    println!("client {client_id}: joined [{}]", cfg.summary());
    let engine = engine_for(&cfg)?;
    let backend = make_backend_with_policy(
        engine,
        cfg.model_name(),
        cfg.batch,
        cfg.native_backend,
        kernel_policy_from(args)?,
    )?;
    let shard = materialize_shard(&cfg, backend.schema().input_dim, client_id as usize)?;
    // the adversary cast is derived from the wire-delivered config, so a
    // remote client acts out exactly the role the coordinator assigned it
    let cast = AdversaryModel::new(cfg.adversary)?;
    let runtime = ClientRuntime {
        client_id,
        backend: backend.as_ref(),
        shard,
        local_epochs: cfg.local_epochs,
        lr: cfg.lr,
        codec: cfg.codec,
        adversary: ClientAdversary::from_model(cast),
    };
    let rounds = client.serve(&runtime)?;
    println!(
        "client {client_id}: served {rounds} rounds — up {} B, down {} B, ctrl {} B",
        client.stats.up_bytes, client.stats.down_bytes, client.stats.ctrl_bytes
    );
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let manifest = tfed::runtime::Manifest::load(default_artifacts_dir())?;
    println!("artifacts dir : {:?}", manifest.dir);
    println!("T_k = {}  server Delta = {}  wq_grad = {}  wq_init = {}",
        manifest.t_k, manifest.server_delta, manifest.wq_grad, manifest.wq_init);
    for (name, entry) in &manifest.models {
        println!(
            "model {name}: {} params ({} quantized layers), optimizer {}, lr {}",
            entry.schema.param_count(),
            entry.num_quantized,
            entry.schema.optimizer,
            entry.schema.default_lr
        );
    }
    println!("{:<42} {:>6} {:>5} {:>4} {:>7} {:>8}", "artifact", "kind", "B", "NB", "inputs", "outputs");
    for (name, a) in &manifest.artifacts {
        println!(
            "{:<42} {:>6} {:>5} {:>4} {:>7} {:>8}",
            name, a.kind, a.batch, a.nb, a.inputs.len(), a.outputs.len()
        );
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    use tfed::coordinator::run_experiment;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);
    println!("PJRT platform OK; {} artifacts", engine.manifest.artifacts.len());
    for task in [Task::MnistLike, Task::CifarLike] {
        for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
            let mut cfg = ExperimentConfig::table2(protocol, task, 1);
            cfg.n_clients = 2;
            cfg.rounds = 1;
            cfg.local_epochs = 1;
            cfg.train_samples = 200;
            cfg.test_samples = 100;
            cfg.batch = 16;
            let backend =
                make_backend(Some(engine.clone()), task.model_name(), cfg.batch, false)?;
            let m = run_experiment(cfg, backend.as_ref())?;
            println!(
                "{:<10} {:<12} 1 round OK (loss {:.3}, acc {:.3})",
                protocol.name(),
                task.name(),
                m.records[0].train_loss,
                m.records[0].test_acc
            );
        }
    }
    println!("selftest OK");
    Ok(())
}

/// Render paper-style reports offline from run artifacts — results
/// bundles and telemetry JSONL sinks, auto-detected per file.
fn cmd_report(args: &Args) -> Result<()> {
    let files = &args.positional()[1..];
    if files.is_empty() {
        bail!("report needs artifacts: tfed report <bundle.json|telemetry.jsonl> ...");
    }
    for (i, file) in files.iter().enumerate() {
        if i > 0 {
            println!();
        }
        print!("{}", tfed::obs::report::render_file(file)?);
    }
    Ok(())
}

/// The ledger path the read-side subcommands operate on: `--ledger-out`
/// if given, the default `runs.tfed` otherwise.
fn ledger_path(args: &Args) -> Result<String> {
    let p = args.get("ledger-out")?;
    Ok(if p.is_empty() { "runs.tfed".into() } else { p })
}

/// List the runs (and bench records) in a ledger, newest last.
fn cmd_history(args: &Args) -> Result<()> {
    let view = tfed::obs::lens::load(&ledger_path(args)?)?;
    // filters apply only when named explicitly — the run/serve defaults
    // ("auto", "mean", ...) must not silently hide history
    let sel = |name: &str| -> Result<Option<String>> {
        Ok(if args.is_set(name) { Some(args.get(name)?) } else { None })
    };
    let filter = tfed::obs::lens::HistoryFilter {
        model: sel("model")?,
        codec: sel("codec")?,
        aggregator: sel("aggregator")?,
        partition: sel("partition")?,
        seed: args.is_set("seed").then(|| args.get_u64("seed")).transpose()?,
    };
    print!("{}", tfed::obs::lens::render_history(&view, &filter));
    Ok(())
}

/// Render one recorded run in full.
fn cmd_query(args: &Args) -> Result<()> {
    let Some(sel) = args.positional().get(1) else {
        bail!("query needs a run selector: tfed query <seq|id|id@k> [--ledger-out <path>]");
    };
    let view = tfed::obs::lens::load(&ledger_path(args)?)?;
    print!("{}", tfed::obs::lens::render_entry(tfed::obs::lens::find(&view, sel)?));
    if let Some(d) = &view.damage {
        eprintln!("warning: {d}");
    }
    Ok(())
}

/// Compare two recorded runs (or bench records). Exits nonzero when any
/// regression threshold is breached — the CI perf gate.
fn cmd_diff(args: &Args) -> Result<()> {
    let (Some(a), Some(b)) = (args.positional().get(1), args.positional().get(2)) else {
        bail!("diff needs two selectors: tfed diff <a> <b> [--ledger-out <path>]");
    };
    let view = tfed::obs::lens::load(&ledger_path(args)?)?;
    let thresholds = tfed::obs::lens::DiffThresholds {
        max_acc_drop: args.get_f64("max-acc-drop")?,
        max_mb_grow_pct: args.get_f64("max-mb-grow-pct")?,
        max_perf_drop_pct: args.get_f64("max-perf-drop-pct")?,
    };
    let d = tfed::obs::lens::diff(&view, a, b, &thresholds)?;
    print!("{}", d.text);
    if !d.breaches.is_empty() {
        bail!(
            "perf gate: {} threshold breach(es):\n  {}",
            d.breaches.len(),
            d.breaches.join("\n  ")
        );
    }
    println!("perf gate: OK");
    Ok(())
}
