//! Naive-but-correct MLP trainer matching python/compile (models.py +
//! train.py + fttq.py) for the `mlp` schema: 784-30-20-10, ReLU,
//! masked softmax-CE, SGD, optional FTTQ quantization-aware forward with
//! the paper's STE gradients.

use anyhow::{bail, Result};

use crate::model::{ModelSchema, ParamSet};
use crate::quant;

/// Which training math to run (mirrors the artifact "mode").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    Fp,
    Fttq,
}

/// Dimensions of one dense layer.
#[derive(Clone, Copy, Debug)]
struct LayerDims {
    inp: usize,
    out: usize,
}

/// Pure-Rust MLP trainer over a ParamSet laid out as [w1,b1,w2,b2,w3,b3].
pub struct NativeMlp {
    layers: Vec<LayerDims>,
    t_k: f32,
    mode: Mode,
}

impl NativeMlp {
    pub fn from_schema(schema: &ModelSchema, mode: Mode, t_k: f32) -> Result<Self> {
        if schema.params.len() % 2 != 0 {
            bail!("expected (w, b) pairs");
        }
        let mut layers = Vec::new();
        for pair in schema.params.chunks(2) {
            let w = &pair[0];
            if w.shape.len() != 2 {
                bail!("native backend only supports dense layers, got {:?}", w.shape);
            }
            layers.push(LayerDims { inp: w.shape[0], out: w.shape[1] });
        }
        Ok(NativeMlp { layers, t_k, mode })
    }

    fn check(&self, params: &ParamSet) -> Result<()> {
        if params.tensors.len() != self.layers.len() * 2 {
            bail!("param count mismatch");
        }
        Ok(())
    }

    /// Forward pass -> logits [n, classes]. In Fttq mode the weights are
    /// ternarized with the paper's pipeline first (wq per layer).
    pub fn forward(&self, params: &ParamSet, wq: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        let mut act = x.to_vec();
        let mut cur = self.layers[0].inp;
        for (li, dims) in self.layers.iter().enumerate() {
            let w = &params.tensors[li * 2].data;
            let b = &params.tensors[li * 2 + 1].data;
            let w_eff: Vec<f32> = match self.mode {
                Mode::Fp => w.clone(),
                Mode::Fttq => {
                    let (it, _) = quant::fttq_quantize(w, self.t_k);
                    quant::dequantize(&it, wq[li])
                }
            };
            let mut next = vec![0f32; n * dims.out];
            matmul_bias(&act, &w_eff, b, &mut next, n, cur, dims.out);
            if li + 1 < self.layers.len() {
                for v in &mut next {
                    *v = v.max(0.0);
                }
            }
            act = next;
            cur = dims.out;
        }
        act
    }

    /// (mean masked CE loss, accuracy) without updating anything.
    pub fn evaluate(
        &self,
        params: &ParamSet,
        wq: &[f32],
        x: &[f32],
        y: &[u32],
        n: usize,
    ) -> (f32, f32) {
        let classes = self.layers.last().unwrap().out;
        let logits = self.forward(params, wq, x, n);
        let mut loss = 0f64;
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits[i * classes..(i + 1) * classes];
            let (lse, argmax) = log_sum_exp(row);
            loss += (lse - row[y[i] as usize]) as f64;
            if argmax == y[i] as usize {
                correct += 1;
            }
        }
        ((loss / n as f64) as f32, correct as f32 / n as f32)
    }

    /// One SGD step over a batch; updates params (and wq in Fttq mode)
    /// in place. Returns the batch mean loss.
    pub fn train_batch(
        &self,
        params: &mut ParamSet,
        wq: &mut [f32],
        x: &[f32],
        y: &[u32],
        n: usize,
        lr: f32,
    ) -> Result<f32> {
        self.check(params)?;
        let l = self.layers.len();
        let classes = self.layers[l - 1].out;

        // ---- forward, keeping activations + ternary patterns ----
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut terns: Vec<Option<(Vec<i8>, Vec<f32>)>> = Vec::with_capacity(l);
        let mut cur = self.layers[0].inp;
        for (li, dims) in self.layers.iter().enumerate() {
            let w = &params.tensors[li * 2].data;
            let b = &params.tensors[li * 2 + 1].data;
            let w_eff: Vec<f32> = match self.mode {
                Mode::Fp => {
                    terns.push(None);
                    w.clone()
                }
                Mode::Fttq => {
                    let (it, _) = quant::fttq_quantize(w, self.t_k);
                    let dense = quant::dequantize(&it, wq[li]);
                    terns.push(Some((it, dense.clone())));
                    dense
                }
            };
            let mut next = vec![0f32; n * dims.out];
            matmul_bias(&acts[li], &w_eff, b, &mut next, n, cur, dims.out);
            if li + 1 < l {
                for v in &mut next {
                    *v = v.max(0.0);
                }
            }
            acts.push(next);
            cur = dims.out;
        }

        // ---- loss + dlogits ----
        let logits = &acts[l];
        let mut dlogits = vec![0f32; n * classes];
        let mut loss = 0f64;
        for i in 0..n {
            let row = &logits[i * classes..(i + 1) * classes];
            let (lse, _) = log_sum_exp(row);
            loss += (lse - row[y[i] as usize]) as f64;
            for c in 0..classes {
                let p = (row[c] - lse).exp();
                dlogits[i * classes + c] =
                    (p - f32::from(c == y[i] as usize)) / n as f32;
            }
        }

        // ---- backward ----
        let mut dact = dlogits;
        for li in (0..l).rev() {
            let dims = self.layers[li];
            let a_in = &acts[li];
            // grads of effective (possibly ternary) weights
            let mut dw = vec![0f32; dims.inp * dims.out];
            let mut db = vec![0f32; dims.out];
            // dw = a_in^T @ dact ; db = colsum(dact)
            for i in 0..n {
                for o in 0..dims.out {
                    let g = dact[i * dims.out + o];
                    if g == 0.0 {
                        continue;
                    }
                    db[o] += g;
                    let row = &a_in[i * dims.inp..(i + 1) * dims.inp];
                    for (k, &aik) in row.iter().enumerate() {
                        dw[k * dims.out + o] += aik * g;
                    }
                }
            }
            // dact_prev = dact @ w_eff^T, with ReLU mask
            if li > 0 {
                let w_eff: Vec<f32> = match &terns[li] {
                    None => params.tensors[li * 2].data.clone(),
                    Some((_, dense)) => dense.clone(),
                };
                let mut dprev = vec![0f32; n * dims.inp];
                for i in 0..n {
                    for k in 0..dims.inp {
                        let mut s = 0f32;
                        let wrow = &w_eff[k * dims.out..(k + 1) * dims.out];
                        let grow = &dact[i * dims.out..(i + 1) * dims.out];
                        for (wv, gv) in wrow.iter().zip(grow) {
                            s += wv * gv;
                        }
                        // ReLU mask of the input activation
                        if acts[li][i * dims.inp + k] <= 0.0 {
                            s = 0.0;
                        }
                        dprev[i * dims.inp + k] = s;
                    }
                }
                dact = dprev;
            }

            // ---- apply updates (paper Algorithm 1 STE rules) ----
            match (&self.mode, &terns[li]) {
                (Mode::Fp, _) => {
                    let w = &mut params.tensors[li * 2].data;
                    for (wv, g) in w.iter_mut().zip(&dw) {
                        *wv -= lr * g;
                    }
                }
                (Mode::Fttq, Some((it, _))) => {
                    // dJ/dwq = mean over I_p of dJ/dtheta_t — Algorithm 1's
                    // sum, support-mean normalized exactly like fttq.py
                    // (see DESIGN.md §7: the raw sum diverges at layer scale)
                    let mut g_wq = 0f32;
                    let mut n_pos = 0usize;
                    for (s, g) in it.iter().zip(&dw) {
                        if *s > 0 {
                            g_wq += g;
                            n_pos += 1;
                        }
                    }
                    g_wq /= n_pos.max(1) as f32;
                    // latent grads: wq*g on support, g on zeros
                    let w = &mut params.tensors[li * 2].data;
                    for ((wv, g), s) in w.iter_mut().zip(&dw).zip(it) {
                        let scale = if *s != 0 { wq[li] } else { 1.0 };
                        *wv -= lr * scale * g;
                    }
                    wq[li] -= lr * g_wq;
                }
                (Mode::Fttq, None) => unreachable!(),
            }
            let b = &mut params.tensors[li * 2 + 1].data;
            for (bv, g) in b.iter_mut().zip(&db) {
                *bv -= lr * g;
            }
        }
        Ok((loss / n as f64) as f32)
    }
}

/// out[n, o] = x[n, i] @ w[i, o] + b[o]
fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], n: usize, i: usize, o: usize) {
    for r in 0..n {
        let xrow = &x[r * i..(r + 1) * i];
        let orow = &mut out[r * o..(r + 1) * o];
        orow.copy_from_slice(b);
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * o..(k + 1) * o];
            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
}

fn log_sum_exp(row: &[f32]) -> (f32, usize) {
    let mut m = f32::NEG_INFINITY;
    let mut arg = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > m {
            m = v;
            arg = i;
        }
    }
    let s: f32 = row.iter().map(|&v| (v - m).exp()).sum();
    (m + s.ln(), arg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelSchema, ParamSpec};
    use crate::util::rng::Pcg;

    fn small_schema() -> ModelSchema {
        ModelSchema {
            name: "small".into(),
            input_dim: 10,
            num_classes: 4,
            optimizer: "sgd".into(),
            default_lr: 0.1,
            params: vec![
                ParamSpec { name: "w1".into(), shape: vec![10, 8], quantized: true },
                ParamSpec { name: "b1".into(), shape: vec![8], quantized: false },
                ParamSpec { name: "w2".into(), shape: vec![8, 4], quantized: true },
                ParamSpec { name: "b2".into(), shape: vec![4], quantized: false },
            ],
        }
    }

    fn toy_batch(rng: &mut Pcg, n: usize, d: usize, classes: usize) -> (Vec<f32>, Vec<u32>) {
        // labels linearly derivable from inputs -> learnable
        let w_true: Vec<f32> = (0..d * classes).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let mut best = (f32::NEG_INFINITY, 0u32);
            for c in 0..classes {
                let mut s = 0f32;
                for k in 0..d {
                    s += x[i * d + k] * w_true[k * classes + c];
                }
                if s > best.0 {
                    best = (s, c as u32);
                }
            }
            y.push(best.1);
        }
        (x, y)
    }

    #[test]
    fn fp_training_learns() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(1);
        let mut params = init_params(&schema, &mut rng);
        let net = NativeMlp::from_schema(&schema, Mode::Fp, 0.05).unwrap();
        let (x, y) = toy_batch(&mut rng, 128, 10, 4);
        let (loss0, acc0) = net.evaluate(&params, &[], &x, &y, 128);
        for _ in 0..60 {
            net.train_batch(&mut params, &mut [], &x, &y, 128, 0.5).unwrap();
        }
        let (loss1, acc1) = net.evaluate(&params, &[], &x, &y, 128);
        assert!(loss1 < loss0 * 0.7, "loss {loss0} -> {loss1}");
        assert!(acc1 > acc0.max(0.5), "acc {acc0} -> {acc1}");
    }

    #[test]
    fn fttq_training_learns_and_wq_moves() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(2);
        let mut params = init_params(&schema, &mut rng);
        let mut wq = vec![0.05f32, 0.05];
        let net = NativeMlp::from_schema(&schema, Mode::Fttq, 0.05).unwrap();
        let (x, y) = toy_batch(&mut rng, 128, 10, 4);
        let (loss0, acc0) = net.evaluate(&params, &wq, &x, &y, 128);
        for _ in 0..250 {
            net.train_batch(&mut params, &mut wq, &x, &y, 128, 0.2).unwrap();
        }
        let (loss1, acc1) = net.evaluate(&params, &wq, &x, &y, 128);
        assert!(loss1 < loss0, "loss {loss0} -> {loss1}");
        // a ternary 10-8-4 net has little capacity; beating the initial
        // accuracy and chance (0.25) is the meaningful bar here
        assert!(acc1 > acc0.max(0.3), "acc {acc0} -> {acc1}");
        assert!(wq.iter().any(|&w| (w - 0.05).abs() > 1e-4), "{wq:?}");
        assert!(wq.iter().all(|w| w.is_finite()));
    }

    #[test]
    fn fttq_forward_uses_ternary_weights() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(3);
        let params = init_params(&schema, &mut rng);
        let net = NativeMlp::from_schema(&schema, Mode::Fttq, 0.05).unwrap();
        let x = vec![1.0f32; 10];
        let wq = vec![0.5, 0.5];
        let out = net.forward(&params, &wq, &x, 1);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gradcheck_fp_weights() {
        // finite-difference check of dL/dw on a tiny net
        let schema = small_schema();
        let mut rng = Pcg::seeded(4);
        let params0 = init_params(&schema, &mut rng);
        let net = NativeMlp::from_schema(&schema, Mode::Fp, 0.05).unwrap();
        let (x, y) = toy_batch(&mut rng, 8, 10, 4);

        // analytic step with tiny lr approximates -lr * grad
        let lr = 1e-3f32;
        let mut p_stepped = params0.clone();
        net.train_batch(&mut p_stepped, &mut [], &x, &y, 8, lr).unwrap();

        let loss_at = |p: &ParamSet| net.evaluate(p, &[], &x, &y, 8).0;
        // numeric gradient for a handful of coordinates
        for (ti, ci) in [(0usize, 0usize), (0, 17), (2, 5), (1, 2), (3, 1)] {
            let eps = 1e-3f32;
            let mut pp = params0.clone();
            pp.tensors[ti].data[ci] += eps;
            let mut pm = params0.clone();
            pm.tensors[ti].data[ci] -= eps;
            let g_num = (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps);
            let g_ana = (params0.tensors[ti].data[ci] - p_stepped.tensors[ti].data[ci]) / lr;
            assert!(
                (g_num - g_ana).abs() < 2e-2 + 0.15 * g_num.abs(),
                "tensor {ti}[{ci}]: num {g_num} vs ana {g_ana}"
            );
        }
    }

    #[test]
    fn eval_counts_match_manual() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(5);
        let params = init_params(&schema, &mut rng);
        let net = NativeMlp::from_schema(&schema, Mode::Fp, 0.05).unwrap();
        let (x, y) = toy_batch(&mut rng, 16, 10, 4);
        let (loss, acc) = net.evaluate(&params, &[], &x, &y, 16);
        assert!(loss > 0.0 && (0.0..=1.0).contains(&acc));
    }
}
