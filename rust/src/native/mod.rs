//! Pure-Rust layer-graph training core.
//!
//! Exists for four reasons:
//!   1. cross-validation — the same math as the L2 JAX graphs, so the
//!      integration tests can check the HLO artifacts end-to-end;
//!   2. fast property tests over the coordinator (no PJRT compile cost);
//!   3. the compute hot path for every scenario-grid and sim-fleet run
//!      in the artifact-less (offline) build;
//!   4. the baseline for the §Perf comparison (`BENCH_train.json`).
//!
//! Structure (DESIGN.md §10):
//!   * [`kernels`] — deterministic cache-blocked, row-parallel GEMM /
//!     gradient kernels (reductions never partitioned: bit-identical to
//!     the naive reference loops at any thread count);
//!   * [`layers`] — the composable `Layer` graph (Dense / ReLU / Conv2d /
//!     AvgPool2 / Flatten) with per-layer FTTQ/TTQ through `QuantSlot`s.
//!
//! The seed's monolithic `NativeMlp` is gone; `tests/native_equiv.rs`
//! keeps it verbatim as the bit-identity reference for the `mlp` schema.
//! Models come from the string-keyed registry
//! ([`crate::model::registry`]): `mlp` (the paper's 784-30-20-10),
//! `mlp-large`, and a CIFAR-shaped `cnn`.

pub mod kernels;
pub mod layers;

pub use kernels::{KernelPolicy, PackedWeights};
pub use layers::{Layer, LayerGraph, Mode, QuantSlot, QuantSpec, TrainCache};
