//! Pure-Rust reference backend: MLP forward/backward + SGD + FTTQ.
//!
//! Exists for three reasons:
//!   1. cross-validation — the same math as the L2 JAX graphs, so the
//!      integration tests can check the HLO artifacts end-to-end;
//!   2. fast property tests over the coordinator (no PJRT compile cost);
//!   3. a baseline for the §Perf comparison (XLA hot path vs naive Rust).
//!
//! Only the MLP is implemented natively (the CNN exists solely as an HLO
//! artifact); the coordinator is generic over `LocalBackend`.

pub mod mlp;

pub use mlp::NativeMlp;
