//! Convolution, pooling, and flatten layers (NHWC, the synth dataset's
//! native pixel layout).
//!
//! Conv2d lowers to the shared GEMM kernels through im2col: the batch's
//! patch matrix `[n*h*w, kh*kw*cin]` turns forward into `col @ W`, the
//! weight gradient into `col^T @ dy`, and the input gradient into a
//! `dy @ W^T` followed by a col2im scatter-add — so the determinism
//! contract (reductions never partitioned) is inherited from
//! [`crate::native::kernels`], and the scatter-add itself runs in one
//! fixed patch order.

use crate::model::ParamSet;
use crate::native::kernels::{self, KernelPolicy};
use crate::native::layers::{
    apply_sgd, packed_scales, quantize_weights, Layer, QuantSlot, QuantSpec, TrainCache,
};

/// Stride-1, zero-padded "same" 2-D convolution over `[h, w, cin]` NHWC
/// input; weights `[kh, kw, cin, cout]` row-major (so the flattened
/// matrix is `[kh*kw*cin, cout]`), bias `[cout]`. Kernel dims odd.
pub struct Conv2d {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub weight: usize,
    pub bias: usize,
    pub quant: Option<QuantSlot>,
}

impl Conv2d {
    fn kdim(&self) -> usize {
        self.kh * self.kw * self.cin
    }
}

impl Layer for Conv2d {
    fn name(&self) -> &'static str {
        "conv2d"
    }

    fn in_len(&self) -> usize {
        self.h * self.w * self.cin
    }

    fn out_len(&self) -> usize {
        self.h * self.w * self.cout
    }

    fn param_indices(&self) -> Vec<usize> {
        vec![self.weight, self.bias]
    }

    fn quant_slot(&self) -> Option<QuantSlot> {
        self.quant
    }

    fn forward(
        &self,
        params: &ParamSet,
        q: QuantSpec,
        factors: &[f32],
        x: &[f32],
        n: usize,
        kp: &KernelPolicy,
    ) -> (Vec<f32>, TrainCache) {
        let w = &params.tensors[self.weight].data;
        let b = &params.tensors[self.bias].data;
        let quant_cache = quantize_weights(w, self.quant, q, factors, kp, self.kdim(), self.cout);
        let col = im2col(x, n, self.h, self.w, self.cin, self.kh, self.kw);
        let rows = n * self.h * self.w;
        let mut out = vec![0f32; rows * self.cout];
        if let Some(pw) = &quant_cache.packed {
            // packed tier: the lowered GEMM runs on the 2-bit cells
            let (ps, ns) = packed_scales(self.quant.unwrap(), q, factors);
            kernels::packed_gemm_bias(&col, pw, b, ps, ns, &mut out, rows, kp);
        } else {
            let w_eff: &[f32] = if quant_cache.w_eff.is_empty() { w } else { &quant_cache.w_eff };
            kernels::gemm_bias(&col, w_eff, b, &mut out, rows, self.kdim(), self.cout, kp);
        }
        (out, TrainCache { col, ..quant_cache })
    }

    fn backward(
        &self,
        params: &mut ParamSet,
        q: QuantSpec,
        factors: &mut [f32],
        cache: &mut TrainCache,
        _x: &[f32],
        dy: &[f32],
        n: usize,
        lr: f32,
        need_dx: bool,
        kp: &KernelPolicy,
    ) -> Vec<f32> {
        let rows = n * self.h * self.w;
        let kdim = self.kdim();
        let mut dw = vec![0f32; kdim * self.cout];
        let mut db = vec![0f32; self.cout];
        kernels::grad_weights(
            &cache.col,
            dy,
            &mut dw,
            &mut db,
            rows,
            kdim,
            self.cout,
            kp,
            &mut cache.scratch,
        );
        let dx = if need_dx {
            let mut dcol = vec![0f32; rows * kdim];
            if let Some(pw) = &cache.packed {
                let (ps, ns) = packed_scales(self.quant.unwrap(), q, factors);
                kernels::packed_grad_input(dy, pw, ps, ns, &mut dcol, rows, kp);
            } else {
                let w_eff: &[f32] = if cache.w_eff.is_empty() {
                    &params.tensors[self.weight].data
                } else {
                    &cache.w_eff
                };
                kernels::grad_input(
                    dy,
                    w_eff,
                    &mut dcol,
                    rows,
                    kdim,
                    self.cout,
                    kp,
                    &mut cache.scratch,
                );
            }
            col2im(&dcol, n, self.h, self.w, self.cin, self.kh, self.kw)
        } else {
            Vec::new()
        };
        apply_sgd(params, self.weight, self.bias, self.quant, q, factors, cache, &dw, &db, lr);
        dx
    }
}

/// Lower an NHWC batch into its patch matrix: row `(s, oy, ox)` holds the
/// zero-padded `kh x kw x cin` receptive field in `(ky, kx, c)` order —
/// matching the `[kh, kw, cin, cout]` weight layout.
pub(crate) fn im2col(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let kdim = kh * kw * cin;
    let (ph, pw) = (kh / 2, kw / 2);
    let mut col = vec![0f32; n * h * w * kdim];
    let mut row = 0usize;
    for s in 0..n {
        let img = &x[s * h * w * cin..(s + 1) * h * w * cin];
        for oy in 0..h {
            for ox in 0..w {
                let dst = &mut col[row * kdim..(row + 1) * kdim];
                let mut idx = 0usize;
                for ky in 0..kh {
                    let iy = oy + ky;
                    if iy < ph || iy >= h + ph {
                        idx += kw * cin; // zero padding rows stay zero
                        continue;
                    }
                    let iy = iy - ph;
                    for kx in 0..kw {
                        let ix = ox + kx;
                        if ix < pw || ix >= w + pw {
                            idx += cin;
                            continue;
                        }
                        let ix = ix - pw;
                        let src = (iy * w + ix) * cin;
                        dst[idx..idx + cin].copy_from_slice(&img[src..src + cin]);
                        idx += cin;
                    }
                }
                row += 1;
            }
        }
    }
    col
}

/// Adjoint of [`im2col`]: scatter-add patch gradients back onto the NHWC
/// input grid (padding positions drop out). One fixed patch order —
/// deterministic by construction.
pub(crate) fn col2im(
    dcol: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let kdim = kh * kw * cin;
    let (ph, pw) = (kh / 2, kw / 2);
    let mut dx = vec![0f32; n * h * w * cin];
    let mut row = 0usize;
    for s in 0..n {
        let img = &mut dx[s * h * w * cin..(s + 1) * h * w * cin];
        for oy in 0..h {
            for ox in 0..w {
                let src = &dcol[row * kdim..(row + 1) * kdim];
                let mut idx = 0usize;
                for ky in 0..kh {
                    let iy = oy + ky;
                    if iy < ph || iy >= h + ph {
                        idx += kw * cin;
                        continue;
                    }
                    let iy = iy - ph;
                    for kx in 0..kw {
                        let ix = ox + kx;
                        if ix < pw || ix >= w + pw {
                            idx += cin;
                            continue;
                        }
                        let ix = ix - pw;
                        let d = (iy * w + ix) * cin;
                        for c in 0..cin {
                            img[d + c] += src[idx + c];
                        }
                        idx += cin;
                    }
                }
                row += 1;
            }
        }
    }
    dx
}

/// 2x2 average pooling, stride 2, over `[h, w, c]` NHWC (h, w even).
pub struct AvgPool2 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Layer for AvgPool2 {
    fn name(&self) -> &'static str {
        "avgpool2"
    }

    fn in_len(&self) -> usize {
        self.h * self.w * self.c
    }

    fn out_len(&self) -> usize {
        (self.h / 2) * (self.w / 2) * self.c
    }

    fn param_indices(&self) -> Vec<usize> {
        Vec::new()
    }

    fn quant_slot(&self) -> Option<QuantSlot> {
        None
    }

    fn forward(
        &self,
        _params: &ParamSet,
        _q: QuantSpec,
        _factors: &[f32],
        x: &[f32],
        n: usize,
        _kp: &KernelPolicy,
    ) -> (Vec<f32>, TrainCache) {
        let (h, w, c) = (self.h, self.w, self.c);
        let (oh, ow) = (h / 2, w / 2);
        let mut out = vec![0f32; n * oh * ow * c];
        for s in 0..n {
            let img = &x[s * h * w * c..(s + 1) * h * w * c];
            let dst = &mut out[s * oh * ow * c..(s + 1) * oh * ow * c];
            for oy in 0..oh {
                for ox in 0..ow {
                    let (y0, x0) = (2 * oy, 2 * ox);
                    for cc in 0..c {
                        let at = |yy: usize, xx: usize| img[(yy * w + xx) * c + cc];
                        // fixed summation order: row-major over the window
                        let v = (at(y0, x0) + at(y0, x0 + 1) + at(y0 + 1, x0)
                            + at(y0 + 1, x0 + 1))
                            * 0.25;
                        dst[(oy * ow + ox) * c + cc] = v;
                    }
                }
            }
        }
        (out, TrainCache::default())
    }

    fn backward(
        &self,
        _params: &mut ParamSet,
        _q: QuantSpec,
        _factors: &mut [f32],
        _cache: &mut TrainCache,
        _x: &[f32],
        dy: &[f32],
        n: usize,
        _lr: f32,
        need_dx: bool,
        _kp: &KernelPolicy,
    ) -> Vec<f32> {
        if !need_dx {
            return Vec::new();
        }
        let (h, w, c) = (self.h, self.w, self.c);
        let (oh, ow) = (h / 2, w / 2);
        let mut dx = vec![0f32; n * h * w * c];
        for s in 0..n {
            let g = &dy[s * oh * ow * c..(s + 1) * oh * ow * c];
            let img = &mut dx[s * h * w * c..(s + 1) * h * w * c];
            for oy in 0..oh {
                for ox in 0..ow {
                    let (y0, x0) = (2 * oy, 2 * ox);
                    for cc in 0..c {
                        let gv = g[(oy * ow + ox) * c + cc] * 0.25;
                        img[(y0 * w + x0) * c + cc] = gv;
                        img[(y0 * w + x0 + 1) * c + cc] = gv;
                        img[((y0 + 1) * w + x0) * c + cc] = gv;
                        img[((y0 + 1) * w + x0 + 1) * c + cc] = gv;
                    }
                }
            }
        }
        dx
    }
}

/// Shape bookkeeping between the conv stack and the dense head. NHWC is
/// already flat per sample, so forward/backward are identity copies.
pub struct Flatten {
    pub len: usize,
}

impl Layer for Flatten {
    fn name(&self) -> &'static str {
        "flatten"
    }

    fn in_len(&self) -> usize {
        self.len
    }

    fn out_len(&self) -> usize {
        self.len
    }

    fn param_indices(&self) -> Vec<usize> {
        Vec::new()
    }

    fn quant_slot(&self) -> Option<QuantSlot> {
        None
    }

    fn forward(
        &self,
        _params: &ParamSet,
        _q: QuantSpec,
        _factors: &[f32],
        x: &[f32],
        _n: usize,
        _kp: &KernelPolicy,
    ) -> (Vec<f32>, TrainCache) {
        (x.to_vec(), TrainCache::default())
    }

    fn backward(
        &self,
        _params: &mut ParamSet,
        _q: QuantSpec,
        _factors: &mut [f32],
        _cache: &mut TrainCache,
        _x: &[f32],
        dy: &[f32],
        _n: usize,
        _lr: f32,
        need_dx: bool,
        _kp: &KernelPolicy,
    ) -> Vec<f32> {
        if need_dx {
            dy.to_vec()
        } else {
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::layers::Mode;

    fn fp_spec() -> QuantSpec {
        QuantSpec { mode: Mode::Fp, t_k: 0.05, nq: 0 }
    }

    #[test]
    fn im2col_center_and_corner_patches() {
        // 1 sample, 3x3 single-channel image 1..9, 3x3 kernel
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let col = im2col(&x, 1, 3, 3, 1, 3, 3);
        assert_eq!(col.len(), 9 * 9);
        // center patch (oy=1, ox=1) sees the whole image in order
        let center = &col[4 * 9..5 * 9];
        assert_eq!(center, &x[..]);
        // top-left patch (oy=0, ox=0): first row/col zero-padded
        let tl = &col[0..9];
        assert_eq!(tl, &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), g> == <x, col2im(g)> for random-ish data — the
        // defining property of the transpose pair
        let (h, w, cin, kh, kw) = (4usize, 5usize, 2usize, 3usize, 3usize);
        let n = 2usize;
        let x: Vec<f32> = (0..n * h * w * cin).map(|i| (i as f32 * 0.37).sin()).collect();
        let g: Vec<f32> =
            (0..n * h * w * kh * kw * cin).map(|i| (i as f32 * 0.11).cos()).collect();
        let col = im2col(&x, n, h, w, cin, kh, kw);
        let back = col2im(&g, n, h, w, cin, kh, kw);
        let lhs: f64 = col.iter().zip(&g).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = x.iter().zip(&back).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn avgpool_means_and_spreads() {
        let pool = AvgPool2 { h: 2, w: 2, c: 1 };
        let mut params = ParamSet { tensors: Vec::new() };
        let x = vec![1.0f32, 2.0, 3.0, 6.0];
        let (out, _) = pool.forward(&params, fp_spec(), &[], &x, 1, &KernelPolicy::default());
        assert_eq!(out, vec![3.0]);
        let dx = pool.backward(
            &mut params,
            fp_spec(),
            &mut [],
            &mut TrainCache::default(),
            &x,
            &[4.0],
            1,
            0.1,
            true,
            &KernelPolicy::default(),
        );
        assert_eq!(dx, vec![1.0; 4]);
    }
}
