//! ReLU as its own graph node (the seed trainer fused it into the dense
//! loop; a separate layer is what lets conv/pool stages reuse it).

use crate::model::ParamSet;
use crate::native::kernels::KernelPolicy;
use crate::native::layers::{Layer, QuantSlot, QuantSpec, TrainCache};

/// Elementwise `max(x, 0)` over `len` floats per sample.
pub struct Relu {
    pub len: usize,
}

impl Layer for Relu {
    fn name(&self) -> &'static str {
        "relu"
    }

    fn in_len(&self) -> usize {
        self.len
    }

    fn out_len(&self) -> usize {
        self.len
    }

    fn param_indices(&self) -> Vec<usize> {
        Vec::new()
    }

    fn quant_slot(&self) -> Option<QuantSlot> {
        None
    }

    fn forward(
        &self,
        _params: &ParamSet,
        _q: QuantSpec,
        _factors: &[f32],
        x: &[f32],
        _n: usize,
        _kp: &KernelPolicy,
    ) -> (Vec<f32>, TrainCache) {
        (x.iter().map(|&v| v.max(0.0)).collect(), TrainCache::default())
    }

    fn backward(
        &self,
        _params: &mut ParamSet,
        _q: QuantSpec,
        _factors: &mut [f32],
        _cache: &mut TrainCache,
        x: &[f32],
        dy: &[f32],
        _n: usize,
        _lr: f32,
        need_dx: bool,
        _kp: &KernelPolicy,
    ) -> Vec<f32> {
        if !need_dx {
            return Vec::new();
        }
        // pass the gradient only where the input was strictly positive —
        // `!(xv > 0)` also masks NaN, matching the seed's post-ReLU
        // `act <= 0` mask bit for bit
        x.iter()
            .zip(dy)
            .map(|(&xv, &gv)| if xv > 0.0 { gv } else { 0.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamSet;

    #[test]
    fn forward_clamps_and_backward_masks() {
        let relu = Relu { len: 4 };
        let mut params = ParamSet { tensors: Vec::new() };
        let q = QuantSpec { mode: crate::native::layers::Mode::Fp, t_k: 0.05, nq: 0 };
        let kp = KernelPolicy::default();
        let x = vec![-1.0f32, 0.0, 2.0, -0.0];
        let (out, _) = relu.forward(&params, q, &[], &x, 1, &kp);
        assert_eq!(out, vec![0.0, 0.0, 2.0, 0.0]);
        let dy = vec![1.0f32, 2.0, 3.0, 4.0];
        let dx = relu.backward(
            &mut params,
            q,
            &mut [],
            &mut TrainCache::default(),
            &x,
            &dy,
            1,
            0.1,
            true,
            &kp,
        );
        assert_eq!(dx, vec![0.0, 0.0, 3.0, 0.0]);
    }
}
