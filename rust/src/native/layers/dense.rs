//! Fully-connected layer over the blocked GEMM kernels.

use crate::model::ParamSet;
use crate::native::kernels::{self, KernelPolicy};
use crate::native::layers::{
    apply_sgd, packed_scales, quantize_weights, Layer, QuantSlot, QuantSpec, TrainCache,
};

/// `out = x @ w + b`, weights `[inp, out]` row-major at `ParamSet`
/// index `weight`, bias `[out]` at `bias`. Quantized layers carry a
/// [`QuantSlot`] and run the mode's ternarization in `forward` plus the
/// STE factor update in `backward`.
pub struct Dense {
    pub inp: usize,
    pub out: usize,
    pub weight: usize,
    pub bias: usize,
    pub quant: Option<QuantSlot>,
}

impl Layer for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn in_len(&self) -> usize {
        self.inp
    }

    fn out_len(&self) -> usize {
        self.out
    }

    fn param_indices(&self) -> Vec<usize> {
        vec![self.weight, self.bias]
    }

    fn quant_slot(&self) -> Option<QuantSlot> {
        self.quant
    }

    fn forward(
        &self,
        params: &ParamSet,
        q: QuantSpec,
        factors: &[f32],
        x: &[f32],
        n: usize,
        kp: &KernelPolicy,
    ) -> (Vec<f32>, TrainCache) {
        let w = &params.tensors[self.weight].data;
        let b = &params.tensors[self.bias].data;
        let cache = quantize_weights(w, self.quant, q, factors, kp, self.inp, self.out);
        let mut out = vec![0f32; n * self.out];
        if let Some(pw) = &cache.packed {
            // packed tier: compute on the 2-bit cells directly
            let (ps, ns) = packed_scales(self.quant.unwrap(), q, factors);
            kernels::packed_gemm_bias(x, pw, b, ps, ns, &mut out, n, kp);
        } else {
            let w_eff: &[f32] = if cache.w_eff.is_empty() { w } else { &cache.w_eff };
            kernels::gemm_bias(x, w_eff, b, &mut out, n, self.inp, self.out, kp);
        }
        (out, cache)
    }

    fn backward(
        &self,
        params: &mut ParamSet,
        q: QuantSpec,
        factors: &mut [f32],
        cache: &mut TrainCache,
        x: &[f32],
        dy: &[f32],
        n: usize,
        lr: f32,
        need_dx: bool,
        kp: &KernelPolicy,
    ) -> Vec<f32> {
        // grads of the effective (possibly ternary) weights
        let mut dw = vec![0f32; self.inp * self.out];
        let mut db = vec![0f32; self.out];
        kernels::grad_weights(
            x,
            dy,
            &mut dw,
            &mut db,
            n,
            self.inp,
            self.out,
            kp,
            &mut cache.scratch,
        );
        // dL/dx from the *pre-update* effective weights (seed order:
        // dprev before the parameter step)
        let dx = if need_dx {
            let mut dx = vec![0f32; n * self.inp];
            if let Some(pw) = &cache.packed {
                let (ps, ns) = packed_scales(self.quant.unwrap(), q, factors);
                kernels::packed_grad_input(dy, pw, ps, ns, &mut dx, n, kp);
            } else {
                let w_eff: &[f32] = if cache.w_eff.is_empty() {
                    &params.tensors[self.weight].data
                } else {
                    &cache.w_eff
                };
                kernels::grad_input(
                    dy,
                    w_eff,
                    &mut dx,
                    n,
                    self.inp,
                    self.out,
                    kp,
                    &mut cache.scratch,
                );
            }
            dx
        } else {
            Vec::new()
        };
        apply_sgd(params, self.weight, self.bias, self.quant, q, factors, cache, &dw, &db, lr);
        dx
    }
}
