//! Composable layer graph: the native training core.
//!
//! The seed backend was one hard-coded dense trainer (`NativeMlp`) with
//! the quantization factors threaded through every call as a bare
//! `wq: &[f32]` indexed by layer position — a scheme that collapses as
//! soon as parameter-free layers (ReLU, pooling) sit between the
//! parameterized ones. This module replaces it with:
//!
//! * a [`Layer`] trait (quantization-aware `forward` + update-applying
//!   `backward`, per-sample in/out sizes, parameter slots);
//! * [`Dense`], [`Relu`], [`Conv2d`], [`AvgPool2`], [`Flatten`]
//!   implementations over the deterministic blocked kernels in
//!   [`crate::native::kernels`];
//! * a [`QuantSlot`] attached to each quantized layer: its index `q`
//!   into the model's factor vector (FTTQ: `factors[q]` = w^q; TTQ:
//!   `factors[q]` = w_p, `factors[nq + q]` = w_n) — layers own their
//!   quantization, the graph never guesses from layer position;
//! * [`LayerGraph`]: the batch trainer (forward, masked softmax-CE,
//!   backward, in-place SGD + factor updates) and evaluator.
//!
//! **Determinism contract:** on the `mlp` schema the graph reproduces the
//! seed `NativeMlp` bit for bit in fp and fttq modes, at any kernel
//! thread count (`tests/native_equiv.rs` keeps the seed trainer verbatim
//! and asserts this). TTQ is new native capability (previously PJRT-only).

pub mod conv;
pub mod dense;
pub mod relu;

use std::cmp::Ordering;

use anyhow::{bail, Result};

pub use conv::{AvgPool2, Conv2d, Flatten};
pub use dense::Dense;
pub use relu::Relu;

use crate::model::registry::{dense_from_schema, model_def, LayerSpec, ModelDef, ModelError};
use crate::model::{ModelSchema, ParamSet};
use crate::native::kernels::{KernelPolicy, PackedWeights};
use crate::obs::{
    self,
    metrics::{Counter, Gauge},
};
use crate::quant;

/// Which training math a graph runs (mirrors the artifact "mode").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// full precision
    Fp,
    /// federated trained ternary quantization: one trained factor w^q per
    /// quantized layer (paper eqs. 6-14)
    Fttq,
    /// two-factor trained ternary quantization (Zhu et al.): w_p / w_n
    Ttq,
}

/// A quantized layer's attachment to the model's factor vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantSlot {
    /// index among the model's quantized layers, in schema order
    pub q: usize,
}

/// Per-call quantization parameters, shared by every layer of one batch.
#[derive(Clone, Copy, Debug)]
pub struct QuantSpec {
    pub mode: Mode,
    /// ternarization threshold hyperparameter T_k
    pub t_k: f32,
    /// number of quantized layers (TTQ factor vectors are `2 * nq`)
    pub nq: usize,
}

/// What a layer's `forward` caches for its `backward`.
#[derive(Clone, Debug, Default)]
pub struct TrainCache {
    /// fttq/ttq: the batch's ternary pattern of the latent weights
    pub pattern: Vec<i8>,
    /// fttq/ttq: the dequantized effective weights the forward used
    /// (empty = forward read the latent weights directly, or the packed
    /// tier kept the weights in 2-bit cells)
    pub w_eff: Vec<f32>,
    /// packed tier: the 2-bit effective weights the forward computed on
    /// (`None` on the fp tiers)
    pub packed: Option<PackedWeights>,
    /// conv: the batch's im2col matrix (reused by both gradient GEMMs)
    pub col: Vec<f32>,
    /// kernel scratch (transpose staging), reused across the backward's
    /// GEMMs instead of per-call allocations
    pub scratch: Vec<f32>,
}

/// One node of the compute graph. Layers are stateless and shareable
/// across threads; all per-batch state lives in the arguments and the
/// returned [`TrainCache`].
pub trait Layer: Send + Sync {
    fn name(&self) -> &'static str;
    /// Per-sample input float count.
    fn in_len(&self) -> usize;
    /// Per-sample output float count.
    fn out_len(&self) -> usize;
    /// Indices of this layer's tensors in the positional `ParamSet`.
    fn param_indices(&self) -> Vec<usize>;
    /// The layer's factor slot, when it owns a quantized weight.
    fn quant_slot(&self) -> Option<QuantSlot>;

    /// Quantization-aware batch forward: `x` is `[n, in_len]` row-major;
    /// returns `[n, out_len]` activations plus whatever backward needs.
    fn forward(
        &self,
        params: &ParamSet,
        q: QuantSpec,
        factors: &[f32],
        x: &[f32],
        n: usize,
        kp: &KernelPolicy,
    ) -> (Vec<f32>, TrainCache);

    /// Batch backward: consume the upstream gradient `dy`, apply this
    /// layer's SGD update in place (latent weights, bias, and factors
    /// through the [`QuantSlot`] STE rules), and return `dL/dx`
    /// (empty when `need_dx` is false — the input layer skips that GEMM).
    #[allow(clippy::too_many_arguments)]
    fn backward(
        &self,
        params: &mut ParamSet,
        q: QuantSpec,
        factors: &mut [f32],
        cache: &mut TrainCache,
        x: &[f32],
        dy: &[f32],
        n: usize,
        lr: f32,
        need_dx: bool,
        kp: &KernelPolicy,
    ) -> Vec<f32>;
}

/// Quantization-aware effective weights for one layer's latent tensor
/// (a logical `[k, o]` matrix). Fp mode and unquantized layers return an
/// empty cache (the caller uses the latent weights directly — no copy);
/// fttq/ttq ternarize and cache the pattern plus either the dequantized
/// weights (fp tiers — the exact seed pipeline, preserving bit-identity)
/// or, on the packed tier (`kp.quantized`), the 2-bit [`PackedWeights`]
/// the packed kernels compute on — fp32 weights are never materialized.
pub(crate) fn quantize_weights(
    w: &[f32],
    slot: Option<QuantSlot>,
    q: QuantSpec,
    factors: &[f32],
    kp: &KernelPolicy,
    k: usize,
    o: usize,
) -> TrainCache {
    let s = match (q.mode, slot) {
        (Mode::Fp, _) | (_, None) => return TrainCache::default(),
        (_, Some(s)) => s,
    };
    let it = match q.mode {
        Mode::Fttq => quant::fttq_quantize(w, q.t_k).0,
        Mode::Ttq => {
            // Zhu et al.: scale, eq.-5 max threshold, {+wp, 0, -wn}
            let theta_s = quant::scale(w);
            let delta = quant::threshold_max(&theta_s, q.t_k);
            quant::ternarize(&theta_s, delta)
        }
        Mode::Fp => unreachable!(),
    };
    if kp.quantized {
        let packed = PackedWeights::from_pattern(&it, k, o);
        return TrainCache { pattern: it, packed: Some(packed), ..TrainCache::default() };
    }
    let w_eff = match q.mode {
        Mode::Fttq => quant::dequantize(&it, factors[s.q]),
        Mode::Ttq => {
            let (wp, wn) = (factors[s.q], factors[q.nq + s.q]);
            it.iter()
                .map(|t| match t.cmp(&0) {
                    Ordering::Greater => wp,
                    Ordering::Less => -wn,
                    Ordering::Equal => 0.0,
                })
                .collect()
        }
        Mode::Fp => unreachable!(),
    };
    TrainCache { pattern: it, w_eff, ..TrainCache::default() }
}

/// The packed tier's scale pair for one quantized layer: the effective
/// weight is `+ps` on +1 cells and `-ns` on -1 cells. FTTQ has one
/// trained factor (`ps == ns == w^q`, the symmetric single-accumulator
/// kernel path); TTQ has two (`w_p` / `w_n`, the dual-sum path).
pub(crate) fn packed_scales(slot: QuantSlot, q: QuantSpec, factors: &[f32]) -> (f32, f32) {
    match q.mode {
        Mode::Fttq => (factors[slot.q], factors[slot.q]),
        Mode::Ttq => (factors[slot.q], factors[q.nq + slot.q]),
        Mode::Fp => unreachable!("fp layers have no packed weights"),
    }
}

/// Apply one layer's SGD step: latent weights through the mode's STE
/// rule, factor updates through the [`QuantSlot`], then the bias —
/// the exact seed update order. Factor gradients are support-mean
/// normalized like fttq.py (DESIGN.md §7: the raw sum diverges at layer
/// scale); TTQ extends the same rule to both supports.
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_sgd(
    params: &mut ParamSet,
    weight: usize,
    bias: usize,
    slot: Option<QuantSlot>,
    q: QuantSpec,
    factors: &mut [f32],
    cache: &TrainCache,
    dw: &[f32],
    db: &[f32],
    lr: f32,
) {
    match (q.mode, slot) {
        (Mode::Fp, _) | (_, None) => {
            let w = &mut params.tensors[weight].data;
            for (wv, g) in w.iter_mut().zip(dw) {
                *wv -= lr * g;
            }
        }
        (Mode::Fttq, Some(s)) => {
            // dJ/dwq = mean over I_p of dJ/dtheta_t (Algorithm 1's sum,
            // support-mean normalized exactly like the seed trainer)
            let it = &cache.pattern;
            let mut g_wq = 0f32;
            let mut n_pos = 0usize;
            for (sv, g) in it.iter().zip(dw) {
                if *sv > 0 {
                    g_wq += g;
                    n_pos += 1;
                }
            }
            g_wq /= n_pos.max(1) as f32;
            // latent grads: wq*g on support, g on zeros
            let wq = factors[s.q];
            let w = &mut params.tensors[weight].data;
            for ((wv, g), sv) in w.iter_mut().zip(dw).zip(it) {
                let scale = if *sv != 0 { wq } else { 1.0 };
                *wv -= lr * scale * g;
            }
            factors[s.q] -= lr * g_wq;
        }
        (Mode::Ttq, Some(s)) => {
            // d(w_eff)/d(wp) = +1 on I_p, d(w_eff)/d(wn) = -1 on I_n
            let it = &cache.pattern;
            let (mut g_wp, mut n_pos) = (0f32, 0usize);
            let (mut g_wn, mut n_neg) = (0f32, 0usize);
            for (sv, g) in it.iter().zip(dw) {
                match sv.cmp(&0) {
                    Ordering::Greater => {
                        g_wp += g;
                        n_pos += 1;
                    }
                    Ordering::Less => {
                        g_wn -= g;
                        n_neg += 1;
                    }
                    Ordering::Equal => {}
                }
            }
            g_wp /= n_pos.max(1) as f32;
            g_wn /= n_neg.max(1) as f32;
            let (wp, wn) = (factors[s.q], factors[q.nq + s.q]);
            let w = &mut params.tensors[weight].data;
            for ((wv, g), sv) in w.iter_mut().zip(dw).zip(it) {
                let scale = match sv.cmp(&0) {
                    Ordering::Greater => wp,
                    Ordering::Less => wn,
                    Ordering::Equal => 1.0,
                };
                *wv -= lr * scale * g;
            }
            factors[s.q] -= lr * g_wp;
            factors[q.nq + s.q] -= lr * g_wn;
        }
    }
    let b = &mut params.tensors[bias].data;
    for (bv, g) in b.iter_mut().zip(db) {
        *bv -= lr * g;
    }
}

// ---------------------------------------------------------------------------
// the graph
// ---------------------------------------------------------------------------

/// A validated, executable model: ordered layers over a positional
/// `ParamSet`, one training mode, one kernel policy. Stateless across
/// batches (factors and parameters travel through the calls), so one
/// graph may serve concurrent clients.
pub struct LayerGraph {
    layers: Vec<Box<dyn Layer>>,
    mode: Mode,
    t_k: f32,
    policy: KernelPolicy,
    nq: usize,
    n_params: usize,
    classes: usize,
    /// per-layer kernel-time counters (`tfed_layer_{fwd,train}_us_total`
    /// labeled `layer="<position>.<name>"`), resolved once at build so the
    /// obs-enabled cost is one clock read and a relaxed add per layer per
    /// batch; untouched (one relaxed load) when obs is off
    fwd_us: Vec<&'static Counter>,
    train_us: Vec<&'static Counter>,
    /// per-quantized-layer ternary zero-fraction gauges
    /// (`tfed_layer_zero_fraction`, labeled like the timers; `None` for
    /// unquantized layers), refreshed from each training batch's cached
    /// pattern only while telemetry is enabled
    zero_frac: Vec<Option<&'static Gauge>>,
}

impl LayerGraph {
    /// Build from a registry [`ModelDef`] (validates schema/graph pairing).
    pub fn from_def(
        def: &ModelDef,
        mode: Mode,
        t_k: f32,
        policy: KernelPolicy,
    ) -> Result<LayerGraph, ModelError> {
        def.validate()?;
        let mut layers: Vec<Box<dyn Layer>> = Vec::new();
        let mut pi = 0usize; // param cursor
        let mut qi = 0usize; // quantized-layer cursor
        for spec in &def.layers {
            match *spec {
                LayerSpec::Dense { inp, out, relu } => {
                    let quant = take_slot(&def.schema, pi, &mut qi);
                    layers.push(Box::new(Dense { inp, out, weight: pi, bias: pi + 1, quant }));
                    pi += 2;
                    if relu {
                        layers.push(Box::new(Relu { len: out }));
                    }
                }
                LayerSpec::Conv2d { h, w, cin, cout, kh, kw, relu } => {
                    let quant = take_slot(&def.schema, pi, &mut qi);
                    layers.push(Box::new(Conv2d {
                        h,
                        w,
                        cin,
                        cout,
                        kh,
                        kw,
                        weight: pi,
                        bias: pi + 1,
                        quant,
                    }));
                    pi += 2;
                    if relu {
                        layers.push(Box::new(Relu { len: h * w * cout }));
                    }
                }
                LayerSpec::AvgPool2 { h, w, c } => layers.push(Box::new(AvgPool2 { h, w, c })),
                LayerSpec::Flatten { len } => layers.push(Box::new(Flatten { len })),
            }
        }
        let fwd_us = layer_timers("tfed_layer_fwd_us_total", &layers);
        let train_us = layer_timers("tfed_layer_train_us_total", &layers);
        let zero_frac = layer_zero_gauges(&layers);
        Ok(LayerGraph {
            layers,
            mode,
            t_k,
            policy,
            nq: qi,
            n_params: pi,
            classes: def.schema.num_classes,
            fwd_us,
            train_us,
            zero_frac,
        })
    }

    /// Build a registry model by name.
    pub fn for_model(
        name: &str,
        mode: Mode,
        t_k: f32,
        policy: KernelPolicy,
    ) -> Result<LayerGraph, ModelError> {
        Self::from_def(&model_def(name)?, mode, t_k, policy)
    }

    /// Infer a dense graph from a (w, b)-paired schema (seed contract,
    /// now shape-validated).
    pub fn from_schema(
        schema: &ModelSchema,
        mode: Mode,
        t_k: f32,
        policy: KernelPolicy,
    ) -> Result<LayerGraph, ModelError> {
        Self::from_def(&dense_from_schema(schema)?, mode, t_k, policy)
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn num_quantized(&self) -> usize {
        self.nq
    }

    /// Length of the factor vector this graph's mode trains:
    /// fp 0, fttq `nq` (w^q per layer), ttq `2 nq` (w_p then w_n).
    pub fn factors_len(&self) -> usize {
        match self.mode {
            Mode::Fp => 0,
            Mode::Fttq => self.nq,
            Mode::Ttq => 2 * self.nq,
        }
    }

    fn quant_spec(&self) -> QuantSpec {
        QuantSpec { mode: self.mode, t_k: self.t_k, nq: self.nq }
    }

    fn check(&self, params: &ParamSet, factors: &[f32], x: &[f32], n: usize) -> Result<()> {
        if params.tensors.len() != self.n_params {
            bail!("param count mismatch: {} vs graph {}", params.tensors.len(), self.n_params);
        }
        if factors.len() != self.factors_len() {
            bail!(
                "{:?} graph wants {} factors, got {}",
                self.mode,
                self.factors_len(),
                factors.len()
            );
        }
        let want = n * self.layers.first().map_or(0, |l| l.in_len());
        if x.len() != want {
            bail!("batch of {n} wants {want} input floats, got {}", x.len());
        }
        Ok(())
    }

    /// Forward pass -> logits `[n, classes]` (quantization-aware per the
    /// graph's mode, like the seed trainer's forward).
    ///
    /// Panics (with the mismatch spelled out, not an index error) on a
    /// wrong-length factor vector or input batch; the fallible
    /// [`Self::train_batch`] reports the same conditions as errors.
    pub fn forward(&self, params: &ParamSet, factors: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(
            factors.len(),
            self.factors_len(),
            "{:?} graph wants {} factors",
            self.mode,
            self.factors_len()
        );
        assert_eq!(
            x.len(),
            n * self.layers.first().map_or(0, |l| l.in_len()),
            "batch of {n} has the wrong input length"
        );
        let q = self.quant_spec();
        let obs_on = obs::enabled();
        let mut act = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let t0 = obs_on.then(std::time::Instant::now);
            let (out, _) = layer.forward(params, q, factors, &act, n, &self.policy);
            if let Some(t0) = t0 {
                self.fwd_us[li].add(t0.elapsed().as_micros() as u64);
            }
            act = out;
        }
        act
    }

    /// (mean masked CE loss, accuracy) without updating anything.
    pub fn evaluate(
        &self,
        params: &ParamSet,
        factors: &[f32],
        x: &[f32],
        y: &[u32],
        n: usize,
    ) -> (f32, f32) {
        let mut loss = 0f64;
        let mut correct = 0usize;
        self.evaluate_accumulate(params, factors, x, y, n, &mut loss, &mut correct);
        ((loss / n as f64) as f32, correct as f32 / n as f32)
    }

    /// The accumulator behind [`Self::evaluate`]: fold one batch's f64
    /// loss sum and correct count into running totals. Rows are
    /// independent in every kernel, and the per-sample f64 adds land in
    /// sample order on the shared accumulator — so streaming a large set
    /// through this in chunks is bit-identical to one whole-set
    /// `evaluate`, at O(chunk) memory (conv models would otherwise
    /// materialize a whole-set im2col matrix).
    pub fn evaluate_accumulate(
        &self,
        params: &ParamSet,
        factors: &[f32],
        x: &[f32],
        y: &[u32],
        n: usize,
        loss: &mut f64,
        correct: &mut usize,
    ) {
        let classes = self.classes;
        let logits = self.forward(params, factors, x, n);
        for i in 0..n {
            let row = &logits[i * classes..(i + 1) * classes];
            let (lse, argmax) = log_sum_exp(row);
            *loss += (lse - row[y[i] as usize]) as f64;
            if argmax == y[i] as usize {
                *correct += 1;
            }
        }
    }

    /// One SGD step over a batch; updates `params` (and `factors` in the
    /// quantized modes) in place. Returns the batch mean loss.
    pub fn train_batch(
        &self,
        params: &mut ParamSet,
        factors: &mut [f32],
        x: &[f32],
        y: &[u32],
        n: usize,
        lr: f32,
    ) -> Result<f32> {
        self.check(params, factors, x, n)?;
        let l = self.layers.len();
        let q = self.quant_spec();
        let obs_on = obs::enabled();

        // ---- forward, caching activations + per-layer quant state ----
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(l + 1);
        acts.push(x.to_vec());
        let mut caches: Vec<TrainCache> = Vec::with_capacity(l);
        for (li, layer) in self.layers.iter().enumerate() {
            let t0 = obs_on.then(std::time::Instant::now);
            let (out, cache) = layer.forward(params, q, factors, &acts[li], n, &self.policy);
            if let Some(t0) = t0 {
                self.train_us[li].add(t0.elapsed().as_micros() as u64);
            }
            acts.push(out);
            caches.push(cache);
        }
        // QuantSlots telemetry point: each quantized layer's ternary
        // zero fraction, from the pattern the forward already computed —
        // no extra quantization work, one relaxed load when off.
        if obs::telemetry::enabled() {
            for (li, cache) in caches.iter().enumerate() {
                if let (Some(g), false) = (self.zero_frac[li], cache.pattern.is_empty()) {
                    let zeros = cache.pattern.iter().filter(|&&v| v == 0).count();
                    g.set(zeros as f64 / cache.pattern.len() as f64);
                }
            }
        }

        // ---- masked softmax-CE loss + dlogits (seed-identical) ----
        let classes = self.classes;
        let logits = &acts[l];
        let mut dlogits = vec![0f32; n * classes];
        let mut loss = 0f64;
        for i in 0..n {
            let row = &logits[i * classes..(i + 1) * classes];
            let (lse, _) = log_sum_exp(row);
            loss += (lse - row[y[i] as usize]) as f64;
            for c in 0..classes {
                let p = (row[c] - lse).exp();
                dlogits[i * classes + c] = (p - f32::from(c == y[i] as usize)) / n as f32;
            }
        }

        // ---- backward: each layer applies its own update ----
        let mut dact = dlogits;
        for li in (0..l).rev() {
            let t0 = obs_on.then(std::time::Instant::now);
            dact = self.layers[li].backward(
                params,
                q,
                factors,
                &mut caches[li],
                &acts[li],
                &dact,
                n,
                lr,
                li > 0,
                &self.policy,
            );
            if let Some(t0) = t0 {
                self.train_us[li].add(t0.elapsed().as_micros() as u64);
            }
        }
        Ok((loss / n as f64) as f32)
    }
}

/// Resolve the graph's per-layer kernel-time counters. Registration is
/// idempotent (same name -> same handle), so rebuilding graphs is free;
/// the counters only ever tick while obs is enabled.
fn layer_timers(base: &str, layers: &[Box<dyn Layer>]) -> Vec<&'static Counter> {
    layers
        .iter()
        .enumerate()
        .map(|(i, l)| obs::metrics::counter(&format!("{base}{{layer=\"{i}.{}\"}}", l.name())))
        .collect()
}

/// Resolve per-layer zero-fraction gauges — only where the layer owns a
/// [`QuantSlot`] (unquantized layers have no ternary pattern to report).
fn layer_zero_gauges(layers: &[Box<dyn Layer>]) -> Vec<Option<&'static Gauge>> {
    layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            l.quant_slot().map(|_| {
                obs::metrics::gauge(&format!(
                    "tfed_layer_zero_fraction{{layer=\"{i}.{}\"}}",
                    l.name()
                ))
            })
        })
        .collect()
}

fn take_slot(schema: &ModelSchema, pi: usize, qi: &mut usize) -> Option<QuantSlot> {
    if schema.params[pi].quantized {
        let s = QuantSlot { q: *qi };
        *qi += 1;
        Some(s)
    } else {
        None
    }
}

/// (log-sum-exp, argmax) of one logit row — verbatim the seed helper.
pub(crate) fn log_sum_exp(row: &[f32]) -> (f32, usize) {
    let mut m = f32::NEG_INFINITY;
    let mut arg = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > m {
            m = v;
            arg = i;
        }
    }
    let s: f32 = row.iter().map(|&v| (v - m).exp()).sum();
    (m + s.ln(), arg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelSchema, ParamSpec};
    use crate::util::rng::Pcg;

    pub(crate) fn small_schema() -> ModelSchema {
        ModelSchema {
            name: "small".into(),
            input_dim: 10,
            num_classes: 4,
            optimizer: "sgd".into(),
            default_lr: 0.1,
            params: vec![
                ParamSpec { name: "w1".into(), shape: vec![10, 8], quantized: true },
                ParamSpec { name: "b1".into(), shape: vec![8], quantized: false },
                ParamSpec { name: "w2".into(), shape: vec![8, 4], quantized: true },
                ParamSpec { name: "b2".into(), shape: vec![4], quantized: false },
            ],
        }
    }

    pub(crate) fn toy_batch(
        rng: &mut Pcg,
        n: usize,
        d: usize,
        classes: usize,
    ) -> (Vec<f32>, Vec<u32>) {
        // labels linearly derivable from inputs -> learnable
        let w_true: Vec<f32> = (0..d * classes).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let mut best = (f32::NEG_INFINITY, 0u32);
            for c in 0..classes {
                let mut s = 0f32;
                for k in 0..d {
                    s += x[i * d + k] * w_true[k * classes + c];
                }
                if s > best.0 {
                    best = (s, c as u32);
                }
            }
            y.push(best.1);
        }
        (x, y)
    }

    fn graph(mode: Mode) -> LayerGraph {
        LayerGraph::from_schema(&small_schema(), mode, 0.05, KernelPolicy::default()).unwrap()
    }

    #[test]
    fn fp_training_learns() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(1);
        let mut params = init_params(&schema, &mut rng);
        let net = graph(Mode::Fp);
        let (x, y) = toy_batch(&mut rng, 128, 10, 4);
        let (loss0, acc0) = net.evaluate(&params, &[], &x, &y, 128);
        for _ in 0..60 {
            net.train_batch(&mut params, &mut [], &x, &y, 128, 0.5).unwrap();
        }
        let (loss1, acc1) = net.evaluate(&params, &[], &x, &y, 128);
        assert!(loss1 < loss0 * 0.7, "loss {loss0} -> {loss1}");
        assert!(acc1 > acc0.max(0.5), "acc {acc0} -> {acc1}");
    }

    #[test]
    fn fttq_training_learns_and_wq_moves() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(2);
        let mut params = init_params(&schema, &mut rng);
        let mut wq = vec![0.05f32, 0.05];
        let net = graph(Mode::Fttq);
        let (x, y) = toy_batch(&mut rng, 128, 10, 4);
        let (loss0, acc0) = net.evaluate(&params, &wq, &x, &y, 128);
        for _ in 0..250 {
            net.train_batch(&mut params, &mut wq, &x, &y, 128, 0.2).unwrap();
        }
        let (loss1, acc1) = net.evaluate(&params, &wq, &x, &y, 128);
        assert!(loss1 < loss0, "loss {loss0} -> {loss1}");
        // a ternary 10-8-4 net has little capacity; beating the initial
        // accuracy and chance (0.25) is the meaningful bar here
        assert!(acc1 > acc0.max(0.3), "acc {acc0} -> {acc1}");
        assert!(wq.iter().any(|&w| (w - 0.05).abs() > 1e-4), "{wq:?}");
        assert!(wq.iter().all(|w| w.is_finite()));
    }

    #[test]
    fn ttq_training_learns_and_factors_move() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(6);
        let mut params = init_params(&schema, &mut rng);
        // [wp1, wp2, wn1, wn2]
        let mut factors = vec![0.05f32; 4];
        let net = graph(Mode::Ttq);
        let (x, y) = toy_batch(&mut rng, 128, 10, 4);
        let (loss0, _) = net.evaluate(&params, &factors, &x, &y, 128);
        for _ in 0..250 {
            net.train_batch(&mut params, &mut factors, &x, &y, 128, 0.2).unwrap();
        }
        let (loss1, acc1) = net.evaluate(&params, &factors, &x, &y, 128);
        assert!(loss1 < loss0, "loss {loss0} -> {loss1}");
        assert!(acc1 > 0.3, "acc {acc1}");
        assert!(factors.iter().any(|&w| (w - 0.05).abs() > 1e-4), "{factors:?}");
        assert!(factors.iter().all(|w| w.is_finite()));
        // both factors stay usable as magnitudes (the STE keeps them near
        // the weight scale, not pinned at the init)
        assert!(params.is_finite());
    }

    #[test]
    fn fttq_forward_uses_ternary_weights() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(3);
        let params = init_params(&schema, &mut rng);
        let net = graph(Mode::Fttq);
        let x = vec![1.0f32; 10];
        let wq = vec![0.5, 0.5];
        let out = net.forward(&params, &wq, &x, 1);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gradcheck_fp_weights() {
        // finite-difference check of dL/dw on a tiny dense net
        let schema = small_schema();
        let mut rng = Pcg::seeded(4);
        let params0 = init_params(&schema, &mut rng);
        let net = graph(Mode::Fp);
        let (x, y) = toy_batch(&mut rng, 8, 10, 4);

        // analytic step with tiny lr approximates -lr * grad
        let lr = 1e-3f32;
        let mut p_stepped = params0.clone();
        net.train_batch(&mut p_stepped, &mut [], &x, &y, 8, lr).unwrap();

        let loss_at = |p: &ParamSet| net.evaluate(p, &[], &x, &y, 8).0;
        for (ti, ci) in [(0usize, 0usize), (0, 17), (2, 5), (1, 2), (3, 1)] {
            let eps = 1e-3f32;
            let mut pp = params0.clone();
            pp.tensors[ti].data[ci] += eps;
            let mut pm = params0.clone();
            pm.tensors[ti].data[ci] -= eps;
            let g_num = (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps);
            let g_ana = (params0.tensors[ti].data[ci] - p_stepped.tensors[ti].data[ci]) / lr;
            assert!(
                (g_num - g_ana).abs() < 2e-2 + 0.15 * g_num.abs(),
                "tensor {ti}[{ci}]: num {g_num} vs ana {g_ana}"
            );
        }
    }

    #[test]
    fn eval_counts_match_manual() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(5);
        let params = init_params(&schema, &mut rng);
        let net = graph(Mode::Fp);
        let (x, y) = toy_batch(&mut rng, 16, 10, 4);
        let (loss, acc) = net.evaluate(&params, &[], &x, &y, 16);
        assert!(loss > 0.0 && (0.0..=1.0).contains(&acc));
    }

    #[test]
    fn factor_length_is_checked() {
        let schema = small_schema();
        let mut rng = Pcg::seeded(7);
        let mut params = init_params(&schema, &mut rng);
        let (x, y) = toy_batch(&mut rng, 4, 10, 4);
        let net = graph(Mode::Fttq);
        assert_eq!(net.factors_len(), 2);
        let mut short = vec![0.05f32];
        assert!(net.train_batch(&mut params, &mut short, &x, &y, 4, 0.1).is_err());
        let net = graph(Mode::Ttq);
        assert_eq!(net.factors_len(), 4);
        let net = graph(Mode::Fp);
        assert_eq!(net.factors_len(), 0);
    }

    #[test]
    fn registry_models_run_a_batch() {
        for name in ["mlp", "mlp-large", "cnn"] {
            let def = model_def(name).unwrap();
            let mut rng = Pcg::seeded(9);
            let mut params = init_params(&def.schema, &mut rng);
            let dim = def.schema.input_dim;
            let (x, y) = toy_batch(&mut rng, 8, dim, def.schema.num_classes);
            for mode in [Mode::Fp, Mode::Fttq, Mode::Ttq] {
                for policy in [KernelPolicy::threaded(2), KernelPolicy::packed(2)] {
                    let net = LayerGraph::from_def(&def, mode, 0.05, policy).unwrap();
                    let mut factors = vec![0.05f32; net.factors_len()];
                    let loss =
                        net.train_batch(&mut params, &mut factors, &x, &y, 8, 0.01).unwrap();
                    assert!(loss.is_finite(), "{name} {mode:?} {policy:?}");
                    assert!(params.is_finite(), "{name} {mode:?} {policy:?}");
                }
            }
        }
    }

    #[test]
    fn packed_tier_training_tracks_the_fp_tier() {
        // the packed tier's float-op order differs from the fp tier's, so
        // results are not bit-identical — but the math is the same, and a
        // short fttq training run must land in the same neighborhood with
        // identical ternary support decisions along the way
        let schema = small_schema();
        let mut rng = Pcg::seeded(11);
        let params0 = init_params(&schema, &mut rng);
        let (x, y) = toy_batch(&mut rng, 64, 10, 4);
        let run = |policy: KernelPolicy| {
            let net = LayerGraph::from_schema(&schema, Mode::Fttq, 0.05, policy).unwrap();
            let mut params = params0.clone();
            let mut wq = vec![0.05f32, 0.05];
            for _ in 0..30 {
                net.train_batch(&mut params, &mut wq, &x, &y, 64, 0.1).unwrap();
            }
            net.evaluate(&params, &wq, &x, &y, 64)
        };
        let (loss_fp, acc_fp) = run(KernelPolicy::default());
        let (loss_pk, acc_pk) = run(KernelPolicy::packed(1));
        assert!((loss_fp - loss_pk).abs() < 0.05, "fp {loss_fp} vs packed {loss_pk}");
        assert!((acc_fp - acc_pk).abs() < 0.15, "fp {acc_fp} vs packed {acc_pk}");
    }
}
