//! Deterministic cache-blocked, row-parallel training kernels.
//!
//! Every kernel here obeys one contract: **per output element, the
//! reduction runs in the exact float-op order of the naive seed loops**
//! (`k` ascending for the forward GEMM, batch index `i` ascending for the
//! weight gradients, output index `o` ascending for the input gradients,
//! with the same zero-skip rules). Parallelism and blocking only ever
//! partition the *output* — rows for the forward/input-grad GEMMs, weight
//! rows for the gradient GEMM — never the reduction dimension, so results
//! are bit-identical to the naive kernels at any thread count. The
//! `native_equiv` integration tests and the `--train` bench both assert
//! this.
//!
//! The naive kernels are kept as the reference implementations (they *are*
//! the determinism contract, verbatim from the seed `NativeMlp`) and as
//! the baseline for the `BENCH_train.json` throughput series.

#![allow(clippy::too_many_arguments)]

use crate::util::parallel::parallel_map_indexed;

/// Forward-GEMM column-block width: a 64-float output chunk stays hot in
/// registers/L1 while the weight panel streams past.
const COL_BLOCK: usize = 64;

/// Below roughly this many multiply-accumulates a call runs inline: the
/// thread-scope setup would cost more than it saves.
const PAR_MIN_MACS: usize = 1 << 17;

/// How a layer executes its kernels: worker-thread count plus an escape
/// hatch to the naive reference loops (bench baseline). Results are
/// bit-identical at every setting — only wall time changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelPolicy {
    /// worker threads for row-parallel kernels (1 = inline, the default:
    /// the round driver already fans out over clients)
    pub threads: usize,
    /// run the naive reference loops instead of the blocked kernels
    pub naive: bool,
}

impl KernelPolicy {
    /// Blocked kernels on `threads` workers.
    pub fn threaded(threads: usize) -> KernelPolicy {
        KernelPolicy { threads: threads.max(1), naive: false }
    }

    /// The naive seed loops — the determinism reference and bench baseline.
    pub fn reference() -> KernelPolicy {
        KernelPolicy { threads: 1, naive: true }
    }
}

impl Default for KernelPolicy {
    fn default() -> KernelPolicy {
        KernelPolicy { threads: 1, naive: false }
    }
}

/// Clamp the requested thread count to useful work: one thread unless the
/// call has enough rows and enough MACs to amortize a thread scope.
fn effective_threads(threads: usize, rows: usize, macs: usize) -> usize {
    if threads <= 1 || rows < 2 || macs < PAR_MIN_MACS {
        1
    } else {
        threads.min(rows)
    }
}

/// Split `0..n` into `parts` contiguous, near-equal `(lo, hi)` ranges.
fn split_rows(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let hi = lo + base + usize::from(p < rem);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// `m[rows, cols]` -> `[cols, rows]`. Pure data movement (no float ops),
/// so it never perturbs the bit-identity contract.
fn transpose(m: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0f32; m.len()];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = m[r * cols + c];
        }
    }
    t
}

// ---------------------------------------------------------------------------
// forward: out[n, o] = x[n, k] @ w[k, o] + b[o]
// ---------------------------------------------------------------------------

/// Naive reference (verbatim the seed `matmul_bias`): per row, `k`
/// ascends and zero activations are skipped — the forward contract.
pub fn gemm_bias_naive(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
) {
    for r in 0..n {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * o..(r + 1) * o];
        orow.copy_from_slice(b);
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * o..(kk + 1) * o];
            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
}

/// One contiguous row block of the forward GEMM, column-blocked: each
/// `COL_BLOCK`-wide output chunk accumulates while the full `k` loop
/// streams past it, `k` ascending per element exactly like the naive
/// kernel.
fn gemm_bias_block(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, o: usize) {
    for r in 0..n {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * o..(r + 1) * o];
        let mut ob = 0;
        while ob < o {
            let oe = (ob + COL_BLOCK).min(o);
            let ochunk = &mut orow[ob..oe];
            ochunk.copy_from_slice(&b[ob..oe]);
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * o + ob..kk * o + oe];
                for (ov, &wv) in ochunk.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
            ob = oe;
        }
    }
}

/// Blocked, row-parallel forward GEMM. Bit-identical to
/// [`gemm_bias_naive`] at any `policy`.
pub fn gemm_bias(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
    policy: &KernelPolicy,
) {
    if policy.naive {
        return gemm_bias_naive(x, w, b, out, n, k, o);
    }
    let threads = effective_threads(policy.threads, n, n * k * o);
    if threads <= 1 {
        return gemm_bias_block(x, w, b, out, n, k, o);
    }
    let bounds = split_rows(n, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * o];
        gemm_bias_block(&x[lo * k..hi * k], w, b, &mut chunk, hi - lo, k, o);
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        out[lo * o..hi * o].copy_from_slice(&chunk);
    }
}

// ---------------------------------------------------------------------------
// weight gradients: dw[k, o] = sum_i a[i, k] * g[i, o]; db[o] = sum_i g[i, o]
// ---------------------------------------------------------------------------

/// Naive reference (verbatim the seed backward loops): the batch index
/// `i` ascends per element and rows with `g == 0` are skipped — the
/// gradient contract. `dw`/`db` must arrive zero-filled.
pub fn grad_weights_naive(
    a: &[f32],
    g: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
) {
    for i in 0..n {
        for oo in 0..o {
            let gv = g[i * o + oo];
            if gv == 0.0 {
                continue;
            }
            db[oo] += gv;
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                dw[kk * o + oo] += av * gv;
            }
        }
    }
}

/// Blocked, weight-row-parallel gradient kernel: `g` is transposed once
/// (data movement only) so every `dw[k, o]` reduces two contiguous
/// length-`n` vectors; the reduction order (`i` ascending, zeros
/// skipped) matches [`grad_weights_naive`] bit for bit. `dw`/`db` must
/// arrive zero-filled.
pub fn grad_weights(
    a: &[f32],
    g: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
    policy: &KernelPolicy,
) {
    if policy.naive {
        return grad_weights_naive(a, g, dw, db, n, k, o);
    }
    let gt = transpose(g, n, o);
    for (oo, dv) in db.iter_mut().enumerate() {
        let grow = &gt[oo * n..(oo + 1) * n];
        let mut s = *dv;
        for &gv in grow {
            if gv == 0.0 {
                continue;
            }
            s += gv;
        }
        *dv = s;
    }
    let threads = effective_threads(policy.threads, k, n * k * o);
    let bounds = split_rows(k, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * o];
        let mut acol = vec![0f32; n];
        for kk in lo..hi {
            for (i, av) in acol.iter_mut().enumerate() {
                *av = a[i * k + kk];
            }
            let crow = &mut chunk[(kk - lo) * o..(kk - lo + 1) * o];
            for (oo, cv) in crow.iter_mut().enumerate() {
                let grow = &gt[oo * n..(oo + 1) * n];
                let mut s = *cv;
                for (&av, &gv) in acol.iter().zip(grow) {
                    if gv == 0.0 {
                        continue;
                    }
                    s += av * gv;
                }
                *cv = s;
            }
        }
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        // dw arrives zero-filled, so add-into-zero == the chunk values
        dw[lo * o..hi * o].copy_from_slice(&chunk);
    }
}

// ---------------------------------------------------------------------------
// input gradients: dx[i, k] = sum_o g[i, o] * w[k, o]
// ---------------------------------------------------------------------------

/// Naive reference (verbatim the seed `dprev` loop, minus the ReLU mask
/// that now lives in the `Relu` layer): `o` ascends per element.
pub fn grad_input_naive(g: &[f32], w: &[f32], dx: &mut [f32], n: usize, k: usize, o: usize) {
    for i in 0..n {
        let grow = &g[i * o..(i + 1) * o];
        let drow = &mut dx[i * k..(i + 1) * k];
        for (kk, dv) in drow.iter_mut().enumerate() {
            let wrow = &w[kk * o..(kk + 1) * o];
            let mut s = 0f32;
            for (&wv, &gv) in wrow.iter().zip(grow) {
                s += wv * gv;
            }
            *dv = s;
        }
    }
}

/// Row-parallel input-gradient GEMM (the inner reduction is already
/// contiguous in both operands). Bit-identical to [`grad_input_naive`].
pub fn grad_input(
    g: &[f32],
    w: &[f32],
    dx: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
    policy: &KernelPolicy,
) {
    if policy.naive {
        return grad_input_naive(g, w, dx, n, k, o);
    }
    let threads = effective_threads(policy.threads, n, n * k * o);
    if threads <= 1 {
        return grad_input_naive(g, w, dx, n, k, o);
    }
    let bounds = split_rows(n, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * k];
        grad_input_naive(&g[lo * o..hi * o], w, &mut chunk, hi - lo, k, o);
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        dx[lo * k..hi * k].copy_from_slice(&chunk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(rng: &mut Pcg, n: usize, sparse: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let v = rng.normal();
                // exercise the zero-skip paths like ReLU activations do
                if sparse && v < 0.0 {
                    0.0
                } else {
                    v
                }
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gemm_bias_matches_naive_at_any_thread_count() {
        let mut rng = Pcg::seeded(1);
        for &(n, k, o) in &[(1usize, 5usize, 3usize), (7, 33, 65), (64, 130, 64), (13, 784, 30)] {
            let x = randn(&mut rng, n * k, true);
            let w = randn(&mut rng, k * o, false);
            let b = randn(&mut rng, o, false);
            let mut want = vec![0f32; n * o];
            gemm_bias_naive(&x, &w, &b, &mut want, n, k, o);
            for threads in [1, 2, 3, 8] {
                let mut got = vec![0f32; n * o];
                gemm_bias(&x, &w, &b, &mut got, n, k, o, &KernelPolicy::threaded(threads));
                assert_eq!(bits(&want), bits(&got), "n={n} k={k} o={o} threads={threads}");
            }
        }
    }

    #[test]
    fn grad_weights_matches_naive_at_any_thread_count() {
        let mut rng = Pcg::seeded(2);
        for &(n, k, o) in &[(1usize, 4usize, 2usize), (9, 65, 31), (64, 129, 66)] {
            let a = randn(&mut rng, n * k, true);
            let g = randn(&mut rng, n * o, true);
            let mut dw_want = vec![0f32; k * o];
            let mut db_want = vec![0f32; o];
            grad_weights_naive(&a, &g, &mut dw_want, &mut db_want, n, k, o);
            for threads in [1, 2, 5] {
                let mut dw = vec![0f32; k * o];
                let mut db = vec![0f32; o];
                grad_weights(&a, &g, &mut dw, &mut db, n, k, o, &KernelPolicy::threaded(threads));
                assert_eq!(bits(&dw_want), bits(&dw), "dw n={n} k={k} o={o} t={threads}");
                assert_eq!(bits(&db_want), bits(&db), "db n={n} k={k} o={o} t={threads}");
            }
        }
    }

    #[test]
    fn grad_input_matches_naive_at_any_thread_count() {
        let mut rng = Pcg::seeded(3);
        for &(n, k, o) in &[(2usize, 3usize, 4usize), (11, 70, 29), (64, 256, 64)] {
            let g = randn(&mut rng, n * o, true);
            let w = randn(&mut rng, k * o, false);
            let mut want = vec![0f32; n * k];
            grad_input_naive(&g, &w, &mut want, n, k, o);
            for threads in [1, 2, 7] {
                let mut got = vec![0f32; n * k];
                grad_input(&g, &w, &mut got, n, k, o, &KernelPolicy::threaded(threads));
                assert_eq!(bits(&want), bits(&got), "n={n} k={k} o={o} t={threads}");
            }
        }
    }

    #[test]
    fn split_rows_partitions_exactly() {
        for (n, parts) in [(10usize, 3usize), (3, 8), (1, 1), (0, 4), (64, 4)] {
            let b = split_rows(n, parts);
            assert_eq!(b.first().map(|r| r.0).unwrap_or(0), 0);
            assert_eq!(b.last().map(|r| r.1).unwrap_or(0), n);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].0 < w[0].1);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let m: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let t = transpose(&m, 3, 4);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // t[c=0, r=1] = m[r=1, c=0]
        assert_eq!(transpose(&t, 4, 3), m);
    }
}
