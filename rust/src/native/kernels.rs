//! Deterministic cache-blocked, row-parallel training kernels — plus the
//! packed-ternary tier that computes directly on the 2-bit cells.
//!
//! Every fp kernel here obeys one contract: **per output element, the
//! reduction runs in the exact float-op order of the naive seed loops**
//! (`k` ascending for the forward GEMM, batch index `i` ascending for the
//! weight gradients, output index `o` ascending for the input gradients,
//! with the same zero-skip rules). Parallelism and blocking only ever
//! partition the *output* — rows for the forward/input-grad GEMMs, weight
//! rows for the gradient GEMM — never the reduction dimension, so results
//! are bit-identical to the naive kernels at any thread count. The
//! `native_equiv` integration tests and the `--train` bench both assert
//! this.
//!
//! The packed tier ([`packed_gemm_bias`] / [`packed_grad_input`] over
//! [`PackedWeights`]) is a *separate* contract (DESIGN.md §15): it
//! accumulates sign-selected sums over the packed bytes and applies the
//! ternary scale once per output element, so its float-op order
//! legitimately differs from the fp32 kernels. It carries its own naive
//! reference oracles ([`packed_gemm_bias_naive`] /
//! [`packed_grad_input_naive`]) and is bit-identical to *those* at any
//! thread count.
//!
//! The naive kernels are kept as the reference implementations (they *are*
//! the determinism contract, verbatim from the seed `NativeMlp`) and as
//! the baseline for the `BENCH_train.json` throughput series.

#![allow(clippy::too_many_arguments)]

use crate::compress::ternary::{byte_expand_lut, cell_table, pack_row};
use crate::util::parallel::parallel_map_indexed;

/// Forward-GEMM column-block width: a 64-float output chunk stays hot in
/// registers/L1 while the weight panel streams past. Kept a multiple of 4
/// so packed-tier column blocks always start on a byte boundary.
const COL_BLOCK: usize = 64;

/// Fixed vector width for the fp inner loops: `chunks_exact` over 8 lanes
/// gives the compiler a branch-free, known-trip-count body to vectorize.
const LANES: usize = 8;

/// Below roughly this many multiply-accumulates a call runs inline: the
/// thread-scope setup would cost more than it saves.
const PAR_MIN_MACS: usize = 1 << 17;

/// How a layer executes its kernels: worker-thread count, an escape hatch
/// to the naive reference loops (bench baseline), and the opt-in
/// quantized tier that runs ternary layers directly on packed weights.
/// Within a tier, results are bit-identical at every thread count — only
/// wall time changes. The fp tiers (`quantized == false`) and the packed
/// tier are *different* contracts with different float-op orders.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelPolicy {
    /// worker threads for row-parallel kernels (1 = inline, the default:
    /// the round driver already fans out over clients)
    pub threads: usize,
    /// run the naive reference loops instead of the blocked kernels
    pub naive: bool,
    /// quantized-domain tier: ternary layers keep their weights packed
    /// (2-bit cells) and run the packed kernels; fp layers are unaffected
    pub quantized: bool,
}

impl KernelPolicy {
    /// Blocked kernels on `threads` workers.
    pub fn threaded(threads: usize) -> KernelPolicy {
        KernelPolicy { threads: threads.max(1), naive: false, quantized: false }
    }

    /// The naive seed loops — the determinism reference and bench baseline.
    pub fn reference() -> KernelPolicy {
        KernelPolicy { threads: 1, naive: true, quantized: false }
    }

    /// Packed-ternary tier on `threads` workers: quantized layers compute
    /// on the 2-bit representation, fp layers use the blocked kernels.
    pub fn packed(threads: usize) -> KernelPolicy {
        KernelPolicy { threads: threads.max(1), naive: false, quantized: true }
    }

    /// The packed tier's naive oracle loops — its own determinism
    /// reference (the packed float-op order differs from fp32).
    pub fn packed_reference() -> KernelPolicy {
        KernelPolicy { threads: 1, naive: true, quantized: true }
    }

    /// Parse a CLI/manifest/env tier spec:
    /// `naive` | `blocked[:threads]` | `packed[:threads]` | `packed-naive`.
    pub fn parse(s: &str) -> Result<KernelPolicy, String> {
        match s {
            "naive" => return Ok(KernelPolicy::reference()),
            "packed-naive" => return Ok(KernelPolicy::packed_reference()),
            _ => {}
        }
        let (tier, threads) = match s.split_once(':') {
            Some((tier, n)) => {
                let n: usize = n
                    .parse()
                    .ok()
                    .filter(|&n| (1..=1024).contains(&n))
                    .ok_or_else(|| format!("bad thread count in kernel spec `{s}`"))?;
                (tier, n)
            }
            None => (s, 1),
        };
        match tier {
            "blocked" => Ok(KernelPolicy::threaded(threads)),
            "packed" => Ok(KernelPolicy::packed(threads)),
            _ => Err(format!(
                "unknown kernel tier `{s}` (expected naive | blocked[:N] | packed[:N] | packed-naive)"
            )),
        }
    }
}

impl Default for KernelPolicy {
    fn default() -> KernelPolicy {
        KernelPolicy { threads: 1, naive: false, quantized: false }
    }
}

/// Clamp the requested thread count to useful work: one thread unless the
/// call has enough rows and enough MACs to amortize a thread scope.
fn effective_threads(threads: usize, rows: usize, macs: usize) -> usize {
    if threads <= 1 || rows < 2 || macs < PAR_MIN_MACS {
        1
    } else {
        threads.min(rows)
    }
}

/// Split `0..n` into `parts` contiguous, near-equal `(lo, hi)` ranges.
fn split_rows(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let hi = lo + base + usize::from(p < rem);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// `m[rows, cols]` -> `out[cols, rows]`, reusing the caller's scratch
/// buffer (no per-call allocation). Pure data movement (no float ops), so
/// it never perturbs the bit-identity contract.
fn transpose_into(m: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(m.len(), 0.0);
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = m[r * cols + c];
        }
    }
}

// ---------------------------------------------------------------------------
// forward: out[n, o] = x[n, k] @ w[k, o] + b[o]
// ---------------------------------------------------------------------------

/// Naive reference (verbatim the seed `matmul_bias`): per row, `k`
/// ascends and zero activations are skipped — the forward contract.
pub fn gemm_bias_naive(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
) {
    for r in 0..n {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * o..(r + 1) * o];
        orow.copy_from_slice(b);
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * o..(kk + 1) * o];
            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
}

/// `chunk[j] += s * src[j]` in explicitly vectorizable form: fixed
/// `LANES`-wide bodies with no per-element branches, plus a scalar tail.
/// Element-wise (no cross-lane reduction), so the per-element float-op
/// order is untouched.
#[inline]
fn axpy_lanes(chunk: &mut [f32], src: &[f32], s: f32) {
    let mut dst = chunk.chunks_exact_mut(LANES);
    let mut srcs = src.chunks_exact(LANES);
    for (d, v) in (&mut dst).zip(&mut srcs) {
        for (dv, &sv) in d.iter_mut().zip(v) {
            *dv += s * sv;
        }
    }
    for (dv, &sv) in dst.into_remainder().iter_mut().zip(srcs.remainder()) {
        *dv += s * sv;
    }
}

/// One contiguous row block of the forward GEMM, column-blocked: each
/// `COL_BLOCK`-wide output chunk accumulates while the full `k` loop
/// streams past it, `k` ascending per element exactly like the naive
/// kernel.
fn gemm_bias_block(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, o: usize) {
    for r in 0..n {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * o..(r + 1) * o];
        let mut ob = 0;
        while ob < o {
            let oe = (ob + COL_BLOCK).min(o);
            let ochunk = &mut orow[ob..oe];
            ochunk.copy_from_slice(&b[ob..oe]);
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                axpy_lanes(ochunk, &w[kk * o + ob..kk * o + oe], xv);
            }
            ob = oe;
        }
    }
}

/// Blocked, row-parallel forward GEMM. Bit-identical to
/// [`gemm_bias_naive`] at any `policy`.
pub fn gemm_bias(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
    policy: &KernelPolicy,
) {
    if policy.naive {
        return gemm_bias_naive(x, w, b, out, n, k, o);
    }
    let threads = effective_threads(policy.threads, n, n * k * o);
    if threads <= 1 {
        return gemm_bias_block(x, w, b, out, n, k, o);
    }
    let bounds = split_rows(n, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * o];
        gemm_bias_block(&x[lo * k..hi * k], w, b, &mut chunk, hi - lo, k, o);
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        out[lo * o..hi * o].copy_from_slice(&chunk);
    }
}

// ---------------------------------------------------------------------------
// weight gradients: dw[k, o] = sum_i a[i, k] * g[i, o]; db[o] = sum_i g[i, o]
// ---------------------------------------------------------------------------

/// Naive reference (verbatim the seed backward loops): the batch index
/// `i` ascends per element and rows with `g == 0` are skipped — the
/// gradient contract. `dw`/`db` must arrive zero-filled.
pub fn grad_weights_naive(
    a: &[f32],
    g: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
) {
    for i in 0..n {
        for oo in 0..o {
            let gv = g[i * o + oo];
            if gv == 0.0 {
                continue;
            }
            db[oo] += gv;
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                dw[kk * o + oo] += av * gv;
            }
        }
    }
}

/// Blocked, weight-row-parallel gradient kernel: `g` is transposed once
/// into the caller's `scratch` buffer (data movement only, no per-call
/// allocation) so every `dw[k, o]` reduces two contiguous length-`n`
/// vectors; the reduction order (`i` ascending, zeros skipped) matches
/// [`grad_weights_naive`] bit for bit. `dw`/`db` must arrive zero-filled.
pub fn grad_weights(
    a: &[f32],
    g: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
    policy: &KernelPolicy,
    scratch: &mut Vec<f32>,
) {
    if policy.naive {
        return grad_weights_naive(a, g, dw, db, n, k, o);
    }
    transpose_into(g, n, o, scratch);
    let gt: &[f32] = scratch;
    for (oo, dv) in db.iter_mut().enumerate() {
        let grow = &gt[oo * n..(oo + 1) * n];
        let mut s = *dv;
        for &gv in grow {
            if gv == 0.0 {
                continue;
            }
            s += gv;
        }
        *dv = s;
    }
    let threads = effective_threads(policy.threads, k, n * k * o);
    let bounds = split_rows(k, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * o];
        let mut acol = vec![0f32; n];
        for kk in lo..hi {
            for (i, av) in acol.iter_mut().enumerate() {
                *av = a[i * k + kk];
            }
            let crow = &mut chunk[(kk - lo) * o..(kk - lo + 1) * o];
            for (oo, cv) in crow.iter_mut().enumerate() {
                let grow = &gt[oo * n..(oo + 1) * n];
                let mut s = *cv;
                for (&av, &gv) in acol.iter().zip(grow) {
                    if gv == 0.0 {
                        continue;
                    }
                    s += av * gv;
                }
                *cv = s;
            }
        }
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        // dw arrives zero-filled, so add-into-zero == the chunk values
        dw[lo * o..hi * o].copy_from_slice(&chunk);
    }
}

// ---------------------------------------------------------------------------
// input gradients: dx[i, k] = sum_o g[i, o] * w[k, o]
// ---------------------------------------------------------------------------

/// Naive reference (verbatim the seed `dprev` loop, minus the ReLU mask
/// that now lives in the `Relu` layer): `o` ascends per element.
pub fn grad_input_naive(g: &[f32], w: &[f32], dx: &mut [f32], n: usize, k: usize, o: usize) {
    for i in 0..n {
        let grow = &g[i * o..(i + 1) * o];
        let drow = &mut dx[i * k..(i + 1) * k];
        for (kk, dv) in drow.iter_mut().enumerate() {
            let wrow = &w[kk * o..(kk + 1) * o];
            let mut s = 0f32;
            for (&wv, &gv) in wrow.iter().zip(grow) {
                s += wv * gv;
            }
            *dv = s;
        }
    }
}

/// One contiguous row block of the input-gradient GEMM over a
/// pre-transposed weight matrix `wt[o, k]`: each `COL_BLOCK`-wide `dx`
/// chunk accumulates while the full `o` loop streams past it, so the
/// inner body is a contiguous branch-free lane loop. Per element the
/// products `w[k, o] * g[i, o]` still accumulate with `o` ascending —
/// bit-identical to [`grad_input_naive`].
fn grad_input_block(g: &[f32], wt: &[f32], dx: &mut [f32], n: usize, k: usize, o: usize) {
    for i in 0..n {
        let grow = &g[i * o..(i + 1) * o];
        let drow = &mut dx[i * k..(i + 1) * k];
        let mut kb = 0;
        while kb < k {
            let ke = (kb + COL_BLOCK).min(k);
            let chunk = &mut drow[kb..ke];
            chunk.fill(0.0);
            for (oo, &gv) in grow.iter().enumerate() {
                axpy_lanes(chunk, &wt[oo * k + kb..oo * k + ke], gv);
            }
            kb = ke;
        }
    }
}

/// Blocked, row-parallel input-gradient GEMM: `w` is transposed once into
/// the caller's `scratch` buffer (data movement only), then every row
/// block runs the column-blocked kernel — no naive fallback at any thread
/// count. Bit-identical to [`grad_input_naive`].
pub fn grad_input(
    g: &[f32],
    w: &[f32],
    dx: &mut [f32],
    n: usize,
    k: usize,
    o: usize,
    policy: &KernelPolicy,
    scratch: &mut Vec<f32>,
) {
    if policy.naive {
        return grad_input_naive(g, w, dx, n, k, o);
    }
    transpose_into(w, k, o, scratch);
    let wt: &[f32] = scratch;
    let threads = effective_threads(policy.threads, n, n * k * o);
    if threads <= 1 {
        return grad_input_block(g, wt, dx, n, k, o);
    }
    let bounds = split_rows(n, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * k];
        grad_input_block(&g[lo * o..hi * o], wt, &mut chunk, hi - lo, k, o);
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        dx[lo * k..hi * k].copy_from_slice(&chunk);
    }
}

// ---------------------------------------------------------------------------
// packed-ternary tier: compute on the 2-bit cells, never dequantize
// ---------------------------------------------------------------------------

/// A `[k, o]` ternary weight matrix kept in the codec's 2-bit cell
/// encoding (00 -> 0, 01 -> +1, 10 -> -1), one byte-aligned packed row
/// per input index `k` so column blocks start on byte boundaries. At 4
/// trits/byte this is 1/16 the footprint of the dequantized fp32 matrix —
/// an `mlp-large` 784x256 panel drops from ~800 KB (streams from L2/L3)
/// to ~50 KB (lives in L1), which is where the packed tier's speed comes
/// from.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedWeights {
    /// input dimension (logical rows)
    pub k: usize,
    /// output dimension (packed 4 trits/byte within a row)
    pub o: usize,
    /// bytes per packed row: `o.div_ceil(4)`
    pub row_bytes: usize,
    /// `k * row_bytes` cells, row-major, zero-padded per row
    pub bytes: Vec<u8>,
}

impl PackedWeights {
    /// Pack a `[k, o]` sign pattern ({-1, 0, +1} as i8, row-major) using
    /// the codec's shared row packer — one trit encoder for wire and
    /// kernels alike.
    pub fn from_pattern(it: &[i8], k: usize, o: usize) -> PackedWeights {
        assert_eq!(it.len(), k * o, "pattern length {} != {k}x{o}", it.len());
        let row_bytes = o.div_ceil(4);
        let mut bytes = Vec::with_capacity(k * row_bytes);
        if o > 0 {
            for row in it.chunks_exact(o) {
                pack_row(row, &mut bytes);
            }
        }
        PackedWeights { k, o, row_bytes, bytes }
    }

    #[inline]
    fn row(&self, kk: usize) -> &[u8] {
        &self.bytes[kk * self.row_bytes..(kk + 1) * self.row_bytes]
    }
}

/// Decode one 2-bit cell of a packed row.
#[inline]
fn cell_code(row: &[u8], oo: usize) -> usize {
    ((row[oo / 4] >> ((oo % 4) * 2)) & 3) as usize
}

/// Naive packed-forward oracle — **the packed tier's contract**, distinct
/// from the fp32 one. Per output element, `k` ascends with the same
/// zero-activation skip as the fp forward, but the accumulation is
/// sign-selected unit sums scaled once at the end:
///
/// * symmetric scales (`ps` bitwise == `ns`, the FTTQ case): a single
///   signed sum `acc += x * sign`, then `b + ps * acc`;
/// * asymmetric scales (TTQ's `wp`/`wn`): a positive and a negative sum,
///   then `b + (ps * pos - ns * neg)`.
///
/// The effective weight is `+ps` on +1 cells and `-ns` on -1 cells.
pub fn packed_gemm_bias_naive(
    x: &[f32],
    pw: &PackedWeights,
    b: &[f32],
    ps: f32,
    ns: f32,
    out: &mut [f32],
    n: usize,
) {
    let (k, o) = (pw.k, pw.o);
    let sign = cell_table(1.0, -1.0);
    let pos_t = cell_table(1.0, 0.0);
    let neg_t = cell_table(0.0, 1.0);
    let symmetric = ps.to_bits() == ns.to_bits();
    for r in 0..n {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * o..(r + 1) * o];
        for (oo, ov) in orow.iter_mut().enumerate() {
            if symmetric {
                let mut acc = 0f32;
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    acc += xv * sign[cell_code(pw.row(kk), oo)];
                }
                *ov = b[oo] + ps * acc;
            } else {
                let (mut pos, mut neg) = (0f32, 0f32);
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let code = cell_code(pw.row(kk), oo);
                    pos += xv * pos_t[code];
                    neg += xv * neg_t[code];
                }
                *ov = b[oo] + (ps * pos - ns * neg);
            }
        }
    }
}

/// One row block of the packed forward, column-blocked over byte-aligned
/// 4-lane cells: the inner body is a branch-free LUT expansion
/// (`byte -> 4 sign floats`) plus fused multiply-adds — fixed-width and
/// vectorizable. Bit-identical to [`packed_gemm_bias_naive`]: per
/// element, the same `k`-ascending sign-selected terms accumulate (the
/// LUT's padding lanes contribute exact zeros to lanes that are never
/// copied out).
fn packed_gemm_block(
    x: &[f32],
    pw: &PackedWeights,
    b: &[f32],
    ps: f32,
    ns: f32,
    out: &mut [f32],
    n: usize,
) {
    let (k, o) = (pw.k, pw.o);
    let symmetric = ps.to_bits() == ns.to_bits();
    let slut = byte_expand_lut(1.0, -1.0);
    let plut = byte_expand_lut(1.0, 0.0);
    let nlut = byte_expand_lut(0.0, 1.0);
    for r in 0..n {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * o..(r + 1) * o];
        let mut ob = 0;
        while ob < o {
            let oe = (ob + COL_BLOCK).min(o);
            let nb = (oe - ob).div_ceil(4);
            if symmetric {
                let mut acc = [0f32; COL_BLOCK];
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &pw.row(kk)[ob / 4..ob / 4 + nb];
                    for (a4, &byte) in acc.chunks_exact_mut(4).zip(wrow) {
                        let lane = &slut[byte as usize];
                        a4[0] += xv * lane[0];
                        a4[1] += xv * lane[1];
                        a4[2] += xv * lane[2];
                        a4[3] += xv * lane[3];
                    }
                }
                for ((ov, &bv), &av) in orow[ob..oe].iter_mut().zip(&b[ob..oe]).zip(&acc) {
                    *ov = bv + ps * av;
                }
            } else {
                let mut pacc = [0f32; COL_BLOCK];
                let mut nacc = [0f32; COL_BLOCK];
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &pw.row(kk)[ob / 4..ob / 4 + nb];
                    for ((p4, n4), &byte) in
                        pacc.chunks_exact_mut(4).zip(nacc.chunks_exact_mut(4)).zip(wrow)
                    {
                        let pl = &plut[byte as usize];
                        let nl = &nlut[byte as usize];
                        p4[0] += xv * pl[0];
                        p4[1] += xv * pl[1];
                        p4[2] += xv * pl[2];
                        p4[3] += xv * pl[3];
                        n4[0] += xv * nl[0];
                        n4[1] += xv * nl[1];
                        n4[2] += xv * nl[2];
                        n4[3] += xv * nl[3];
                    }
                }
                for (j, (ov, &bv)) in orow[ob..oe].iter_mut().zip(&b[ob..oe]).enumerate() {
                    *ov = bv + (ps * pacc[j] - ns * nacc[j]);
                }
            }
            ob = oe;
        }
    }
}

/// Packed-ternary forward GEMM: `out[n, o] = x[n, k] @ W + b` where `W`
/// is `+ps` on +1 cells and `-ns` on -1 cells, computed without ever
/// materializing `W` in fp32. Row-parallel; bit-identical to
/// [`packed_gemm_bias_naive`] at any `policy`.
pub fn packed_gemm_bias(
    x: &[f32],
    pw: &PackedWeights,
    b: &[f32],
    ps: f32,
    ns: f32,
    out: &mut [f32],
    n: usize,
    policy: &KernelPolicy,
) {
    if policy.naive {
        return packed_gemm_bias_naive(x, pw, b, ps, ns, out, n);
    }
    let (k, o) = (pw.k, pw.o);
    let threads = effective_threads(policy.threads, n, n * k * o);
    if threads <= 1 {
        return packed_gemm_block(x, pw, b, ps, ns, out, n);
    }
    let bounds = split_rows(n, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * o];
        packed_gemm_block(&x[lo * k..hi * k], pw, b, ps, ns, &mut chunk, hi - lo);
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        out[lo * o..hi * o].copy_from_slice(&chunk);
    }
}

/// Naive packed input-gradient oracle — the packed tier's backward
/// contract. `dx[i, k] = sum_o g[i, o] * sign(W[k, o])`, accumulated in
/// four lane partials (`o mod 4`, each `o`-ascending) and combined as
/// `(a0 + a1) + (a2 + a3)` so the fast path's 4-lane byte expansion is
/// the same float-op order; scales apply once per element like the
/// forward.
pub fn packed_grad_input_naive(
    g: &[f32],
    pw: &PackedWeights,
    ps: f32,
    ns: f32,
    dx: &mut [f32],
    n: usize,
) {
    let (k, o) = (pw.k, pw.o);
    let sign = cell_table(1.0, -1.0);
    let pos_t = cell_table(1.0, 0.0);
    let neg_t = cell_table(0.0, 1.0);
    let symmetric = ps.to_bits() == ns.to_bits();
    for i in 0..n {
        let grow = &g[i * o..(i + 1) * o];
        let drow = &mut dx[i * k..(i + 1) * k];
        for (kk, dv) in drow.iter_mut().enumerate() {
            let wrow = pw.row(kk);
            if symmetric {
                let mut a = [0f32; 4];
                for (oo, &gv) in grow.iter().enumerate() {
                    a[oo % 4] += gv * sign[cell_code(wrow, oo)];
                }
                *dv = ps * ((a[0] + a[1]) + (a[2] + a[3]));
            } else {
                let mut pa = [0f32; 4];
                let mut na = [0f32; 4];
                for (oo, &gv) in grow.iter().enumerate() {
                    let code = cell_code(wrow, oo);
                    pa[oo % 4] += gv * pos_t[code];
                    na[oo % 4] += gv * neg_t[code];
                }
                *dv = ps * ((pa[0] + pa[1]) + (pa[2] + pa[3]))
                    - ns * ((na[0] + na[1]) + (na[2] + na[3]));
            }
        }
    }
}

/// One row block of the packed input gradient: per `(i, k)` the packed
/// row streams byte-by-byte through the sign LUT against four gradient
/// lanes — branch-free and fixed-width. Bit-identical to
/// [`packed_grad_input_naive`] (same lane partials, same combine).
fn packed_grad_input_block(
    g: &[f32],
    pw: &PackedWeights,
    ps: f32,
    ns: f32,
    dx: &mut [f32],
    n: usize,
) {
    let (k, o) = (pw.k, pw.o);
    let full = o / 4;
    let rem = o % 4;
    let symmetric = ps.to_bits() == ns.to_bits();
    let slut = byte_expand_lut(1.0, -1.0);
    let plut = byte_expand_lut(1.0, 0.0);
    let nlut = byte_expand_lut(0.0, 1.0);
    for i in 0..n {
        let grow = &g[i * o..(i + 1) * o];
        let drow = &mut dx[i * k..(i + 1) * k];
        for (kk, dv) in drow.iter_mut().enumerate() {
            let wrow = pw.row(kk);
            if symmetric {
                let mut a = [0f32; 4];
                for (g4, &byte) in grow.chunks_exact(4).zip(wrow) {
                    let lane = &slut[byte as usize];
                    a[0] += g4[0] * lane[0];
                    a[1] += g4[1] * lane[1];
                    a[2] += g4[2] * lane[2];
                    a[3] += g4[3] * lane[3];
                }
                if rem != 0 {
                    let lane = &slut[wrow[full] as usize];
                    for (j, &gv) in grow[full * 4..].iter().enumerate() {
                        a[j] += gv * lane[j];
                    }
                }
                *dv = ps * ((a[0] + a[1]) + (a[2] + a[3]));
            } else {
                let mut pa = [0f32; 4];
                let mut na = [0f32; 4];
                for (g4, &byte) in grow.chunks_exact(4).zip(wrow) {
                    let pl = &plut[byte as usize];
                    let nl = &nlut[byte as usize];
                    pa[0] += g4[0] * pl[0];
                    pa[1] += g4[1] * pl[1];
                    pa[2] += g4[2] * pl[2];
                    pa[3] += g4[3] * pl[3];
                    na[0] += g4[0] * nl[0];
                    na[1] += g4[1] * nl[1];
                    na[2] += g4[2] * nl[2];
                    na[3] += g4[3] * nl[3];
                }
                if rem != 0 {
                    let pl = &plut[wrow[full] as usize];
                    let nl = &nlut[wrow[full] as usize];
                    for (j, &gv) in grow[full * 4..].iter().enumerate() {
                        pa[j] += gv * pl[j];
                        na[j] += gv * nl[j];
                    }
                }
                *dv = ps * ((pa[0] + pa[1]) + (pa[2] + pa[3]))
                    - ns * ((na[0] + na[1]) + (na[2] + na[3]));
            }
        }
    }
}

/// Packed-ternary input-gradient GEMM. Row-parallel; bit-identical to
/// [`packed_grad_input_naive`] at any `policy`.
pub fn packed_grad_input(
    g: &[f32],
    pw: &PackedWeights,
    ps: f32,
    ns: f32,
    dx: &mut [f32],
    n: usize,
    policy: &KernelPolicy,
) {
    if policy.naive {
        return packed_grad_input_naive(g, pw, ps, ns, dx, n);
    }
    let (k, o) = (pw.k, pw.o);
    let threads = effective_threads(policy.threads, n, n * k * o);
    if threads <= 1 {
        return packed_grad_input_block(g, pw, ps, ns, dx, n);
    }
    let bounds = split_rows(n, threads);
    let chunks: Vec<Vec<f32>> = parallel_map_indexed(bounds.len(), threads, |bi| {
        let (lo, hi) = bounds[bi];
        let mut chunk = vec![0f32; (hi - lo) * k];
        packed_grad_input_block(&g[lo * o..hi * o], pw, ps, ns, &mut chunk, hi - lo);
        chunk
    });
    for ((lo, hi), chunk) in bounds.into_iter().zip(chunks) {
        dx[lo * k..hi * k].copy_from_slice(&chunk);
    }
}

// ---------------------------------------------------------------------------
// popcount / bit-slicing fast path for binary activations
// ---------------------------------------------------------------------------

/// Bit-sliced view of a [`PackedWeights`] matrix: per output column, one
/// positive and one negative bit-plane over `k` (`u64` words). For
/// `x ∈ {0, 1}` rows — the sparse post-ReLU-of-binarized case — the
/// matmul degenerates to `popcount(x & plane)`, 64 MACs per instruction.
///
/// Counts are exact integers (any `k < 2^24` is exactly representable in
/// f32), so [`BitPlanes::matvec_binary`] reproduces the dual-accumulator
/// branch of [`packed_gemm_bias_naive`] bit for bit on binary input.
pub struct BitPlanes {
    /// input dimension
    pub k: usize,
    /// output dimension
    pub o: usize,
    words: usize,
    pos: Vec<u64>,
    neg: Vec<u64>,
}

impl BitPlanes {
    /// Slice a packed matrix into per-column sign planes.
    pub fn from_packed(pw: &PackedWeights) -> BitPlanes {
        let words = pw.k.div_ceil(64);
        let mut pos = vec![0u64; pw.o * words];
        let mut neg = vec![0u64; pw.o * words];
        for kk in 0..pw.k {
            let wrow = pw.row(kk);
            let bit = 1u64 << (kk % 64);
            let word = kk / 64;
            for oo in 0..pw.o {
                match cell_code(wrow, oo) {
                    0b01 => pos[oo * words + word] |= bit,
                    0b10 => neg[oo * words + word] |= bit,
                    _ => {}
                }
            }
        }
        BitPlanes { k: pw.k, o: pw.o, words, pos, neg }
    }

    /// `out[o] = b[o] + (ps * pos_count - ns * neg_count)` for one binary
    /// activation row packed by [`pack_activation_bits`].
    pub fn matvec_binary(&self, xbits: &[u64], b: &[f32], ps: f32, ns: f32, out: &mut [f32]) {
        assert_eq!(xbits.len(), self.words);
        assert_eq!(out.len(), self.o);
        for (oo, ov) in out.iter_mut().enumerate() {
            let pp = &self.pos[oo * self.words..(oo + 1) * self.words];
            let np = &self.neg[oo * self.words..(oo + 1) * self.words];
            let mut pc = 0u32;
            let mut nc = 0u32;
            for ((&xw, &pv), &nv) in xbits.iter().zip(pp).zip(np) {
                pc += (xw & pv).count_ones();
                nc += (xw & nv).count_ones();
            }
            *ov = b[oo] + (ps * pc as f32 - ns * nc as f32);
        }
    }
}

/// Pack a `{0, 1}`-valued activation row into a bitmask (bit `k` set iff
/// `x[k] != 0`), the input side of [`BitPlanes::matvec_binary`].
pub fn pack_activation_bits(x: &[f32]) -> Vec<u64> {
    let mut out = vec![0u64; x.len().div_ceil(64)];
    for (kk, &xv) in x.iter().enumerate() {
        if xv != 0.0 {
            out[kk / 64] |= 1 << (kk % 64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(rng: &mut Pcg, n: usize, sparse: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let v = rng.normal();
                // exercise the zero-skip paths like ReLU activations do
                if sparse && v < 0.0 {
                    0.0
                } else {
                    v
                }
            })
            .collect()
    }

    fn trits(rng: &mut Pcg, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.below(3) as i8 - 1).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gemm_bias_matches_naive_at_any_thread_count() {
        let mut rng = Pcg::seeded(1);
        for &(n, k, o) in &[(1usize, 5usize, 3usize), (7, 33, 65), (64, 130, 64), (13, 784, 30)] {
            let x = randn(&mut rng, n * k, true);
            let w = randn(&mut rng, k * o, false);
            let b = randn(&mut rng, o, false);
            let mut want = vec![0f32; n * o];
            gemm_bias_naive(&x, &w, &b, &mut want, n, k, o);
            for threads in [1, 2, 3, 8] {
                let mut got = vec![0f32; n * o];
                gemm_bias(&x, &w, &b, &mut got, n, k, o, &KernelPolicy::threaded(threads));
                assert_eq!(bits(&want), bits(&got), "n={n} k={k} o={o} threads={threads}");
            }
        }
    }

    #[test]
    fn grad_weights_matches_naive_at_any_thread_count() {
        let mut rng = Pcg::seeded(2);
        for &(n, k, o) in &[(1usize, 4usize, 2usize), (9, 65, 31), (64, 129, 66)] {
            let a = randn(&mut rng, n * k, true);
            let g = randn(&mut rng, n * o, true);
            let mut dw_want = vec![0f32; k * o];
            let mut db_want = vec![0f32; o];
            grad_weights_naive(&a, &g, &mut dw_want, &mut db_want, n, k, o);
            for threads in [1, 2, 5] {
                let mut dw = vec![0f32; k * o];
                let mut db = vec![0f32; o];
                let mut scratch = Vec::new();
                grad_weights(
                    &a,
                    &g,
                    &mut dw,
                    &mut db,
                    n,
                    k,
                    o,
                    &KernelPolicy::threaded(threads),
                    &mut scratch,
                );
                assert_eq!(bits(&dw_want), bits(&dw), "dw n={n} k={k} o={o} t={threads}");
                assert_eq!(bits(&db_want), bits(&db), "db n={n} k={k} o={o} t={threads}");
            }
        }
    }

    #[test]
    fn grad_input_matches_naive_at_any_thread_count() {
        let mut rng = Pcg::seeded(3);
        for &(n, k, o) in &[(2usize, 3usize, 4usize), (11, 70, 29), (64, 256, 64)] {
            let g = randn(&mut rng, n * o, true);
            let w = randn(&mut rng, k * o, false);
            let mut want = vec![0f32; n * k];
            grad_input_naive(&g, &w, &mut want, n, k, o);
            for threads in [1, 2, 7] {
                let mut got = vec![0f32; n * k];
                let mut scratch = Vec::new();
                grad_input(
                    &g,
                    &w,
                    &mut got,
                    n,
                    k,
                    o,
                    &KernelPolicy::threaded(threads),
                    &mut scratch,
                );
                assert_eq!(bits(&want), bits(&got), "n={n} k={k} o={o} t={threads}");
            }
        }
    }

    #[test]
    fn packed_gemm_matches_its_oracle_at_any_thread_count() {
        let mut rng = Pcg::seeded(4);
        // shapes that hit o % 4 != 0 padding, o < 4, and multi-block o
        for &(n, k, o) in &[(1usize, 5usize, 3usize), (7, 33, 65), (13, 784, 30), (64, 130, 66)] {
            let x = randn(&mut rng, n * k, true);
            let b = randn(&mut rng, o, false);
            let pw = PackedWeights::from_pattern(&trits(&mut rng, k * o), k, o);
            for &(ps, ns) in &[(0.05f32, 0.05f32), (0.04, 0.07)] {
                let mut want = vec![0f32; n * o];
                packed_gemm_bias_naive(&x, &pw, &b, ps, ns, &mut want, n);
                for threads in [1, 2, 3, 8] {
                    let mut got = vec![0f32; n * o];
                    packed_gemm_bias(
                        &x,
                        &pw,
                        &b,
                        ps,
                        ns,
                        &mut got,
                        n,
                        &KernelPolicy::packed(threads),
                    );
                    assert_eq!(
                        bits(&want),
                        bits(&got),
                        "n={n} k={k} o={o} t={threads} ps={ps} ns={ns}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_grad_input_matches_its_oracle_at_any_thread_count() {
        let mut rng = Pcg::seeded(5);
        for &(n, k, o) in &[(2usize, 3usize, 5usize), (11, 70, 29), (64, 256, 66)] {
            let g = randn(&mut rng, n * o, true);
            let pw = PackedWeights::from_pattern(&trits(&mut rng, k * o), k, o);
            for &(ps, ns) in &[(0.05f32, 0.05f32), (0.04, 0.07)] {
                let mut want = vec![0f32; n * k];
                packed_grad_input_naive(&g, &pw, ps, ns, &mut want, n);
                for threads in [1, 2, 7] {
                    let mut got = vec![0f32; n * k];
                    packed_grad_input(
                        &g,
                        &pw,
                        ps,
                        ns,
                        &mut got,
                        n,
                        &KernelPolicy::packed(threads),
                    );
                    assert_eq!(
                        bits(&want),
                        bits(&got),
                        "n={n} k={k} o={o} t={threads} ps={ps} ns={ns}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_gemm_agrees_with_dense_gemm_on_effective_weights() {
        // not bit-identical (different contracts) but numerically tight
        let mut rng = Pcg::seeded(6);
        let (n, k, o) = (5usize, 37usize, 18usize);
        let x = randn(&mut rng, n * k, true);
        let b = randn(&mut rng, o, false);
        let it = trits(&mut rng, k * o);
        let (ps, ns) = (0.04f32, 0.07f32);
        let w: Vec<f32> = it
            .iter()
            .map(|&s| match s {
                1 => ps,
                -1 => -ns,
                _ => 0.0,
            })
            .collect();
        let pw = PackedWeights::from_pattern(&it, k, o);
        let mut dense = vec![0f32; n * o];
        gemm_bias_naive(&x, &w, &b, &mut dense, n, k, o);
        let mut packed = vec![0f32; n * o];
        packed_gemm_bias_naive(&x, &pw, &b, ps, ns, &mut packed, n);
        for (d, p) in dense.iter().zip(&packed) {
            assert!((d - p).abs() < 1e-4, "dense={d} packed={p}");
        }
    }

    #[test]
    fn popcount_matvec_matches_packed_oracle_on_binary_rows() {
        let mut rng = Pcg::seeded(7);
        for &(k, o) in &[(5usize, 3usize), (130, 66), (784, 30)] {
            let x: Vec<f32> = (0..k).map(|_| (rng.below(2)) as f32).collect();
            let b = randn(&mut rng, o, false);
            let pw = PackedWeights::from_pattern(&trits(&mut rng, k * o), k, o);
            // asymmetric scales force the oracle's dual pos/neg branch,
            // which is the expression popcount reproduces exactly
            let (ps, ns) = (0.04f32, 0.07f32);
            let mut want = vec![0f32; o];
            packed_gemm_bias_naive(&x, &pw, &b, ps, ns, &mut want, 1);
            let planes = BitPlanes::from_packed(&pw);
            let xbits = pack_activation_bits(&x);
            let mut got = vec![0f32; o];
            planes.matvec_binary(&xbits, &b, ps, ns, &mut got);
            assert_eq!(bits(&want), bits(&got), "k={k} o={o}");
        }
    }

    #[test]
    fn kernel_policy_parses_tier_specs() {
        assert_eq!(KernelPolicy::parse("naive").unwrap(), KernelPolicy::reference());
        assert_eq!(KernelPolicy::parse("blocked").unwrap(), KernelPolicy::threaded(1));
        assert_eq!(KernelPolicy::parse("blocked:4").unwrap(), KernelPolicy::threaded(4));
        assert_eq!(KernelPolicy::parse("packed").unwrap(), KernelPolicy::packed(1));
        assert_eq!(KernelPolicy::parse("packed:2").unwrap(), KernelPolicy::packed(2));
        assert_eq!(
            KernelPolicy::parse("packed-naive").unwrap(),
            KernelPolicy::packed_reference()
        );
        for bad in ["", "simd", "blocked:0", "blocked:x", "packed:99999", "naive:2"] {
            assert!(KernelPolicy::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn split_rows_partitions_exactly() {
        for (n, parts) in [(10usize, 3usize), (3, 8), (1, 1), (0, 4), (64, 4)] {
            let b = split_rows(n, parts);
            assert_eq!(b.first().map(|r| r.0).unwrap_or(0), 0);
            assert_eq!(b.last().map(|r| r.1).unwrap_or(0), n);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].0 < w[0].1);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let m: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut t = Vec::new();
        transpose_into(&m, 3, 4, &mut t);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // t[c=0, r=1] = m[r=1, c=0]
        let mut back = Vec::new();
        transpose_into(&t, 4, 3, &mut back);
        assert_eq!(back, m);
    }

    #[test]
    fn packed_weights_rows_are_byte_aligned() {
        let it = trits(&mut Pcg::seeded(8), 3 * 5);
        let pw = PackedWeights::from_pattern(&it, 3, 5);
        assert_eq!(pw.row_bytes, 2);
        assert_eq!(pw.bytes.len(), 6);
        for kk in 0..3 {
            for oo in 0..5 {
                let code = cell_code(pw.row(kk), oo);
                let want = match it[kk * 5 + oo] {
                    1 => 0b01,
                    -1 => 0b10,
                    _ => 0b00,
                };
                assert_eq!(code, want, "kk={kk} oo={oo}");
            }
            // padding lanes in the trailing byte stay zero
            for oo in 5..8 {
                assert_eq!(cell_code(pw.row(kk), oo), 0, "kk={kk} pad oo={oo}");
            }
        }
    }
}
