//! Wire format + byte accounting for the federated message layer.
//!
//! The paper's headline claim (Table IV, ~16x compression of both upstream
//! and downstream) lives here: T-FedAvg messages carry 2-bit-packed ternary
//! weight patterns + one f32 `w^q` per layer, FedAvg messages carry raw f32
//! tensors. Every serialized byte that would cross the network is counted
//! by the in-process message bus, so the Table-IV bench measures *actual*
//! payload sizes, not analytic estimates.

pub mod codec;
pub mod messages;

pub use codec::{pack_ternary, unpack_dequantize, unpack_ternary, PackedTernary};
pub use messages::*;
