//! Wire format + byte accounting for the federated message layer.
//!
//! The paper's headline claim (Table IV, ~16x compression of both upstream
//! and downstream) lives here: T-FedAvg messages carry 2-bit-packed ternary
//! weight patterns + one f32 `w^q` per layer, FedAvg messages carry raw f32
//! tensors, and the generic `Coded*` messages carry any registered
//! `compress` codec's opaque payload behind a codec-id header. Every
//! serialized byte that would cross the network is counted at the
//! transport frame layer, so the Table-IV bench measures *actual* payload
//! sizes, not analytic estimates.
//!
//! The ternary pack/unpack primitives moved to `compress::ternary` (the
//! codec registry's first implementation); they are re-exported here so
//! `comms::{pack_ternary, ...}` callers keep working.

pub mod messages;

pub use crate::compress::ternary::{
    pack_ternary, unpack_dequantize, unpack_ternary, PackedTernary,
};
pub use messages::*;
