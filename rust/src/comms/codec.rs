//! 2-bit ternary packing: 4 trits per byte.
//!
//! Encoding per 2-bit cell: 00 -> 0, 01 -> +1, 10 -> -1 (11 unused). The
//! upstream/downstream payload for one layer of n weights is
//! ceil(n/4) bytes — 1/16 of the 4n bytes FedAvg ships, matching the
//! paper's §III-B arithmetic.

use anyhow::{bail, Result};

/// A packed ternary tensor (one layer's sign pattern).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTernary {
    pub len: usize,
    pub bytes: Vec<u8>,
}

impl PackedTernary {
    pub fn payload_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[inline]
fn encode_trit(s: i8) -> u8 {
    match s {
        0 => 0b00,
        1 => 0b01,
        -1 => 0b10,
        _ => unreachable!("non-ternary value {s}"),
    }
}

#[inline]
fn decode_trit(b: u8) -> Result<i8> {
    match b {
        0b00 => Ok(0),
        0b01 => Ok(1),
        0b10 => Ok(-1),
        _ => bail!("invalid trit encoding 0b11"),
    }
}

/// Pack a sign pattern ({-1, 0, +1} as i8) into 2-bit cells.
pub fn pack_ternary(it: &[i8]) -> PackedTernary {
    let mut bytes = vec![0u8; it.len().div_ceil(4)];
    for (i, &s) in it.iter().enumerate() {
        bytes[i / 4] |= encode_trit(s) << ((i % 4) * 2);
    }
    PackedTernary { len: it.len(), bytes }
}

/// Unpack back to the sign pattern; validates cell encoding.
pub fn unpack_ternary(p: &PackedTernary) -> Result<Vec<i8>> {
    if p.bytes.len() != p.len.div_ceil(4) {
        bail!("packed length {} inconsistent with len {}", p.bytes.len(), p.len);
    }
    let mut out = Vec::with_capacity(p.len);
    for i in 0..p.len {
        let cell = (p.bytes[i / 4] >> ((i % 4) * 2)) & 0b11;
        out.push(decode_trit(cell)?);
    }
    // trailing cells of the last byte must be zero-padded
    if p.len % 4 != 0 {
        let last = p.bytes[p.bytes.len() - 1];
        let used = (p.len % 4) * 2;
        if last >> used != 0 {
            bail!("non-zero padding bits in final byte");
        }
    }
    Ok(out)
}

/// A 2-bit cell is the invalid encoding 0b11 iff both of its bits are set;
/// `b & (b >> 1)` lines those up on the low bit of each cell.
#[inline]
fn has_invalid_cell(b: u8) -> bool {
    b & (b >> 1) & 0b0101_0101 != 0
}

/// Unpack directly to dense f32 weights (wq * it) without the i8 hop —
/// the hot-path variant used when materializing a downloaded model.
///
/// Validity is checked up front with a per-byte bit trick (no post-hoc NaN
/// scan), then the body is a straight 256-entry x 4-lane table copy: one
/// LUT row per byte value replaces the per-element shift/mask loop.
pub fn unpack_dequantize(p: &PackedTernary, wq: f32) -> Result<Vec<f32>> {
    if p.bytes.len() != p.len.div_ceil(4) {
        bail!("packed length {} inconsistent with len {}", p.bytes.len(), p.len);
    }
    // up-front 0b11-cell check; the tail byte is masked to its used cells
    // (padding stays the concern of unpack_ternary's strict path)
    let full_bytes = p.len / 4;
    if p.bytes[..full_bytes].iter().any(|&b| has_invalid_cell(b)) {
        bail!("invalid trit encoding 0b11");
    }
    let rem = p.len % 4;
    if rem != 0 {
        let used_mask = (1u8 << (rem * 2)) - 1;
        if has_invalid_cell(p.bytes[full_bytes] & used_mask) {
            bail!("invalid trit encoding 0b11");
        }
    }

    let cell = [0.0f32, wq, -wq, 0.0];
    let mut out = Vec::with_capacity(p.len);

    // below this size the 1024-entry LUT fill would cost more than the
    // unpack itself (e.g. the MLP's bias-sized layers): use the 4-entry
    // cell table directly
    if p.len < 4096 {
        for &b in &p.bytes[..full_bytes] {
            out.push(cell[(b & 3) as usize]);
            out.push(cell[((b >> 2) & 3) as usize]);
            out.push(cell[((b >> 4) & 3) as usize]);
            out.push(cell[((b >> 6) & 3) as usize]);
        }
        if rem != 0 {
            let b = p.bytes[full_bytes];
            for lane in 0..rem {
                out.push(cell[((b >> (2 * lane)) & 3) as usize]);
            }
        }
        return Ok(out);
    }

    // 256-entry x 4-lane per-byte LUT (the 0b11 lane is unreachable after
    // the validity check; 0.0 keeps the table total)
    let mut lut = [[0.0f32; 4]; 256];
    for (b, row) in lut.iter_mut().enumerate() {
        for (lane, v) in row.iter_mut().enumerate() {
            *v = cell[(b >> (2 * lane)) & 3];
        }
    }
    for &b in &p.bytes[..full_bytes] {
        out.extend_from_slice(&lut[b as usize]);
    }
    if rem != 0 {
        out.extend_from_slice(&lut[p.bytes[full_bytes] as usize][..rem]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn roundtrip_small() {
        for pattern in [
            vec![],
            vec![0i8],
            vec![1, -1, 0],
            vec![1, 1, 1, 1],
            vec![-1, 0, 1, -1, 0],
        ] {
            let p = pack_ternary(&pattern);
            assert_eq!(unpack_ternary(&p).unwrap(), pattern);
        }
    }

    #[test]
    fn roundtrip_property() {
        forall(128, |rng| {
            let n = rng.below(4096) as usize;
            let it: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
            let p = pack_ternary(&it);
            assert_eq!(p.payload_bytes(), n.div_ceil(4));
            assert_eq!(unpack_ternary(&p).unwrap(), it);
        });
    }

    #[test]
    fn sixteen_x_compression() {
        // paper §III-B: 2-bit vs 32-bit => 16x on the weight payload
        let n = 24_380; // MLP parameter count
        let it = vec![1i8; n];
        let p = pack_ternary(&it);
        let fp32 = n * 4;
        let ratio = fp32 as f64 / p.payload_bytes() as f64;
        assert!((ratio - 16.0).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn dequantize_matches_unpack() {
        forall(64, |rng| {
            let n = rng.below(1000) as usize;
            let it: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
            let wq = rng.next_f32() + 0.01;
            let p = pack_ternary(&it);
            let dense = unpack_dequantize(&p, wq).unwrap();
            let via_i8: Vec<f32> =
                unpack_ternary(&p).unwrap().iter().map(|&s| wq * s as f32).collect();
            assert_eq!(dense, via_i8);
        });
    }

    #[test]
    fn rejects_corrupt_encoding() {
        let mut p = pack_ternary(&[1, 1, 1, 1]);
        p.bytes[0] = 0xFF; // 0b11 cells
        assert!(unpack_ternary(&p).is_err());
        assert!(unpack_dequantize(&p, 1.0).is_err());
    }

    #[test]
    fn rejects_bad_length() {
        let p = PackedTernary { len: 10, bytes: vec![0; 1] };
        assert!(unpack_ternary(&p).is_err());
    }

    #[test]
    fn rejects_dirty_padding() {
        let mut p = pack_ternary(&[1, 1, 1]);
        p.bytes[0] |= 0b01 << 6; // set the unused 4th cell
        assert!(unpack_ternary(&p).is_err());
    }
}
