//! Federated protocol messages + binary serialization + byte accounting.
//!
//! The serialization is hand-rolled (offline: no serde/bincode): little-
//! endian, length-prefixed, with a 4-byte magic + kind tag. The coordinator
//! never inspects raw bytes — it serializes, counts, and deserializes at
//! the client/server boundary, exactly like a real network path would.

use anyhow::{bail, Result};

use crate::compress::ternary::{pack_ternary, unpack_ternary, PackedTernary};
use crate::compress::{CodecSpec, CompressedUpdate};
use crate::model::ParamSet;
use crate::model::Tensor;

const MAGIC: u32 = 0x5446_4544; // "TFED"

/// Upstream payload from one T-FedAvg client (Algorithm 2, upload step):
/// per quantized layer a packed ternary pattern + trained w^q + the
/// threshold Delta; biases ride along as f32.
#[derive(Clone, Debug, PartialEq)]
pub struct TernaryUpdate {
    pub client_id: u32,
    pub num_samples: u64,
    pub layers: Vec<TernaryLayer>,
    /// full-precision (non-quantized) tensors, positionally indexed
    pub fp_tensors: Vec<(u32, Vec<f32>)>,
    pub train_loss: f32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TernaryLayer {
    /// index into the model's parameter list
    pub param_index: u32,
    pub pattern: PackedTernary,
    pub wq: f32,
    pub delta: f32,
}

/// Upstream payload from one FedAvg client: full f32 parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseUpdate {
    pub client_id: u32,
    pub num_samples: u64,
    pub tensors: Vec<Vec<f32>>,
    pub train_loss: f32,
}

/// Downstream broadcast, T-FedAvg: ternary global model + f32 biases +
/// the per-layer w^q init for the next round (Algorithm 2 leaves the
/// "initialize w^q" rule open; we broadcast the aggregated mean of the
/// previous round's trained factors — L extra f32s, counted in the payload).
#[derive(Clone, Debug, PartialEq)]
pub struct TernaryGlobal {
    pub round: u32,
    pub layers: Vec<(u32, PackedTernary)>,
    pub fp_tensors: Vec<(u32, Vec<f32>)>,
    pub wq_init: Vec<f32>,
}

/// Downstream broadcast, FedAvg: full f32 global model.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseGlobal {
    pub round: u32,
    pub tensors: Vec<Vec<f32>>,
}

/// Upstream payload from a client running a registry codec (fp16, quant,
/// stc, ...): the codec's opaque per-tensor blobs behind its wire id.
#[derive(Clone, Debug, PartialEq)]
pub struct CodedUpdate {
    pub client_id: u32,
    pub num_samples: u64,
    pub train_loss: f32,
    pub update: CompressedUpdate,
}

/// Downstream broadcast under a registry codec.
#[derive(Clone, Debug, PartialEq)]
pub struct CodedGlobal {
    pub round: u32,
    pub update: CompressedUpdate,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    TernaryUpdate(TernaryUpdate),
    DenseUpdate(DenseUpdate),
    TernaryGlobal(TernaryGlobal),
    DenseGlobal(DenseGlobal),
    CodedUpdate(CodedUpdate),
    CodedGlobal(CodedGlobal),
}

impl Message {
    pub fn kind(&self) -> u8 {
        match self {
            Message::TernaryUpdate(_) => 1,
            Message::DenseUpdate(_) => 2,
            Message::TernaryGlobal(_) => 3,
            Message::DenseGlobal(_) => 4,
            Message::CodedUpdate(_) => 5,
            Message::CodedGlobal(_) => 6,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u8(self.kind());
        match self {
            Message::TernaryUpdate(m) => {
                w.u32(m.client_id);
                w.u64(m.num_samples);
                w.f32(m.train_loss);
                w.u32(m.layers.len() as u32);
                for l in &m.layers {
                    w.u32(l.param_index);
                    w.f32(l.wq);
                    w.f32(l.delta);
                    w.packed(&l.pattern);
                }
                w.fp_tensors(&m.fp_tensors);
            }
            Message::DenseUpdate(m) => {
                w.u32(m.client_id);
                w.u64(m.num_samples);
                w.f32(m.train_loss);
                w.u32(m.tensors.len() as u32);
                for t in &m.tensors {
                    w.f32s(t);
                }
            }
            Message::TernaryGlobal(m) => {
                w.u32(m.round);
                w.u32(m.layers.len() as u32);
                for (i, p) in &m.layers {
                    w.u32(*i);
                    w.packed(p);
                }
                w.fp_tensors(&m.fp_tensors);
                w.f32s(&m.wq_init);
            }
            Message::DenseGlobal(m) => {
                w.u32(m.round);
                w.u32(m.tensors.len() as u32);
                for t in &m.tensors {
                    w.f32s(t);
                }
            }
            Message::CodedUpdate(m) => {
                w.u32(m.client_id);
                w.u64(m.num_samples);
                w.f32(m.train_loss);
                w.compressed(&m.update);
            }
            Message::CodedGlobal(m) => {
                w.u32(m.round);
                w.compressed(&m.update);
            }
        }
        w.out
    }

    pub fn decode(bytes: &[u8]) -> Result<Message> {
        let mut r = Reader { b: bytes, i: 0 };
        if r.u32()? != MAGIC {
            bail!("bad magic");
        }
        let kind = r.u8()?;
        let msg = match kind {
            1 => {
                let client_id = r.u32()?;
                let num_samples = r.u64()?;
                let train_loss = r.f32()?;
                let n = r.count(16)?;
                let mut layers = Vec::with_capacity(n);
                for _ in 0..n {
                    let param_index = r.u32()?;
                    let wq = r.f32()?;
                    let delta = r.f32()?;
                    let pattern = r.packed()?;
                    layers.push(TernaryLayer { param_index, pattern, wq, delta });
                }
                let fp_tensors = r.fp_tensors()?;
                Message::TernaryUpdate(TernaryUpdate {
                    client_id,
                    num_samples,
                    layers,
                    fp_tensors,
                    train_loss,
                })
            }
            2 => {
                let client_id = r.u32()?;
                let num_samples = r.u64()?;
                let train_loss = r.f32()?;
                let n = r.count(4)?;
                let mut tensors = Vec::with_capacity(n);
                for _ in 0..n {
                    tensors.push(r.f32s()?);
                }
                Message::DenseUpdate(DenseUpdate { client_id, num_samples, tensors, train_loss })
            }
            3 => {
                let round = r.u32()?;
                let n = r.count(9)?;
                let mut layers = Vec::with_capacity(n);
                for _ in 0..n {
                    let i = r.u32()?;
                    layers.push((i, r.packed()?));
                }
                let fp_tensors = r.fp_tensors()?;
                let wq_init = r.f32s()?;
                Message::TernaryGlobal(TernaryGlobal { round, layers, fp_tensors, wq_init })
            }
            4 => {
                let round = r.u32()?;
                let n = r.count(4)?;
                let mut tensors = Vec::with_capacity(n);
                for _ in 0..n {
                    tensors.push(r.f32s()?);
                }
                Message::DenseGlobal(DenseGlobal { round, tensors })
            }
            5 => {
                let client_id = r.u32()?;
                let num_samples = r.u64()?;
                let train_loss = r.f32()?;
                let update = r.compressed()?;
                Message::CodedUpdate(CodedUpdate { client_id, num_samples, train_loss, update })
            }
            6 => {
                let round = r.u32()?;
                let update = r.compressed()?;
                Message::CodedGlobal(CodedGlobal { round, update })
            }
            k => bail!("unknown message kind {k}"),
        };
        if r.i != bytes.len() {
            bail!("trailing bytes in message");
        }
        Ok(msg)
    }
}

/// Build a DenseUpdate straight from a ParamSet (FedAvg upstream).
pub fn dense_update(client_id: u32, num_samples: u64, params: &ParamSet,
                    train_loss: f32) -> DenseUpdate {
    DenseUpdate {
        client_id,
        num_samples,
        tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
        train_loss,
    }
}

/// Build a TernaryUpdate from ternary patterns + w^q + fp tensors.
#[allow(clippy::too_many_arguments)]
pub fn ternary_update(
    client_id: u32,
    num_samples: u64,
    quantized_idx: &[usize],
    patterns: &[Vec<i8>],
    wqs: &[f32],
    deltas: &[f32],
    params: &ParamSet,
    train_loss: f32,
) -> TernaryUpdate {
    let layers = quantized_idx
        .iter()
        .enumerate()
        .map(|(k, &i)| TernaryLayer {
            param_index: i as u32,
            pattern: pack_ternary(&patterns[k]),
            wq: wqs[k],
            delta: deltas[k],
        })
        .collect();
    let fp_tensors = params
        .tensors
        .iter()
        .enumerate()
        .filter(|(i, _)| !quantized_idx.contains(i))
        .map(|(i, t)| (i as u32, t.data.clone()))
        .collect();
    TernaryUpdate { client_id, num_samples, layers, fp_tensors, train_loss }
}

/// Rebuild a dense ParamSet from a TernaryUpdate (server, Algorithm 2:
/// "the server will rebuild all models received": theta = wq * it).
pub fn rebuild_update(update: &TernaryUpdate, shapes: &[Vec<usize>]) -> Result<ParamSet> {
    let mut tensors: Vec<Option<Tensor>> = vec![None; shapes.len()];
    for l in &update.layers {
        let i = l.param_index as usize;
        if i >= shapes.len() {
            bail!("update layer index {i} out of range ({} params)", shapes.len());
        }
        let it = unpack_ternary(&l.pattern)?;
        let data: Vec<f32> = it.iter().map(|&s| l.wq * s as f32).collect();
        tensors[i] = Some(Tensor::new(shapes[i].clone(), data)?);
    }
    for (i, data) in &update.fp_tensors {
        let i = *i as usize;
        if i >= shapes.len() {
            bail!("update tensor index {i} out of range ({} params)", shapes.len());
        }
        tensors[i] = Some(Tensor::new(shapes[i].clone(), data.clone())?);
    }
    let tensors: Result<Vec<Tensor>> = tensors
        .into_iter()
        .enumerate()
        .map(|(i, t)| t.ok_or_else(|| anyhow::anyhow!("missing tensor {i} in update")))
        .collect();
    Ok(ParamSet { tensors: tensors? })
}

// ---------------------------------------------------------------------------
// little-endian writer/reader (shared with transport::Ctrl payloads)
// ---------------------------------------------------------------------------

pub(crate) struct Writer {
    out: Vec<u8>,
}

impl Writer {
    pub(crate) fn new() -> Self {
        Writer { out: Vec::new() }
    }

    pub(crate) fn into_bytes(self) -> Vec<u8> {
        self.out
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.out.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f32(&mut self, v: f32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }

    /// Raw bytes, no length prefix (fixed-size fields like codec headers).
    pub(crate) fn bytes(&mut self, b: &[u8]) {
        self.out.extend_from_slice(b);
    }

    fn packed(&mut self, p: &PackedTernary) {
        self.u32(p.len as u32);
        self.u32(p.bytes.len() as u32);
        self.out.extend_from_slice(&p.bytes);
    }

    fn compressed(&mut self, u: &CompressedUpdate) {
        self.bytes(&u.codec.to_wire());
        self.u32(u.tensors.len() as u32);
        for t in &u.tensors {
            self.u32(t.len() as u32);
            self.out.extend_from_slice(t);
        }
    }

    fn fp_tensors(&mut self, ts: &[(u32, Vec<f32>)]) {
        self.u32(ts.len() as u32);
        for (i, t) in ts {
            self.u32(*i);
            self.f32s(t);
        }
    }
}

pub(crate) struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    /// All input consumed? (trailing-bytes checks at the frame boundary)
    pub(crate) fn exhausted(&self) -> bool {
        self.i == self.b.len()
    }

    /// Read a u32 length prefix and validate it against the bytes actually
    /// remaining, so a corrupt count can never trigger a huge allocation.
    fn count(&mut self, min_bytes_each: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.b.len() - self.i;
        if n.saturating_mul(min_bytes_each.max(1)) > remaining {
            bail!("length prefix {n} exceeds remaining {remaining} bytes");
        }
        Ok(n)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("message truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Raw bytes, no length prefix (fixed-size fields like codec headers).
    pub(crate) fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    fn packed(&mut self) -> Result<PackedTernary> {
        let len = self.u32()? as usize;
        let nb = self.count(1)?;
        if nb != len.div_ceil(4) {
            bail!("packed byte count {nb} inconsistent with len {len}");
        }
        Ok(PackedTernary { len, bytes: self.take(nb)?.to_vec() })
    }

    fn compressed(&mut self) -> Result<CompressedUpdate> {
        let codec = CodecSpec::from_wire(
            self.take(CodecSpec::WIRE_BYTES)?.try_into().unwrap(),
        )?;
        // each tensor entry is at least its 4-byte length prefix
        let n = self.count(4)?;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let nb = self.count(1)?;
            tensors.push(self.take(nb)?.to_vec());
        }
        Ok(CompressedUpdate { codec, tensors })
    }

    fn fp_tensors(&mut self) -> Result<Vec<(u32, Vec<f32>)>> {
        let n = self.count(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let i = self.u32()?;
            out.push((i, self.f32s()?));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::toy_schema;
    use crate::model::init_params;
    use crate::quant;
    use crate::util::proptest::forall;
    use crate::util::rng::Pcg;

    fn sample_ternary_update(seed: u64) -> (TernaryUpdate, ParamSet, Vec<Vec<usize>>) {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(seed);
        let params = init_params(&schema, &mut rng);
        let qidx = schema.quantized_indices();
        let mut patterns = Vec::new();
        let mut deltas = Vec::new();
        for &i in &qidx {
            let (it, d) = quant::fttq_quantize(&params.tensors[i].data, 0.05);
            patterns.push(it);
            deltas.push(d);
        }
        let wqs = vec![0.4, 0.6];
        let upd = ternary_update(7, 123, &qidx, &patterns, &wqs, &deltas, &params, 1.5);
        let shapes: Vec<Vec<usize>> = schema.params.iter().map(|p| p.shape.clone()).collect();
        (upd, params, shapes)
    }

    #[test]
    fn ternary_update_roundtrip() {
        let (upd, _, _) = sample_ternary_update(1);
        let bytes = Message::TernaryUpdate(upd.clone()).encode();
        match Message::decode(&bytes).unwrap() {
            Message::TernaryUpdate(got) => assert_eq!(got, upd),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn dense_update_roundtrip() {
        let schema = toy_schema();
        let mut rng = Pcg::seeded(2);
        let params = init_params(&schema, &mut rng);
        let upd = dense_update(3, 50, &params, 0.7);
        let bytes = Message::DenseUpdate(upd.clone()).encode();
        match Message::decode(&bytes).unwrap() {
            Message::DenseUpdate(got) => assert_eq!(got, upd),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn global_messages_roundtrip() {
        let (upd, params, _) = sample_ternary_update(3);
        let tg = TernaryGlobal {
            round: 9,
            layers: upd.layers.iter().map(|l| (l.param_index, l.pattern.clone())).collect(),
            fp_tensors: upd.fp_tensors.clone(),
            wq_init: vec![0.1, 0.2],
        };
        let bytes = Message::TernaryGlobal(tg.clone()).encode();
        assert_eq!(Message::decode(&bytes).unwrap(), Message::TernaryGlobal(tg));

        let dg = DenseGlobal {
            round: 2,
            tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
        };
        let bytes = Message::DenseGlobal(dg.clone()).encode();
        assert_eq!(Message::decode(&bytes).unwrap(), Message::DenseGlobal(dg));
    }

    #[test]
    fn rebuild_matches_dequantized_params() {
        let (upd, params, shapes) = sample_ternary_update(4);
        let rebuilt = rebuild_update(&upd, &shapes).unwrap();
        // biases identical
        assert_eq!(rebuilt.tensors[1].data, params.tensors[1].data);
        // quantized layers are wq * sign pattern
        for l in &upd.layers {
            let i = l.param_index as usize;
            let vals = &rebuilt.tensors[i].data;
            assert!(vals.iter().all(|&v| {
                (v - l.wq).abs() < 1e-6 || v == 0.0 || (v + l.wq).abs() < 1e-6
            }));
        }
    }

    #[test]
    fn ternary_message_is_much_smaller() {
        // paper §III-B: ternary payload ~ 1/16 of dense for the same model
        let (upd, params, _) = sample_ternary_update(5);
        let t_bytes = Message::TernaryUpdate(upd).encode().len();
        let d_bytes = Message::DenseUpdate(dense_update(0, 1, &params, 0.0)).encode().len();
        // toy model is tiny so overhead dominates less than 16x; just check
        // a real reduction plus the exact arithmetic on the weight payload
        assert!(t_bytes < d_bytes);
        let weight_elems = 12 + 6;
        let dense_payload = weight_elems * 4;
        let tern_payload = (12usize.div_ceil(4)) + (6usize.div_ceil(4));
        assert!(dense_payload as f64 / tern_payload as f64 > 14.0);
    }

    #[test]
    fn decode_rejects_corruption() {
        forall(32, |rng| {
            let (upd, _, _) = sample_ternary_update(rng.next_u64());
            let mut bytes = Message::TernaryUpdate(upd).encode();
            let pos = rng.below(bytes.len() as u32) as usize;
            bytes[pos] ^= 0xFF;
            // must not panic: either decodes to different content or errors
            let _ = Message::decode(&bytes);
            // truncation always errors
            let cut = rng.below(bytes.len() as u32) as usize;
            assert!(Message::decode(&bytes[..cut]).is_err() || cut == bytes.len());
        });
    }

    #[test]
    fn missing_tensor_detected() {
        let (mut upd, _, shapes) = sample_ternary_update(6);
        upd.fp_tensors.clear();
        assert!(rebuild_update(&upd, &shapes).is_err());
    }

    #[test]
    fn coded_messages_roundtrip_every_codec() {
        use crate::compress::{self, codec_names};
        let schema = toy_schema();
        let mut rng = Pcg::seeded(11);
        let params = init_params(&schema, &mut rng);
        for name in codec_names() {
            let codec = compress::build_named(name).unwrap();
            let update = compress::compress(codec.as_ref(), &params, &mut rng).unwrap();
            let up = CodedUpdate {
                client_id: 3,
                num_samples: 77,
                train_loss: 0.25,
                update: update.clone(),
            };
            let bytes = Message::CodedUpdate(up.clone()).encode();
            assert_eq!(Message::decode(&bytes).unwrap(), Message::CodedUpdate(up));
            let down = CodedGlobal { round: 4, update };
            let bytes = Message::CodedGlobal(down.clone()).encode();
            assert_eq!(Message::decode(&bytes).unwrap(), Message::CodedGlobal(down));
        }
    }

    #[test]
    fn coded_message_rejects_unknown_codec_id() {
        let up = CodedUpdate {
            client_id: 0,
            num_samples: 1,
            train_loss: 0.0,
            update: CompressedUpdate { codec: CodecSpec::Fp16, tensors: vec![vec![1, 2]] },
        };
        let mut bytes = Message::CodedUpdate(up).encode();
        // codec id sits right after magic(4) + kind(1) + client(4) + samples(8) + loss(4)
        bytes[21] = 250;
        assert!(Message::decode(&bytes).is_err());
    }
}
