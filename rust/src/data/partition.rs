//! Federated sharding: IID, Nc-class non-IID (Fig. 8/9), unbalanced beta
//! splits (Fig. 11, eq. 29), and Dirichlet(α) label skew (Hsu et al.
//! 2019, the standard federated non-IID benchmark the scenario engine
//! sweeps over).

use anyhow::{anyhow, bail, Result};

use crate::config::ExperimentConfig;
use crate::data::synth::Dataset;
use crate::util::rng::Pcg;
use crate::util::stats;

/// How to split a dataset across clients.
///
/// ```no_run
/// // (no_run: rustdoc test binaries don't inherit the xla rpath)
/// use tfed::data::partition::{partition, PartitionSpec};
/// use tfed::data::synth::SynthSpec;
///
/// let (train, _test) = SynthSpec::mnist_like(1_000, 100, 7).generate();
/// // Dirichlet(0.5) label skew over 10 clients
/// let part = partition(&train, &PartitionSpec::dirichlet(10, 0.5, 7)).unwrap();
/// assert!(part.is_exact_cover(train.len()));
/// assert!(part.shards.iter().all(|s| !s.is_empty()));
/// ```
#[derive(Clone, Debug)]
pub struct PartitionSpec {
    pub n_clients: usize,
    /// classes per client; == num_classes means IID (paper §V-A.3)
    pub nc: usize,
    /// unbalancedness ratio beta = median/max of client sizes (eq. 29);
    /// 1.0 = balanced
    pub beta: f64,
    /// Dirichlet label-skew concentration; 0.0 = disabled (use nc/beta).
    /// When > 0, each class's client quotas are drawn from
    /// Dirichlet(alpha · 1_N) and nc/beta are ignored.
    pub alpha: f64,
    pub seed: u64,
}

impl PartitionSpec {
    pub fn iid(n_clients: usize, seed: u64) -> Self {
        PartitionSpec { n_clients, nc: usize::MAX, beta: 1.0, alpha: 0.0, seed }
    }

    pub fn non_iid(n_clients: usize, nc: usize, seed: u64) -> Self {
        PartitionSpec { n_clients, nc, beta: 1.0, alpha: 0.0, seed }
    }

    pub fn unbalanced(n_clients: usize, beta: f64, seed: u64) -> Self {
        PartitionSpec { n_clients, nc: usize::MAX, beta, alpha: 0.0, seed }
    }

    pub fn dirichlet(n_clients: usize, alpha: f64, seed: u64) -> Self {
        PartitionSpec { n_clients, nc: usize::MAX, beta: 1.0, alpha, seed }
    }
}

/// A named partition regime — the scenario-manifest (and sweep-axis)
/// surface over [`PartitionSpec`]. Parsed from strings like `iid`,
/// `nc:2`, `beta:0.5`, `dirichlet:alpha=0.5`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PartitionStrategy {
    /// Shuffle-and-deal: every client sees every class.
    Iid,
    /// Each client holds `nc` classes (paper Fig. 8/9).
    NonIid { nc: usize },
    /// Geometric size profile with median/max = beta (paper Fig. 11).
    Unbalanced { beta: f64 },
    /// Dirichlet(alpha) label skew (Hsu et al. 2019).
    Dirichlet { alpha: f64 },
}

impl PartitionStrategy {
    /// Parse `iid` | `nc:<k>` | `beta:<b>` | `dirichlet:alpha=<a>`
    /// (also accepts `dirichlet:<a>`), validating parameters.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        let lower = s.to_ascii_lowercase();
        if lower == "iid" {
            return Ok(PartitionStrategy::Iid);
        }
        if let Some(v) = lower.strip_prefix("nc:") {
            let nc: usize = v.parse().map_err(|e| anyhow!("nc:{v}: {e}"))?;
            if nc == 0 {
                bail!("partition nc must be >= 1");
            }
            return Ok(PartitionStrategy::NonIid { nc });
        }
        if let Some(v) = lower.strip_prefix("beta:") {
            let beta: f64 = v.parse().map_err(|e| anyhow!("beta:{v}: {e}"))?;
            if !(beta > 0.0 && beta <= 1.0) {
                bail!("partition beta must be in (0, 1], got {beta}");
            }
            return Ok(PartitionStrategy::Unbalanced { beta });
        }
        if let Some(v) = lower.strip_prefix("dirichlet:") {
            let v = v.strip_prefix("alpha=").unwrap_or(v);
            let alpha: f64 = v.parse().map_err(|e| anyhow!("dirichlet:{v}: {e}"))?;
            if !(alpha > 0.0 && alpha.is_finite()) {
                bail!("dirichlet alpha must be positive and finite, got {alpha}");
            }
            return Ok(PartitionStrategy::Dirichlet { alpha });
        }
        bail!("unknown partition strategy {s:?} (iid | nc:<k> | beta:<b> | dirichlet:alpha=<a>)")
    }

    /// Canonical name, parseable by [`Self::parse`].
    pub fn name(&self) -> String {
        match self {
            PartitionStrategy::Iid => "iid".into(),
            PartitionStrategy::NonIid { nc } => format!("nc:{nc}"),
            PartitionStrategy::Unbalanced { beta } => format!("beta:{beta}"),
            PartitionStrategy::Dirichlet { alpha } => format!("dirichlet:alpha={alpha}"),
        }
    }

    /// Write this regime into an experiment config (the same fields the
    /// `--nc` / `--beta` / `--alpha` CLI flags set, so a manifest cell and
    /// the equivalent flag-driven invocation are byte-identical).
    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        let (nc, beta, alpha) = match *self {
            PartitionStrategy::Iid => (10, 1.0, 0.0),
            PartitionStrategy::NonIid { nc } => (nc, 1.0, 0.0),
            PartitionStrategy::Unbalanced { beta } => (10, beta, 0.0),
            PartitionStrategy::Dirichlet { alpha } => (10, 1.0, alpha),
        };
        cfg.nc = nc;
        cfg.beta = beta;
        cfg.dirichlet_alpha = alpha;
    }
}

/// One client's local data: indices into the shared dataset.
#[derive(Clone, Debug)]
pub struct ClientShard {
    pub client_id: usize,
    pub indices: Vec<u32>,
}

impl ClientShard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn class_histogram(&self, data: &Dataset) -> Vec<usize> {
        let mut h = vec![0usize; data.num_classes];
        for &i in &self.indices {
            h[data.labels[i as usize] as usize] += 1;
        }
        h
    }
}

/// The result of sharding a dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<ClientShard>,
}

impl Partition {
    pub fn sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.len()).collect()
    }

    /// Measured unbalancedness (eq. 29) of this partition.
    pub fn beta(&self) -> f64 {
        stats::unbalancedness(&self.sizes())
    }

    /// Every sample must be assigned exactly once.
    pub fn is_exact_cover(&self, n: usize) -> bool {
        let mut seen = vec![false; n];
        for s in &self.shards {
            for &i in &s.indices {
                if seen[i as usize] {
                    return false;
                }
                seen[i as usize] = true;
            }
        }
        seen.iter().all(|&b| b)
    }
}

/// Target client sizes for a given beta: geometric profile
/// size_i = max * beta^(2i / (N-1)), normalized to sum to `total`.
/// By construction median/max ~= beta.
pub fn unbalanced_sizes(total: usize, n_clients: usize, beta: f64) -> Vec<usize> {
    assert!(n_clients > 0);
    assert!((0.0..=1.0).contains(&beta) && beta > 0.0, "beta in (0, 1]");
    if n_clients == 1 {
        return vec![total];
    }
    let raw: Vec<f64> = (0..n_clients)
        .map(|i| beta.powf(2.0 * i as f64 / (n_clients as f64 - 1.0)))
        .collect();
    let s: f64 = raw.iter().sum();
    let mut sizes: Vec<usize> = raw
        .iter()
        .map(|r| ((r / s) * total as f64).floor().max(1.0) as usize)
        .collect();
    // distribute the remainder deterministically to the largest clients
    let mut assigned: usize = sizes.iter().sum();
    let mut i = 0;
    while assigned < total {
        sizes[i % n_clients] += 1;
        assigned += 1;
        i += 1;
    }
    while assigned > total {
        let j = sizes
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .map(|(j, _)| j)
            .unwrap();
        sizes[j] -= 1;
        assigned -= 1;
    }
    sizes
}

/// Split `data` across clients per `spec`.
///
/// * IID (`nc >= num_classes`): random permutation dealt out in
///   `sizes`-length runs.
/// * non-IID: client i is assigned classes {(i*nc + j) mod C}, j in 0..nc
///   (each class held by exactly N*nc/C clients when divisible, matching
///   Fig. 9: nc=2 -> disjoint labels, nc=5 -> partial overlap), and draws
///   its quota evenly from per-class pools.
pub fn partition(data: &Dataset, spec: &PartitionSpec) -> Result<Partition> {
    if spec.n_clients == 0 {
        bail!("n_clients must be > 0");
    }
    if data.len() < spec.n_clients {
        bail!("{} samples cannot cover {} clients", data.len(), spec.n_clients);
    }
    if spec.alpha != 0.0 {
        if !(spec.alpha > 0.0 && spec.alpha.is_finite()) {
            bail!("dirichlet alpha must be positive and finite, got {}", spec.alpha);
        }
        return dirichlet_partition(data, spec);
    }
    let mut rng = Pcg::new(spec.seed, 0x5A4D);
    let sizes = unbalanced_sizes(data.len(), spec.n_clients, spec.beta);
    let c = data.num_classes;
    let iid = spec.nc >= c;

    let shards = if iid {
        let mut order: Vec<u32> = (0..data.len() as u32).collect();
        rng.shuffle(&mut order);
        let mut shards = Vec::with_capacity(spec.n_clients);
        let mut off = 0;
        for (cid, &sz) in sizes.iter().enumerate() {
            shards.push(ClientShard {
                client_id: cid,
                indices: order[off..off + sz].to_vec(),
            });
            off += sz;
        }
        shards
    } else {
        // per-class pools, shuffled
        let mut pools: Vec<Vec<u32>> = vec![Vec::new(); c];
        for (i, &y) in data.labels.iter().enumerate() {
            pools[y as usize].push(i as u32);
        }
        for p in pools.iter_mut() {
            rng.shuffle(p);
        }
        let mut cursor = vec![0usize; c];
        let mut shards = Vec::with_capacity(spec.n_clients);
        for (cid, &sz) in sizes.iter().enumerate() {
            let classes: Vec<usize> =
                (0..spec.nc).map(|j| (cid * spec.nc + j) % c).collect();
            let mut idx = Vec::with_capacity(sz);
            for (j, &k) in classes.iter().enumerate() {
                // even quota, remainder to the first classes
                let quota = sz / spec.nc + usize::from(j < sz % spec.nc);
                let avail = pools[k].len() - cursor[k];
                let take = quota.min(avail);
                idx.extend_from_slice(&pools[k][cursor[k]..cursor[k] + take]);
                cursor[k] += take;
            }
            shards.push(ClientShard { client_id: cid, indices: idx });
        }
        // leftovers (rounding / exhausted pools): deal to clients whose
        // assigned classes match, else round-robin
        let mut leftovers: Vec<u32> = Vec::new();
        for (k, pool) in pools.iter().enumerate() {
            leftovers.extend_from_slice(&pool[cursor[k]..]);
        }
        for (j, &i) in leftovers.iter().enumerate() {
            let cid = j % spec.n_clients;
            shards[cid].indices.push(i);
        }
        shards
    };

    Ok(Partition { shards })
}

/// Dirichlet(α) label-skew split: per class, client quotas are drawn from
/// Dirichlet(α · 1_N) and the shuffled class pool is dealt accordingly
/// (largest-remainder rounding keeps the deal exact). α → 0 concentrates
/// each class on few clients; α → ∞ approaches the IID class mix. Every
/// sample is assigned exactly once and every client ends up with at least
/// one sample (rebalanced deterministically from the largest shard, so a
/// selected client can always train).
fn dirichlet_partition(data: &Dataset, spec: &PartitionSpec) -> Result<Partition> {
    let n = spec.n_clients;
    let mut rng = Pcg::new(spec.seed, 0xD141);
    let mut pools: Vec<Vec<u32>> = vec![Vec::new(); data.num_classes];
    for (i, &y) in data.labels.iter().enumerate() {
        pools[y as usize].push(i as u32);
    }
    let mut shards: Vec<ClientShard> = (0..n)
        .map(|cid| ClientShard { client_id: cid, indices: Vec::new() })
        .collect();
    for pool in pools.iter_mut() {
        if pool.is_empty() {
            continue;
        }
        rng.shuffle(pool);
        let w = rng.dirichlet(spec.alpha, n);
        let quotas = largest_remainder_quotas(&w, pool.len());
        let mut off = 0;
        for (cid, &q) in quotas.iter().enumerate() {
            shards[cid].indices.extend_from_slice(&pool[off..off + q]);
            off += q;
        }
        debug_assert_eq!(off, pool.len());
    }
    // a selected-but-empty client cannot train: move one sample at a time
    // from the currently largest shard (deterministic donor choice)
    for cid in 0..n {
        if !shards[cid].indices.is_empty() {
            continue;
        }
        let donor = (0..n)
            .filter(|&j| j != cid && shards[j].indices.len() > 1)
            .max_by_key(|&j| shards[j].indices.len())
            .ok_or_else(|| anyhow!("cannot give every client at least one sample"))?;
        let moved = shards[donor].indices.pop().unwrap();
        shards[cid].indices.push(moved);
    }
    Ok(Partition { shards })
}

/// Split `total` items into integer quotas proportional to `w` (which
/// sums to 1): floor each share, then hand the remainder to the largest
/// fractional parts (ties broken by lower index — fully deterministic).
fn largest_remainder_quotas(w: &[f64], total: usize) -> Vec<usize> {
    let n = w.len();
    let mut quotas = Vec::with_capacity(n);
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(n);
    let mut assigned = 0usize;
    for (i, &p) in w.iter().enumerate() {
        let ideal = p * total as f64;
        let q = (ideal.floor() as usize).min(total);
        quotas.push(q);
        assigned += q;
        fracs.push((i, ideal - ideal.floor()));
    }
    fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut k = 0;
    while assigned < total {
        quotas[fracs[k % n].0] += 1;
        assigned += 1;
        k += 1;
    }
    while assigned > total {
        // float-edge safety: shave the largest quota
        let j = (0..n).max_by_key(|&j| quotas[j]).unwrap();
        quotas[j] -= 1;
        assigned -= 1;
    }
    quotas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::proptest::forall;

    fn toy_data(n: usize) -> Dataset {
        // tiny feature dim, balanced labels
        Dataset {
            dim: 2,
            num_classes: 10,
            features: vec![0.0; n * 2],
            labels: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    #[test]
    fn iid_exact_cover_and_balance() {
        let data = toy_data(1000);
        let p = partition(&data, &PartitionSpec::iid(10, 1)).unwrap();
        assert!(p.is_exact_cover(1000));
        assert!(p.sizes().iter().all(|&s| s == 100));
        assert!((p.beta() - 1.0).abs() < 1e-9);
        // each client sees ~all classes
        for s in &p.shards {
            let h = s.class_histogram(&data);
            assert!(h.iter().all(|&c| c > 0), "{h:?}");
        }
    }

    #[test]
    fn nc2_disjoint_classes() {
        let data = toy_data(1000);
        let p = partition(&data, &PartitionSpec::non_iid(10, 2, 2)).unwrap();
        assert!(p.is_exact_cover(1000));
        for s in &p.shards {
            let h = s.class_histogram(&data);
            let present = h.iter().filter(|&&c| c > 0).count();
            assert!(present <= 3, "client {} classes {present} {h:?}", s.client_id);
        }
    }

    #[test]
    fn nc5_partial_overlap() {
        let data = toy_data(2000);
        let p = partition(&data, &PartitionSpec::non_iid(10, 5, 3)).unwrap();
        assert!(p.is_exact_cover(2000));
        for s in &p.shards {
            let h = s.class_histogram(&data);
            let present = h.iter().filter(|&&c| c > 0).count();
            assert!((4..=6).contains(&present), "{h:?}");
        }
    }

    #[test]
    fn beta_controls_unbalance() {
        for beta in [0.1, 0.3, 0.5, 1.0] {
            let sizes = unbalanced_sizes(10_000, 30, beta);
            assert_eq!(sizes.iter().sum::<usize>(), 10_000);
            let measured = stats::unbalancedness(&sizes);
            assert!(
                (measured - beta).abs() < 0.12,
                "beta={beta} measured={measured} sizes={sizes:?}"
            );
        }
    }

    #[test]
    fn unbalanced_partition_cover() {
        let data = toy_data(3000);
        let p = partition(&data, &PartitionSpec::unbalanced(20, 0.2, 4)).unwrap();
        assert!(p.is_exact_cover(3000));
        assert!((p.beta() - 0.2).abs() < 0.12, "beta={}", p.beta());
    }

    #[test]
    fn partition_properties() {
        forall(32, |rng| {
            let n = 500 + rng.below(2000) as usize;
            let clients = 2 + rng.below(20) as usize;
            let nc = 1 + rng.below(10) as usize;
            let data = toy_data(n);
            let spec = PartitionSpec {
                n_clients: clients,
                nc,
                beta: 1.0,
                alpha: 0.0,
                seed: rng.next_u64(),
            };
            let p = partition(&data, &spec).unwrap();
            assert!(p.is_exact_cover(n));
            assert_eq!(p.shards.len(), clients);
        });
    }

    #[test]
    fn works_on_real_synth_data() {
        let (train, _) = SynthSpec::mnist_like(500, 100, 5).generate();
        let p = partition(&train, &PartitionSpec::non_iid(10, 2, 6)).unwrap();
        assert!(p.is_exact_cover(500));
    }

    #[test]
    fn errors_on_bad_specs() {
        let data = toy_data(5);
        assert!(partition(&data, &PartitionSpec::iid(0, 1)).is_err());
        assert!(partition(&data, &PartitionSpec::iid(10, 1)).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let data = toy_data(800);
        let a = partition(&data, &PartitionSpec::non_iid(10, 2, 9)).unwrap();
        let b = partition(&data, &PartitionSpec::non_iid(10, 2, 9)).unwrap();
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.indices, y.indices);
        }
    }

    // -- Dirichlet(alpha) label skew ----------------------------------------

    #[test]
    fn prop_dirichlet_exact_disjoint_cover() {
        forall(32, |rng| {
            let n = 300 + rng.below(3000) as usize;
            let clients = 2 + rng.below(30) as usize;
            let alpha = [0.05, 0.5, 1.0, 10.0][rng.below(4) as usize];
            let data = toy_data(n);
            let spec = PartitionSpec::dirichlet(clients, alpha, rng.next_u64());
            let p = partition(&data, &spec).unwrap();
            assert!(p.is_exact_cover(n), "alpha={alpha} clients={clients}");
            assert_eq!(p.shards.len(), clients);
            assert!(p.shards.iter().all(|s| !s.is_empty()), "alpha={alpha}");
        });
    }

    #[test]
    fn prop_dirichlet_deterministic_across_rebuilds() {
        forall(16, |rng| {
            let data = toy_data(500 + rng.below(1000) as usize);
            let spec = PartitionSpec::dirichlet(
                2 + rng.below(12) as usize,
                0.1 + rng.next_f64(),
                rng.next_u64(),
            );
            let a = partition(&data, &spec).unwrap();
            let b = partition(&data, &spec).unwrap();
            for (x, y) in a.shards.iter().zip(&b.shards) {
                assert_eq!(x.indices, y.indices);
            }
        });
    }

    #[test]
    fn dirichlet_large_alpha_approaches_iid_mix() {
        // alpha -> inf: every client holds ~1/N of every class
        let data = toy_data(5000); // 500 per class, 10 classes
        let p = partition(&data, &PartitionSpec::dirichlet(10, 1e6, 13)).unwrap();
        assert!(p.is_exact_cover(5000));
        for s in &p.shards {
            let h = s.class_histogram(&data);
            for (c, &count) in h.iter().enumerate() {
                // ideal 50 per class per client; largest-remainder gives ±1,
                // near-uniform Dirichlet weights add a little slack
                assert!(
                    (count as i64 - 50).abs() <= 5,
                    "client {} class {c}: {count} (want ~50) {h:?}",
                    s.client_id
                );
            }
        }
        // sizes are near-balanced, too
        assert!(p.beta() > 0.9, "beta={}", p.beta());
    }

    #[test]
    fn dirichlet_small_alpha_skews_labels() {
        // small alpha concentrates each client on few labels relative to
        // the IID mix: compare max class share per client
        let data = toy_data(5000);
        let max_share = |p: &Partition| -> f64 {
            let mut acc = 0.0;
            for s in &p.shards {
                let h = s.class_histogram(&data);
                let total: usize = h.iter().sum();
                let mx = *h.iter().max().unwrap();
                acc += mx as f64 / total.max(1) as f64;
            }
            acc / p.shards.len() as f64
        };
        let skewed = partition(&data, &PartitionSpec::dirichlet(10, 0.05, 17)).unwrap();
        let mixed = partition(&data, &PartitionSpec::dirichlet(10, 1000.0, 17)).unwrap();
        assert!(skewed.is_exact_cover(5000));
        let (s, m) = (max_share(&skewed), max_share(&mixed));
        assert!(s > m + 0.2, "skewed={s} mixed={m}");
    }

    #[test]
    fn dirichlet_rejects_bad_alpha() {
        let data = toy_data(100);
        for alpha in [-1.0, f64::NAN, f64::INFINITY] {
            let spec = PartitionSpec::dirichlet(4, alpha, 1);
            assert!(partition(&data, &spec).is_err(), "alpha={alpha}");
        }
    }

    // -- PartitionStrategy ---------------------------------------------------

    #[test]
    fn strategy_parse_roundtrip() {
        for s in ["iid", "nc:2", "nc:5", "beta:0.5", "dirichlet:alpha=0.5"] {
            let strat = PartitionStrategy::parse(s).unwrap();
            assert_eq!(strat.name(), s);
            // canonical names re-parse to the same strategy
            assert_eq!(PartitionStrategy::parse(&strat.name()).unwrap(), strat);
        }
        // sugar form
        assert_eq!(
            PartitionStrategy::parse("dirichlet:0.3").unwrap(),
            PartitionStrategy::Dirichlet { alpha: 0.3 }
        );
        assert_eq!(PartitionStrategy::parse(" IID ").unwrap(), PartitionStrategy::Iid);
    }

    #[test]
    fn strategy_parse_rejects_garbage() {
        for s in [
            "", "unknown", "nc:", "nc:0", "nc:x", "beta:0", "beta:2", "beta:NaN-ish",
            "dirichlet:", "dirichlet:alpha=", "dirichlet:alpha=-1", "dirichlet:alpha=inf",
        ] {
            assert!(PartitionStrategy::parse(s).is_err(), "accepted {s:?}");
        }
    }

    #[test]
    fn strategy_apply_sets_config_fields() {
        use crate::config::{ExperimentConfig, Protocol, Task};
        let base = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
        let mut c = base.clone();
        PartitionStrategy::NonIid { nc: 2 }.apply(&mut c);
        assert_eq!((c.nc, c.beta, c.dirichlet_alpha), (2, 1.0, 0.0));
        PartitionStrategy::Unbalanced { beta: 0.3 }.apply(&mut c);
        assert_eq!((c.nc, c.beta, c.dirichlet_alpha), (10, 0.3, 0.0));
        PartitionStrategy::Dirichlet { alpha: 0.5 }.apply(&mut c);
        assert_eq!((c.nc, c.beta, c.dirichlet_alpha), (10, 1.0, 0.5));
        PartitionStrategy::Iid.apply(&mut c);
        assert_eq!(c, base); // back to the IID defaults, byte-for-byte
        c.validate().unwrap();
    }
}
