//! Federated sharding: IID, Nc-class non-IID (Fig. 8/9), unbalanced beta
//! splits (Fig. 11, eq. 29).

use anyhow::{bail, Result};

use crate::data::synth::Dataset;
use crate::util::rng::Pcg;
use crate::util::stats;

/// How to split a dataset across clients.
#[derive(Clone, Debug)]
pub struct PartitionSpec {
    pub n_clients: usize,
    /// classes per client; == num_classes means IID (paper §V-A.3)
    pub nc: usize,
    /// unbalancedness ratio beta = median/max of client sizes (eq. 29);
    /// 1.0 = balanced
    pub beta: f64,
    pub seed: u64,
}

impl PartitionSpec {
    pub fn iid(n_clients: usize, seed: u64) -> Self {
        PartitionSpec { n_clients, nc: usize::MAX, beta: 1.0, seed }
    }

    pub fn non_iid(n_clients: usize, nc: usize, seed: u64) -> Self {
        PartitionSpec { n_clients, nc, beta: 1.0, seed }
    }

    pub fn unbalanced(n_clients: usize, beta: f64, seed: u64) -> Self {
        PartitionSpec { n_clients, nc: usize::MAX, beta, seed }
    }
}

/// One client's local data: indices into the shared dataset.
#[derive(Clone, Debug)]
pub struct ClientShard {
    pub client_id: usize,
    pub indices: Vec<u32>,
}

impl ClientShard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn class_histogram(&self, data: &Dataset) -> Vec<usize> {
        let mut h = vec![0usize; data.num_classes];
        for &i in &self.indices {
            h[data.labels[i as usize] as usize] += 1;
        }
        h
    }
}

/// The result of sharding a dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<ClientShard>,
}

impl Partition {
    pub fn sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.len()).collect()
    }

    /// Measured unbalancedness (eq. 29) of this partition.
    pub fn beta(&self) -> f64 {
        stats::unbalancedness(&self.sizes())
    }

    /// Every sample must be assigned exactly once.
    pub fn is_exact_cover(&self, n: usize) -> bool {
        let mut seen = vec![false; n];
        for s in &self.shards {
            for &i in &s.indices {
                if seen[i as usize] {
                    return false;
                }
                seen[i as usize] = true;
            }
        }
        seen.iter().all(|&b| b)
    }
}

/// Target client sizes for a given beta: geometric profile
/// size_i = max * beta^(2i / (N-1)), normalized to sum to `total`.
/// By construction median/max ~= beta.
pub fn unbalanced_sizes(total: usize, n_clients: usize, beta: f64) -> Vec<usize> {
    assert!(n_clients > 0);
    assert!((0.0..=1.0).contains(&beta) && beta > 0.0, "beta in (0, 1]");
    if n_clients == 1 {
        return vec![total];
    }
    let raw: Vec<f64> = (0..n_clients)
        .map(|i| beta.powf(2.0 * i as f64 / (n_clients as f64 - 1.0)))
        .collect();
    let s: f64 = raw.iter().sum();
    let mut sizes: Vec<usize> = raw
        .iter()
        .map(|r| ((r / s) * total as f64).floor().max(1.0) as usize)
        .collect();
    // distribute the remainder deterministically to the largest clients
    let mut assigned: usize = sizes.iter().sum();
    let mut i = 0;
    while assigned < total {
        sizes[i % n_clients] += 1;
        assigned += 1;
        i += 1;
    }
    while assigned > total {
        let j = sizes
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .map(|(j, _)| j)
            .unwrap();
        sizes[j] -= 1;
        assigned -= 1;
    }
    sizes
}

/// Split `data` across clients per `spec`.
///
/// * IID (`nc >= num_classes`): random permutation dealt out in
///   `sizes`-length runs.
/// * non-IID: client i is assigned classes {(i*nc + j) mod C}, j in 0..nc
///   (each class held by exactly N*nc/C clients when divisible, matching
///   Fig. 9: nc=2 -> disjoint labels, nc=5 -> partial overlap), and draws
///   its quota evenly from per-class pools.
pub fn partition(data: &Dataset, spec: &PartitionSpec) -> Result<Partition> {
    if spec.n_clients == 0 {
        bail!("n_clients must be > 0");
    }
    if data.len() < spec.n_clients {
        bail!("{} samples cannot cover {} clients", data.len(), spec.n_clients);
    }
    let mut rng = Pcg::new(spec.seed, 0x5A4D);
    let sizes = unbalanced_sizes(data.len(), spec.n_clients, spec.beta);
    let c = data.num_classes;
    let iid = spec.nc >= c;

    let shards = if iid {
        let mut order: Vec<u32> = (0..data.len() as u32).collect();
        rng.shuffle(&mut order);
        let mut shards = Vec::with_capacity(spec.n_clients);
        let mut off = 0;
        for (cid, &sz) in sizes.iter().enumerate() {
            shards.push(ClientShard {
                client_id: cid,
                indices: order[off..off + sz].to_vec(),
            });
            off += sz;
        }
        shards
    } else {
        // per-class pools, shuffled
        let mut pools: Vec<Vec<u32>> = vec![Vec::new(); c];
        for (i, &y) in data.labels.iter().enumerate() {
            pools[y as usize].push(i as u32);
        }
        for p in pools.iter_mut() {
            rng.shuffle(p);
        }
        let mut cursor = vec![0usize; c];
        let mut shards = Vec::with_capacity(spec.n_clients);
        for (cid, &sz) in sizes.iter().enumerate() {
            let classes: Vec<usize> =
                (0..spec.nc).map(|j| (cid * spec.nc + j) % c).collect();
            let mut idx = Vec::with_capacity(sz);
            for (j, &k) in classes.iter().enumerate() {
                // even quota, remainder to the first classes
                let quota = sz / spec.nc + usize::from(j < sz % spec.nc);
                let avail = pools[k].len() - cursor[k];
                let take = quota.min(avail);
                idx.extend_from_slice(&pools[k][cursor[k]..cursor[k] + take]);
                cursor[k] += take;
            }
            shards.push(ClientShard { client_id: cid, indices: idx });
        }
        // leftovers (rounding / exhausted pools): deal to clients whose
        // assigned classes match, else round-robin
        let mut leftovers: Vec<u32> = Vec::new();
        for (k, pool) in pools.iter().enumerate() {
            leftovers.extend_from_slice(&pool[cursor[k]..]);
        }
        for (j, &i) in leftovers.iter().enumerate() {
            let cid = j % spec.n_clients;
            shards[cid].indices.push(i);
        }
        shards
    };

    Ok(Partition { shards })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::proptest::forall;

    fn toy_data(n: usize) -> Dataset {
        // tiny feature dim, balanced labels
        Dataset {
            dim: 2,
            num_classes: 10,
            features: vec![0.0; n * 2],
            labels: (0..n as u32).map(|i| i % 10).collect(),
        }
    }

    #[test]
    fn iid_exact_cover_and_balance() {
        let data = toy_data(1000);
        let p = partition(&data, &PartitionSpec::iid(10, 1)).unwrap();
        assert!(p.is_exact_cover(1000));
        assert!(p.sizes().iter().all(|&s| s == 100));
        assert!((p.beta() - 1.0).abs() < 1e-9);
        // each client sees ~all classes
        for s in &p.shards {
            let h = s.class_histogram(&data);
            assert!(h.iter().all(|&c| c > 0), "{h:?}");
        }
    }

    #[test]
    fn nc2_disjoint_classes() {
        let data = toy_data(1000);
        let p = partition(&data, &PartitionSpec::non_iid(10, 2, 2)).unwrap();
        assert!(p.is_exact_cover(1000));
        for s in &p.shards {
            let h = s.class_histogram(&data);
            let present = h.iter().filter(|&&c| c > 0).count();
            assert!(present <= 3, "client {} classes {present} {h:?}", s.client_id);
        }
    }

    #[test]
    fn nc5_partial_overlap() {
        let data = toy_data(2000);
        let p = partition(&data, &PartitionSpec::non_iid(10, 5, 3)).unwrap();
        assert!(p.is_exact_cover(2000));
        for s in &p.shards {
            let h = s.class_histogram(&data);
            let present = h.iter().filter(|&&c| c > 0).count();
            assert!((4..=6).contains(&present), "{h:?}");
        }
    }

    #[test]
    fn beta_controls_unbalance() {
        for beta in [0.1, 0.3, 0.5, 1.0] {
            let sizes = unbalanced_sizes(10_000, 30, beta);
            assert_eq!(sizes.iter().sum::<usize>(), 10_000);
            let measured = stats::unbalancedness(&sizes);
            assert!(
                (measured - beta).abs() < 0.12,
                "beta={beta} measured={measured} sizes={sizes:?}"
            );
        }
    }

    #[test]
    fn unbalanced_partition_cover() {
        let data = toy_data(3000);
        let p = partition(&data, &PartitionSpec::unbalanced(20, 0.2, 4)).unwrap();
        assert!(p.is_exact_cover(3000));
        assert!((p.beta() - 0.2).abs() < 0.12, "beta={}", p.beta());
    }

    #[test]
    fn partition_properties() {
        forall(32, |rng| {
            let n = 500 + rng.below(2000) as usize;
            let clients = 2 + rng.below(20) as usize;
            let nc = 1 + rng.below(10) as usize;
            let data = toy_data(n);
            let spec = PartitionSpec { n_clients: clients, nc, beta: 1.0, seed: rng.next_u64() };
            let p = partition(&data, &spec).unwrap();
            assert!(p.is_exact_cover(n));
            assert_eq!(p.shards.len(), clients);
        });
    }

    #[test]
    fn works_on_real_synth_data() {
        let (train, _) = SynthSpec::mnist_like(500, 100, 5).generate();
        let p = partition(&train, &PartitionSpec::non_iid(10, 2, 6)).unwrap();
        assert!(p.is_exact_cover(500));
    }

    #[test]
    fn errors_on_bad_specs() {
        let data = toy_data(5);
        assert!(partition(&data, &PartitionSpec::iid(0, 1)).is_err());
        assert!(partition(&data, &PartitionSpec::iid(10, 1)).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let data = toy_data(800);
        let a = partition(&data, &PartitionSpec::non_iid(10, 2, 9)).unwrap();
        let b = partition(&data, &PartitionSpec::non_iid(10, 2, 9)).unwrap();
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.indices, y.indices);
        }
    }
}
