//! Data pipeline: synthetic dataset generators + federated sharding.
//!
//! Substitution (DESIGN.md §3): no network access means no MNIST/CIFAR10
//! downloads; `synth` builds deterministic 10-class Gaussian-mixture image
//! datasets whose difficulty is tuned so accuracies land mid-range. The
//! phenomena the paper studies — IID vs Nc-class non-IID splits (Fig. 8/9),
//! unbalanced client sizes (Fig. 11, eq. 29), participation ratio (Fig. 10)
//! — are properties of the *sharding*, which is implemented here exactly as
//! described.

pub mod partition;
pub mod synth;

pub use partition::{partition, ClientShard, Partition, PartitionSpec};
pub use synth::{Dataset, SynthSpec};
