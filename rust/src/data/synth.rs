//! Deterministic synthetic classification datasets (MNIST/CIFAR10 stand-ins).
//!
//! Each class k gets a smooth random "prototype image" built from low-
//! frequency random blobs; samples are prototype + per-sample elastic noise
//! + pixel noise. Class overlap (difficulty) is controlled by the
//! noise-to-signal ratio. The generator is seeded and deterministic, so
//! every bench run sees the same data.

use crate::util::rng::Pcg;

/// (side, side, channels) of the mnist-like task — the single source of
/// truth for its geometry (`config::Task::image_shape` and the native
/// model registry validate against it).
pub const MNIST_LIKE_SHAPE: (usize, usize, usize) = (28, 28, 1);
/// (side, side, channels) of the cifar-like task (the `cnn` substrate).
pub const CIFAR_LIKE_SHAPE: (usize, usize, usize) = (16, 16, 3);

/// Dataset: row-major features [n, dim] + integer labels, values ~ [-1, 1].
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub num_classes: usize,
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Per-class sample counts (Fig. 9 histograms).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &y in &self.labels {
            h[y as usize] += 1;
        }
        h
    }
}

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// square image side (e.g. 28 for the MNIST-like task)
    pub side: usize,
    /// channels (1 for MNIST-like, 3 for CIFAR-like)
    pub channels: usize,
    pub num_classes: usize,
    pub train: usize,
    pub test: usize,
    /// per-sample spatial jitter amplitude (class overlap knob)
    pub jitter: f32,
    /// additive pixel noise sigma
    pub noise: f32,
    pub seed: u64,
}

impl SynthSpec {
    /// MNIST-like: 28x28x1. Noise is tuned so a full-precision MLP lands
    /// mid-90s rather than saturating instantly — keeps the Table-II
    /// comparisons informative (DESIGN.md §3).
    pub fn mnist_like(train: usize, test: usize, seed: u64) -> Self {
        let (side, _, channels) = MNIST_LIKE_SHAPE;
        SynthSpec {
            side,
            channels,
            num_classes: 10,
            train,
            test,
            jitter: 0.6,
            noise: 1.1,
            seed,
        }
    }

    /// CIFAR-like: 16x16x3, harder features (mid-range CNN accuracy).
    pub fn cifar_like(train: usize, test: usize, seed: u64) -> Self {
        let (side, _, channels) = CIFAR_LIKE_SHAPE;
        SynthSpec {
            side,
            channels,
            num_classes: 10,
            train,
            test,
            jitter: 0.55,
            noise: 0.75,
            seed,
        }
    }

    pub fn dim(&self) -> usize {
        self.side * self.side * self.channels
    }

    /// (side, side, channels) — the NHWC image geometry conv models
    /// consume. Pixels are laid out `(y * side + x) * channels + c`, which
    /// is exactly the layout `native::layers::Conv2d` expects, so the
    /// cifar-like task feeds the `cnn` registry model with no reshaping.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.side, self.side, self.channels)
    }

    /// Generate (train, test) datasets.
    pub fn generate(&self) -> (Dataset, Dataset) {
        let mut rng = Pcg::new(self.seed, 0xDA7A);
        let protos = self.prototypes(&mut rng);
        let train = self.sample_set(self.train, &protos, &mut rng);
        let test = self.sample_set(self.test, &protos, &mut rng);
        (train, test)
    }

    /// Low-frequency class prototypes: sum of `side/2` random soft blobs.
    fn prototypes(&self, rng: &mut Pcg) -> Vec<Vec<f32>> {
        let d = self.dim();
        (0..self.num_classes)
            .map(|_| {
                let mut img = vec![0f32; d];
                let blobs = (self.side / 2).max(3);
                for _ in 0..blobs {
                    let cx = rng.uniform(0.0, self.side as f32);
                    let cy = rng.uniform(0.0, self.side as f32);
                    let amp = rng.uniform(-1.5, 1.5);
                    let sig = rng.uniform(1.0, self.side as f32 / 3.0);
                    let ch = rng.below(self.channels as u32) as usize;
                    for y in 0..self.side {
                        for x in 0..self.side {
                            let dx = x as f32 - cx;
                            let dy = y as f32 - cy;
                            let g = amp * (-(dx * dx + dy * dy) / (2.0 * sig * sig)).exp();
                            img[(y * self.side + x) * self.channels + ch] += g;
                        }
                    }
                }
                // normalize prototype to unit max-abs
                let m = img.iter().fold(0f32, |a, x| a.max(x.abs())).max(1e-6);
                for x in &mut img {
                    *x /= m;
                }
                img
            })
            .collect()
    }

    fn sample_set(&self, n: usize, protos: &[Vec<f32>], rng: &mut Pcg) -> Dataset {
        let d = self.dim();
        let mut features = Vec::with_capacity(n * d);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let k = (i % self.num_classes) as u32; // balanced classes
            let proto = &protos[k as usize];
            // elastic jitter: shift the prototype by a sub-pixel offset
            let ox = rng.normal() * self.jitter;
            let oy = rng.normal() * self.jitter;
            let gain = 1.0 + rng.normal() * 0.1;
            for y in 0..self.side {
                for x in 0..self.side {
                    for c in 0..self.channels {
                        let sx = (x as f32 + ox).clamp(0.0, self.side as f32 - 1.0);
                        let sy = (y as f32 + oy).clamp(0.0, self.side as f32 - 1.0);
                        let v = bilinear(proto, self.side, self.channels, sx, sy, c);
                        let noise = rng.normal() * self.noise;
                        features.push((gain * v + noise).clamp(-3.0, 3.0));
                    }
                }
            }
            labels.push(k);
        }
        // shuffle samples so class order is not systematic
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut sf = Vec::with_capacity(n * d);
        let mut sl = Vec::with_capacity(n);
        for &i in &order {
            sf.extend_from_slice(&features[i * d..(i + 1) * d]);
            sl.push(labels[i]);
        }
        Dataset { dim: d, num_classes: self.num_classes, features: sf, labels: sl }
    }
}

fn bilinear(img: &[f32], side: usize, channels: usize, x: f32, y: f32, c: usize) -> f32 {
    let x0 = x.floor() as usize;
    let y0 = y.floor() as usize;
    let x1 = (x0 + 1).min(side - 1);
    let y1 = (y0 + 1).min(side - 1);
    let fx = x - x0 as f32;
    let fy = y - y0 as f32;
    let at = |xx: usize, yy: usize| img[(yy * side + xx) * channels + c];
    at(x0, y0) * (1.0 - fx) * (1.0 - fy)
        + at(x1, y0) * fx * (1.0 - fy)
        + at(x0, y1) * (1.0 - fx) * fy
        + at(x1, y1) * fx * fy
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = SynthSpec::mnist_like(100, 20, 7);
        let (a, _) = spec.generate();
        let (b, _) = spec.generate();
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn shapes_and_balance() {
        let spec = SynthSpec::mnist_like(200, 50, 1);
        let (train, test) = spec.generate();
        assert_eq!(train.len(), 200);
        assert_eq!(test.len(), 50);
        assert_eq!(train.dim, 784);
        assert_eq!(train.features.len(), 200 * 784);
        let h = train.class_histogram();
        assert_eq!(h.iter().sum::<usize>(), 200);
        assert!(h.iter().all(|&c| c == 20), "{h:?}");
    }

    #[test]
    fn cifar_like_dims() {
        let spec = SynthSpec::cifar_like(50, 10, 2);
        assert_eq!(spec.shape(), (16, 16, 3));
        let (train, _) = spec.generate();
        assert_eq!(train.dim, 16 * 16 * 3);
    }

    #[test]
    fn shapes_match_the_native_cnn_registry() {
        // the cifar-like task is the cnn model's substrate: geometry must
        // agree end-to-end (conv layers consume NHWC of exactly this dim)
        let def = crate::model::registry::model_def("cnn").unwrap();
        let spec = SynthSpec::cifar_like(10, 5, 1);
        assert_eq!(def.schema.input_dim, spec.dim());
        let (h, w, c) = spec.shape();
        assert_eq!((h, w, c), (16, 16, 3));
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin — i.e. the classes carry signal.
        let spec = SynthSpec::mnist_like(500, 100, 3);
        let mut rng = Pcg::new(spec.seed, 0xDA7A);
        let protos = spec.prototypes(&mut rng);
        let (train, _) = spec.generate();
        let mut correct = 0;
        for i in 0..train.len() {
            let xs = train.sample(i);
            let mut best = (f32::INFINITY, 0u32);
            for (k, p) in protos.iter().enumerate() {
                let d: f32 = xs.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, k as u32);
                }
            }
            if best.1 == train.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / train.len() as f64;
        assert!(acc > 0.5, "nearest-prototype accuracy {acc}");
    }

    #[test]
    fn values_bounded() {
        let spec = SynthSpec::cifar_like(30, 5, 4);
        let (train, _) = spec.generate();
        assert!(train.features.iter().all(|x| x.abs() <= 3.0 && x.is_finite()));
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = SynthSpec::mnist_like(50, 10, 1).generate();
        let (b, _) = SynthSpec::mnist_like(50, 10, 2).generate();
        assert_ne!(a.features, b.features);
    }
}
