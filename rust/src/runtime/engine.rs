//! The PJRT execution engine: compile HLO artifacts once, run many times.
//!
//! The `xla` crate (PJRT C API) is vendored, not on crates.io, so the real
//! engine is behind the `pjrt` cargo feature. The default build substitutes
//! a stub whose `load` fails with a clear message — every coordinator,
//! transport, and bench path then runs on the pure-Rust native backend.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::runtime::manifest::Manifest;
use crate::runtime::value::Value;
#[cfg(feature = "pjrt")]
use crate::runtime::manifest::ArtifactSpec;
#[cfg(feature = "pjrt")]
use crate::{debug, info};

/// Compiled-executable cache keyed by artifact name.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// executions per artifact (perf accounting)
    exec_counts: Mutex<HashMap<String, u64>>,
    /// serializes every call into the xla C API (see the Sync impl below)
    api_lock: Mutex<()>,
}

// SAFETY: the xla wrapper types hold raw pointers and are not Sync on
// their own, so this impl is made conservative instead of assumed: every
// path that touches the xla C API (literal marshalling, compile, execute,
// transfer) runs under `api_lock`, and all remaining Engine state sits
// behind its own Mutexes. The concurrent round driver therefore shares
// one Engine across worker threads with xla calls fully serialized; if
// the vendored PJRT client is ever verified reentrant, the lock scope can
// be narrowed to regain device-level parallelism.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        info!(
            "PJRT engine up: platform={} artifacts={}",
            client.platform_name(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exec_counts: Mutex::new(HashMap::new()),
            api_lock: Mutex::new(()),
        })
    }

    /// Compile (or fetch cached) an artifact's executable. Private: the
    /// returned handle must only be driven under `api_lock` (see the Sync
    /// impl), which `execute`/`warmup` guarantee.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let art = self.manifest.artifact(name)?;
        let path = self.manifest.hlo_path(art);
        let _api = self.api_lock.lock().unwrap();
        // another worker may have compiled this while we waited for the API
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (amortize JIT cost before timing).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with typed host values; returns outputs in the
    /// manifest's output order. Input shapes/dtypes are validated against
    /// the manifest before they reach PJRT.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let art = self.manifest.artifact(name)?.clone();
        self.validate_inputs(&art, inputs)?;
        let exe = self.executable(name)?;
        // marshal + execute + transfer are all xla calls: hold the API lock
        let _api = self.api_lock.lock().unwrap();
        let literals: Result<Vec<xla::Literal>> =
            inputs.iter().map(|v| v.to_literal()).collect();
        let literals = literals?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple at top level
        let parts = tuple.to_tuple()?;
        if parts.len() != art.outputs.len() {
            bail!(
                "{name}: HLO returned {} outputs, manifest says {}",
                parts.len(),
                art.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&art.outputs) {
            out.push(Value::from_literal(lit, spec)?);
        }
        *self.exec_counts.lock().unwrap().entry(name.to_string()).or_insert(0) += 1;
        debug!("executed {name} ({} inputs)", inputs.len());
        Ok(out)
    }

    fn validate_inputs(&self, art: &ArtifactSpec, inputs: &[Value]) -> Result<()> {
        if inputs.len() != art.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest wants {}",
                art.name,
                inputs.len(),
                art.inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&art.inputs) {
            if !v.matches(spec) {
                bail!(
                    "{}: input {:?} expects shape {:?} dtype {:?}, got shape {:?}",
                    art.name,
                    spec.name,
                    spec.shape,
                    spec.dtype,
                    v.shape()
                );
            }
        }
        Ok(())
    }

    /// Executions per artifact so far (perf accounting).
    pub fn exec_counts(&self) -> Vec<(String, u64)> {
        let m = self.exec_counts.lock().unwrap();
        let mut v: Vec<(String, u64)> = m.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort();
        v
    }
}

/// Stub engine compiled when the `pjrt` feature is off: keeps every call
/// site type-checking while making the unavailability unmissable at the
/// single entry point (`load`).
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        bail!(
            "tfed was built without the `pjrt` feature (the vendored `xla` \
             crate is absent); cannot load PJRT artifacts from {:?}. Use the \
             native backend (--native), or vendor the xla crate and rebuild \
             with `--features pjrt`.",
            dir.as_ref()
        )
    }

    pub fn warmup(&self, _names: &[&str]) -> Result<()> {
        bail!("PJRT engine unavailable: built without the `pjrt` feature")
    }

    pub fn execute(&self, _name: &str, _inputs: &[Value]) -> Result<Vec<Value>> {
        bail!("PJRT engine unavailable: built without the `pjrt` feature")
    }

    pub fn exec_counts(&self) -> Vec<(String, u64)> {
        Vec::new()
    }
}
