//! Host-side tensor values + PJRT literal marshalling.

use anyhow::{bail, Result};

use crate::runtime::manifest::{Dtype, IoSpec};

/// A host tensor moving in/out of an artifact execution.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32 { shape: vec![], data: vec![v] }
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Value> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Value::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Value> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Value::I32 { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            Value::F32 { data, .. } => data.len(),
            Value::I32 { data, .. } => data.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Check against an artifact IoSpec (shape + dtype).
    pub fn matches(&self, spec: &IoSpec) -> bool {
        match (self, spec.dtype) {
            (Value::F32 { shape, .. }, Dtype::F32) => shape == &spec.shape,
            (Value::I32 { shape, .. }, Dtype::S32) => shape == &spec.shape,
            _ => false,
        }
    }

    /// Convert to an xla literal.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64>;
        let lit = match self {
            Value::F32 { shape, data } => {
                if shape.is_empty() {
                    return Ok(xla::Literal::scalar(data[0]));
                }
                dims = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Value::I32 { shape, data } => {
                if shape.is_empty() {
                    return Ok(xla::Literal::scalar(data[0]));
                }
                dims = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    /// Read back from an xla literal, trusting `spec` for shape/dtype.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<Value> {
        match spec.dtype {
            Dtype::F32 => Ok(Value::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? }),
            Dtype::S32 => Ok(Value::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(Value::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Value::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Value::i32(vec![2], vec![1, 2]).is_ok());
    }

    #[test]
    fn matches_spec() {
        let v = Value::f32(vec![4], vec![0.0; 4]).unwrap();
        let s = IoSpec { name: "x".into(), shape: vec![4], dtype: Dtype::F32 };
        assert!(v.matches(&s));
        let s2 = IoSpec { name: "x".into(), shape: vec![4], dtype: Dtype::S32 };
        assert!(!v.matches(&s2));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let v = Value::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = v.to_literal().unwrap();
        let spec = IoSpec { name: "t".into(), shape: vec![2, 2], dtype: Dtype::F32 };
        let back = Value::from_literal(&lit, &spec).unwrap();
        assert_eq!(v, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_scalar_and_i32() {
        let v = Value::scalar_f32(0.5);
        let lit = v.to_literal().unwrap();
        let spec = IoSpec { name: "s".into(), shape: vec![], dtype: Dtype::F32 };
        assert_eq!(Value::from_literal(&lit, &spec).unwrap().scalar().unwrap(), 0.5);

        let vi = Value::i32(vec![3], vec![7, -1, 2]).unwrap();
        let lit = vi.to_literal().unwrap();
        let spec = IoSpec { name: "y".into(), shape: vec![3], dtype: Dtype::S32 };
        assert_eq!(Value::from_literal(&lit, &spec).unwrap(), vi);
    }
}
