//! artifacts/manifest.json loader — the contract between python aot.py and
//! the Rust runtime (parameter order, shapes, dtypes, file names).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{ModelSchema, ParamSpec};
use crate::util::json::Json;

/// Dtype of one artifact input/output (only f32/s32 are emitted).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dtype {
    F32,
    S32,
}

/// One artifact input or output tensor.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: String,
    pub mode: String,
    pub batch: usize,
    pub nb: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One model entry: schema + optimizer-state layouts per mode.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub schema: ModelSchema,
    pub num_quantized: usize,
    pub opt_state_fp: Vec<IoSpec>,
    pub opt_state_fttq: Vec<IoSpec>,
    pub opt_state_ttq: Vec<IoSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub t_k: f32,
    pub server_delta: f32,
    pub wq_grad: String,
    pub wq_init: f32,
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "s32" => Ok(Dtype::S32),
        other => bail!("unsupported dtype {other}"),
    }
}

fn parse_io_list(v: &Json) -> Result<Vec<IoSpec>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.expect("name")?.as_str()?.to_string(),
                shape: e.expect("shape")?.as_shape()?,
                dtype: parse_dtype(
                    e.get("dtype").map(|d| d.as_str()).transpose()?.unwrap_or("f32"),
                )?,
            })
        })
        .collect()
}

fn parse_param_list(v: &Json) -> Result<Vec<ParamSpec>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(ParamSpec {
                name: e.expect("name")?.as_str()?.to_string(),
                shape: e.expect("shape")?.as_shape()?,
                quantized: e
                    .get("quantized")
                    .map(|q| q.as_bool())
                    .transpose()?
                    .unwrap_or(false),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in root.expect("models")?.as_obj()? {
            let schema = ModelSchema {
                name: name.clone(),
                input_dim: m.expect("input_dim")?.as_usize()?,
                num_classes: m.expect("num_classes")?.as_usize()?,
                optimizer: m.expect("optimizer")?.as_str()?.to_string(),
                default_lr: m.expect("default_lr")?.as_f64()? as f32,
                params: parse_param_list(m.expect("params")?)?,
            };
            models.insert(
                name.clone(),
                ModelEntry {
                    schema,
                    num_quantized: m.expect("num_quantized")?.as_usize()?,
                    opt_state_fp: parse_io_list(m.expect("opt_state_fp")?)?,
                    opt_state_fttq: parse_io_list(m.expect("opt_state_fttq")?)?,
                    opt_state_ttq: parse_io_list(m.expect("opt_state_ttq")?)?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in root.expect("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.expect("file")?.as_str()?.to_string(),
                    kind: a.expect("kind")?.as_str()?.to_string(),
                    model: a.expect("model")?.as_str()?.to_string(),
                    mode: a.expect("mode")?.as_str()?.to_string(),
                    batch: a.expect("batch")?.as_usize()?,
                    nb: a.expect("nb")?.as_usize()?,
                    inputs: parse_io_list(a.expect("inputs")?)?,
                    outputs: parse_io_list(a.expect("outputs")?)?,
                },
            );
        }

        Ok(Manifest {
            dir,
            t_k: root.expect("t_k")?.as_f64()? as f32,
            server_delta: root.expect("server_delta")?.as_f64()? as f32,
            wq_grad: root.expect("wq_grad")?.as_str()?.to_string(),
            wq_init: root.expect("wq_init")?.as_f64()? as f32,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model {name:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Find the train artifact for (model, mode, batch).
    pub fn train_artifact(&self, model: &str, mode: &str, batch: usize) -> Result<&ArtifactSpec> {
        self.artifact(&format!("{model}_{mode}_train_b{batch}"))
    }

    /// The eval artifact for a model (any batch size; there is one).
    pub fn eval_artifact(&self, model: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| a.model == model && a.kind == "eval")
            .ok_or_else(|| anyhow!("no eval artifact for model {model:?}"))
    }

    pub fn quantize_artifact(&self, model: &str) -> Result<&ArtifactSpec> {
        self.artifact(&format!("{model}_quantize"))
    }

    /// Train batch sizes available for a model (Fig. 7 sweep).
    pub fn train_batches(&self, model: &str) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.model == model && a.kind == "train" && a.mode == "fttq")
            .map(|a| a.batch)
            .collect();
        bs.sort_unstable();
        bs.dedup();
        bs
    }

    pub fn hlo_path(&self, art: &ArtifactSpec) -> PathBuf {
        self.dir.join(&art.file)
    }
}

/// Locate the artifacts directory: $TFED_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TFED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(default_artifacts_dir()).unwrap();
        assert!((m.t_k - 0.05).abs() < 1e-9);
        assert!((m.server_delta - 0.05).abs() < 1e-9);
        let mlp = m.model("mlp").unwrap();
        assert_eq!(mlp.schema.input_dim, 784);
        assert_eq!(mlp.schema.param_count(), 24_380);
        assert_eq!(mlp.num_quantized, 3);
        assert_eq!(mlp.schema.quantized_indices(), vec![0, 2, 4]);
        // every artifact file exists
        for a in m.artifacts.values() {
            assert!(m.hlo_path(a).exists(), "{:?}", a.file);
        }
        // train artifact I/O symmetry (outputs = inputs - data + loss)
        let t = m.train_artifact("mlp", "fttq", 64).unwrap();
        assert_eq!(t.inputs.len(), t.outputs.len() + 3);
        assert_eq!(t.batch, 64);
        assert_eq!(t.nb, 16);
        // fig. 7 sweep present
        assert!(m.train_batches("mlp").len() >= 3);
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent/path").is_err());
    }
}
