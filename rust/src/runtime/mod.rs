//! PJRT runtime: load `artifacts/manifest.json` + HLO text, compile once,
//! execute from the coordinator's hot path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): HLO *text* is the
//! interchange format — xla_extension 0.5.1 rejects jax>=0.5 serialized
//! protos (64-bit instruction ids), while the text parser reassigns ids.

pub mod engine;
pub mod manifest;
pub mod value;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelEntry};
pub use value::Value;
